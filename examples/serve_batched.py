"""Batched serving example: prefill + decode waves over the engine.

Runs a hybrid (RecurrentGemma-family) smoke model — exercising the ring
window-attention caches and RG-LRU recurrent state — through the batched
request engine.

    PYTHONPATH=src python examples/serve_batched.py
"""

from repro.obs import clock

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine

cfg = get_smoke_config("recurrentgemma-2b").with_(dtype="float32")
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
requests = [Request(prompt=rng.integers(0, cfg.vocab_size, 12).tolist(),
                    max_new_tokens=24, temperature=0.8)
            for _ in range(12)]
engine = ServeEngine(model, params, batch_size=4, max_len=48, seed=0)

t0 = clock.perf_counter()
engine.run(requests)
dt = clock.perf_counter() - t0
total = sum(len(r.out_tokens) for r in requests)
print(f"served {len(requests)} requests / {total} tokens in {dt:.1f}s "
      f"({total/dt:.1f} tok/s, batch=4 waves)")
for i, r in enumerate(requests[:3]):
    print(f"req{i}: prompt={r.prompt[:6]}… → {r.out_tokens[:10]}…")
assert all(r.done for r in requests)
