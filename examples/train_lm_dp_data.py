"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
a DP synthetic-data pipeline released by Fast-MWEM.

The paper's technique enters as the data layer (DESIGN.md §5): the private
corpus' statistics are released once through Fast-MWEM under (ε, δ)-DP;
training batches are sampled from the synthetic histogram, so the model is
DP by post-processing. Any registry architecture works — this driver uses a
~100M-param llama3-family config.

    PYTHONPATH=src python examples/train_lm_dp_data.py [--steps 300]
"""

import argparse
from repro.obs import clock

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig, uniform_stages
from repro.data.private import PrivateDataPipeline
from repro.data.synthetic import SyntheticCorpus, batch_for_step
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--eps", type=float, default=2.0)
ap.add_argument("--ckpt", default="/tmp/repro_ckpt_dp")
args = ap.parse_args()

# ~100M params: llama3-family, 12L × 768
cfg = get_config("llama3-8b").with_(
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    head_dim=64, vocab_size=8192, stages=uniform_stages("attn", 12),
    tie_embeddings=True, dtype="float32")
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
print(f"model: {n_params/1e6:.1f}M params "
      f"({cfg.n_layers}L × {cfg.d_model}d, vocab {cfg.vocab_size})")

# ---- DP data release via Fast-MWEM ------------------------------------
corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
raw = np.asarray(batch_for_step(corpus, 0, 0, 1, 256, args.seq))
pipe = PrivateDataPipeline(vocab_size=cfg.vocab_size, eps=args.eps,
                           n_queries=512, T=150, index_kind="ivf", seed=0)
t0 = clock.perf_counter()
pipe.fit(raw)
eps, delta = pipe.privacy_spent()
print(f"Fast-MWEM release: (ε={eps:.2f}, δ={delta:.1e}) "
      f"in {clock.perf_counter()-t0:.1f}s — training is DP by post-processing")

# ---- train --------------------------------------------------------------
tcfg = TrainConfig(lr=1e-3, total_steps=args.steps, warmup_steps=20,
                   remat="none")
opt_init, train_step = make_train_step(model, tcfg)
train_step = jax.jit(train_step)
opt_state = opt_init(params)
ckpt = CheckpointManager(args.ckpt, keep_n=2)

losses = []
t0 = clock.perf_counter()
for step in range(args.steps):
    tokens = pipe.sample_batch(step, 0, args.batch, args.seq)
    params, opt_state, metrics = train_step(params, opt_state,
                                            {"tokens": tokens})
    losses.append(float(metrics["loss"]))
    if (step + 1) % 25 == 0:
        tok_s = (step + 1) * args.batch * args.seq / (clock.perf_counter() - t0)
        print(f"step {step+1:4d}  loss {losses[-1]:.4f}  tok/s {tok_s:,.0f}")
    if (step + 1) % 100 == 0:
        ckpt.save(step + 1, {"params": params, "opt": opt_state})

ckpt.save(args.steps, {"params": params, "opt": opt_state}, block=True)
import math
print(f"\nloss: {losses[0]:.3f} → {losses[-1]:.3f} "
      f"(uniform = ln V = {math.log(cfg.vocab_size):.3f}); "
      f"checkpoints in {args.ckpt}")
assert losses[-1] < losses[0], "training should reduce loss"
