"""Quickstart: private linear query release with Fast-MWEM.

Releases the answers to 1 000 random counting queries over a histogram of
500 records under (ε=1, δ=1e-3)-DP, comparing classic MWEM against
Fast-MWEM with an IVF index — same error, fewer score evaluations.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.obs import clock

import jax
import numpy as np

from repro.core import MWEMConfig, run_mwem
from repro.core.queries import gaussian_histogram, random_binary_queries, max_error
from repro.mips import FlatAbsIndex, IVFIndex, augment_complement

U, m, n, T = 256, 1000, 500, 150
key = jax.random.PRNGKey(0)
kh, kq = jax.random.split(key)
h = gaussian_histogram(kh, n, U)
Q = random_binary_queries(kq, m, U)

print(f"domain |X|={U}, m={m} queries, n={n} records, T={T} iterations")
print(f"uniform-baseline error: "
      f"{float(max_error(Q, h, jax.numpy.full((U,), 1/U))):.4f}\n")

# --- classic MWEM: exhaustive exponential mechanism -------------------
t0 = clock.perf_counter()
exact = run_mwem(Q, h, MWEMConfig(eps=1.0, delta=1e-3, T=T, mode="exact",
                                  n_records=n), jax.random.PRNGKey(1))
print(f"MWEM      (exhaustive): err={exact.final_error:.4f}  "
      f"scored/iter={int(np.mean(exact.n_scored))}  "
      f"wall={clock.perf_counter()-t0:.1f}s")

# --- Fast-MWEM: lazy Gumbel + k-MIPS index -----------------------------
for name, index in (
    ("flat", FlatAbsIndex(Q)),
    ("ivf", IVFIndex(augment_complement(np.asarray(Q)), seed=0)),
):
    t0 = clock.perf_counter()
    fast = run_mwem(Q, h, MWEMConfig(eps=1.0, delta=1e-3, T=T, mode="fast",
                                     n_records=n),
                    jax.random.PRNGKey(1), index=index)
    eps, delta = fast.ledger.composed()
    print(f"Fast-MWEM ({name:4s}):     err={fast.final_error:.4f}  "
          f"scored/iter={int(np.mean(fast.n_scored))}  "
          f"wall={clock.perf_counter()-t0:.1f}s  "
          f"(ε={eps:.2f}, δ={delta:.1e})")
