"""Release-service walkthrough: tenants, budgets, waves, zero-ε answers.

Three tenants with different datasets and budgets share one service. Their
release requests ride the same fixed-size `run_mwem_batch` wave; one
tenant's budget runs out and its request is rejected *before* anything is
spent; read traffic is answered from released histograms at zero extra ε.

    PYTHONPATH=src:. python examples/release_service.py
"""

import numpy as np

import jax

from repro.core import MWEMConfig
from repro.core.queries import random_binary_queries
from repro.serve import ReleaseService

U, m, n = 256, 1024, 2000
rng = np.random.default_rng(0)
Q = random_binary_queries(jax.random.PRNGKey(0), m, U)

svc = ReleaseService(Q, MWEMConfig(eps=0.5, delta=1e-3, T=30, mode="fast"),
                     wave_size=4, auto_flush=False)

# --- tenants: distinct private datasets, per-tenant (ε, δ) budgets ----------
for name, center, eps_budget in [("alpha", 60, 20.0), ("bravo", 120, 20.0),
                                 ("charlie", 200, 1e-3)]:  # charlie is broke
    tokens = np.clip(rng.normal(center, 20, size=n).astype(int), 0, U - 1)
    svc.create_session(name, tokens=tokens, eps_budget=eps_budget,
                       delta_budget=0.5)

tickets = {name: svc.submit(name) for name in ("alpha", "bravo", "charlie")}
for name, t in tickets.items():
    print(f"{name:8s} -> {t.status:9s}"
          + ("" if t.decision.admitted else f"  ({t.decision.reason})"))

done = svc.flush()
print(f"\nwave stats: {svc.stats.as_dict()}")
for t in done:
    sess = svc.session(t.tenant_id)
    eps, delta = sess.spent()
    print(f"{t.tenant_id:8s} released (err={t.final_error:.4f}) "
          f"spent ε={eps:.3f} δ={delta:.2e}, "
          f"remaining ε={sess.remaining()[0]:.3f}")

# --- zero-ε reads: repeats hit the cache, rollups derive from it ------------
q = np.asarray(Q)[5]
fresh = svc.answer("alpha", q)
again = svc.answer("alpha", q)
assert again.cached and again.value == fresh.value
combo = svc.answer_derived("alpha", {fresh.fingerprint: 2.0})
eps_after, _ = svc.session("alpha").spent()
print(f"\nanswer ⟨q5, p̂⟩ = {fresh.value:.4f} (repeat cached: {again.cached}, "
      f"2× rollup derived: {combo.value:.4f})")
print(f"alpha ε unchanged by reads: {eps_after:.3f} "
      f"(cache {svc.session('alpha').cache.hits} hits)")

# --- a second release composes; admission tracks the running ledger ---------
t2 = svc.submit("alpha")
svc.flush()
print(f"\nalpha second release: {t2.status}, "
      f"spent ε={svc.session('alpha').spent()[0]:.3f} of "
      f"{svc.session('alpha').eps_budget}")

# --- obs: the same story, read back from the metrics registry ----------------
snap = svc.metrics_snapshot()
lat = snap["histograms"]['admission_to_answer_seconds{kind=mwem}']
print(f"\nmetrics: admission→answer (mwem) "
      f"p50={lat['p50'] * 1e3:.1f}ms p95={lat['p95'] * 1e3:.1f}ms "
      f"over {lat['count']} releases")
print(f"metrics: cache hits={snap['counters']['answer_cache_hits_total']} "
      f"misses={snap['counters']['answer_cache_misses_total']}, "
      f"rejections={sum(v for k, v in snap['counters'].items() if k.startswith('admission_rejections_total'))}")
print(f"metrics: alpha ε-spent gauge="
      f"{snap['gauges']['tenant_eps_spent{tenant=alpha}']:.3f} "
      f"(matches ledger: {svc.session('alpha').spent()[0]:.3f})")
