"""Factored k-way marginal release — no (m, U) query table, ever.

A 12-attribute categorical domain (|X| = 32 768) with all 3-way
marginals is m = 3 328 queries over 220 cliques; the dense table would
be ~440 MB and at 15+ attributes it stops fitting at all. `MarginalWorkload` keeps the workload
as structured index maps (a few int32 arrays), and everything downstream
— Fast-MWEM selection via the clique-structured `MarginalIVFIndex`, the
adaptive worst-marginal loop, and the multi-tenant `ReleaseService` —
runs factored end to end (DESIGN.md §9).

    PYTHONPATH=src python examples/marginals.py
"""

from repro.obs import clock

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveConfig, MarginalWorkload, MWEMConfig,
                        run_adaptive_marginals, run_mwem)
from repro.core.queries import max_error
from repro.mips import MarginalIVFIndex
from repro.serve.release_service import ReleaseService

card = (4, 4, 4, 2, 2, 2, 2, 2, 2, 2, 2, 2)   # 12 attributes, |X| = 4096
W = MarginalWorkload.all_kway(card, 3)
n, T = 10_000, 40
key = jax.random.PRNGKey(0)
h = jax.nn.softmax(jax.random.normal(key, (W.U,)) * 2.0)

print(f"domain |X|={W.U}, {W.n_cliques} cliques, m={W.m} marginal queries")
print(f"dense table would be {W.dense_nbytes/1e6:.0f} MB; factored state is "
      f"{sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(W))/1e3:.0f} KB")
uniform = float(max_error(W, h, jnp.full((W.U,), 1.0 / W.U)))
print(f"uniform-baseline error: {uniform:.4f}\n")

# --- Fast-MWEM over the factored workload ------------------------------
t0 = clock.perf_counter()
res = run_mwem(W, h, MWEMConfig(eps=1.0, delta=1e-3, T=T, mode="fast",
                                n_records=n),
               jax.random.PRNGKey(1), index=MarginalIVFIndex(W))
eps, delta = res.ledger.composed()
print(f"Fast-MWEM (marginal_ivf): err={res.final_error:.4f}  "
      f"scored/iter={int(np.mean(res.n_scored))} of {2*W.m}  "
      f"wall={clock.perf_counter()-t0:.1f}s  (ε={eps:.2f}, δ={delta:.1e})")

# --- adaptive worst-marginal loop: whole tables per round --------------
t0 = clock.perf_counter()
ad = run_adaptive_marginals(W, h, AdaptiveConfig(eps=1.0, delta=1e-3, T=12,
                                                 n_records=n),
                            jax.random.PRNGKey(2))
print(f"adaptive marginals:       err={float(ad.final_error):.4f}  "
      f"{len(set(map(int, ad.selected)))} distinct cliques measured  "
      f"wall={clock.perf_counter()-t0:.1f}s  (ε={ad.eps_spent:.2f})")

# --- the same workload through the serving tier ------------------------
svc = ReleaseService(W, MWEMConfig(eps=1.0, delta=1e-3, T=T, mode="fast",
                                   n_records=n, use_pallas="never"),
                     wave_size=2, index_kind="marginal_ivf")
svc.create_session("tenant-a", eps_budget=10.0, delta_budget=1e-2,
                   h=np.asarray(h, np.float32), n_records=n)
svc.create_session("tenant-b", eps_budget=10.0, delta_budget=1e-2,
                   h=np.asarray(h, np.float32), n_records=n)
t1, t2 = svc.submit("tenant-a"), svc.submit("tenant-b")
print(f"\nservice wave: tickets {t1.status}/{t2.status}, "
      f"errs {t1.final_error:.4f}/{t2.final_error:.4f}")
ans = svc.answer("tenant-a", np.ones(W.U, np.float32))
print(f"post-processing answer ⟨1, p̂⟩ = {ans.value:.4f} (zero extra ε)")
