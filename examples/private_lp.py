"""Privately solving LPs with Fast-MWEM (paper §4).

1. Scalar-private feasibility LP (Alg. 3): Ax ≤ b over the simplex, b
   private with Δ∞ sensitivity — fast constraint selection via k-MIPS over
   the concatenated rows [A_i, b_i].
2. Constraint-private packing LP (§4.2): dense MWU on the dual with
   Bregman projections; the dual oracle maximizes ⟨y, N_j⟩ via LazyEM.

    PYTHONPATH=src python examples/private_lp.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DualLPConfig, ScalarLPConfig,
                        solve_constraint_private_lp, solve_scalar_lp)
from repro.core.queries import random_feasible_lp, random_packing_lp
from repro.mips import FlatIndex, IVFIndex

# ---- scalar-private LP -------------------------------------------------
m, d = 4000, 20
A, b, x_star = random_feasible_lp(jax.random.PRNGKey(0), m=m, d=d)
print(f"scalar-private LP: m={m} constraints, d={d}, Δ∞=0.1, α=0.5")

t0 = time.time()
exact = solve_scalar_lp(A, b, ScalarLPConfig(T=150, mode="exact"),
                        jax.random.PRNGKey(1))
print(f"  exhaustive: violated={exact.violated_frac:.4f} "
      f"wall={time.time()-t0:.1f}s")

Ab = np.concatenate([np.asarray(A), np.asarray(b)[:, None]], axis=1)
for name, index in (("flat", FlatIndex(Ab, use_pallas='never')),
                    ("ivf", IVFIndex(Ab, seed=0))):
    t0 = time.time()
    fast = solve_scalar_lp(A, b, ScalarLPConfig(T=150, mode="fast"),
                           jax.random.PRNGKey(1), index=index)
    print(f"  fast-{name:4s}: violated={fast.violated_frac:.4f} "
          f"scored/iter={int(np.mean(fast.n_scored))} "
          f"wall={time.time()-t0:.1f}s")

# ---- constraint-private packing LP ------------------------------------
m2, d2 = 300, 128
A2, b2, c2 = random_packing_lp(jax.random.PRNGKey(2), m=m2, d=d2)
opt = float(c2 @ jnp.full((d2,), 1.0 / d2)) * 0.5
print(f"\nconstraint-private packing LP: m={m2}, d={d2}, OPT={opt:.3f}")
N = np.asarray(-(opt / c2)[:, None] * A2.T)
res = solve_constraint_private_lp(
    A2, b2, c2, opt, DualLPConfig(T=150, s=12, alpha=1.0, mode="fast"),
    jax.random.PRNGKey(3), index=FlatIndex(N, use_pallas="never"))
print(f"  violated beyond α: {res.n_violated}/{m2} "
      f"(density bound s−1={12-1}) value={float(res.x_bar @ c2):.3f}")
