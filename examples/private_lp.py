"""Privately solving LPs with Fast-MWEM (paper §4, DESIGN.md §6).

1. Scalar-private feasibility LP (Alg. 3): Ax ≤ b over the simplex, b
   private with Δ∞ sensitivity — fast constraint selection via k-MIPS over
   the concatenated rows [A_i, b_i], run on both drivers (the fused scan
   dispatches the whole T-iteration loop once).
2. Constraint-private packing LP (§4.2): dense MWU on the dual with
   in-graph Bregman projections; the dual oracle maximizes ⟨y, N_j⟩.
3. The serving tier: tenants draw budget-admitted private solves from a
   `ReleaseService` LP workload through batched waves.

    PYTHONPATH=src python examples/private_lp.py
"""

from repro.obs import clock

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DualLPConfig, MWEMConfig, ScalarLPConfig,
                        solve_constraint_private_lp, solve_scalar_lp)
from repro.core.queries import random_feasible_lp, random_packing_lp
from repro.mips import FlatIndex, IVFIndex, lp_dual_rows, lp_scalar_rows
from repro.serve import ReleaseService

# ---- scalar-private LP -------------------------------------------------
m, d = 4000, 20
A, b, x_star = random_feasible_lp(jax.random.PRNGKey(0), m=m, d=d)
print(f"scalar-private LP: m={m} constraints, d={d}, Δ∞=0.1, α=0.5")

t0 = clock.perf_counter()
exact = solve_scalar_lp(A, b, ScalarLPConfig(T=150, mode="exact"),
                        jax.random.PRNGKey(1))
print(f"  exhaustive: violated={exact.violated_frac:.4f} "
      f"wall={clock.perf_counter()-t0:.1f}s")

Ab = lp_scalar_rows(np.asarray(A), np.asarray(b))
for name, index in (("flat", FlatIndex(Ab, use_pallas="never")),
                    ("ivf", IVFIndex(Ab, seed=0))):
    for driver in ("host", "fused"):
        t0 = clock.perf_counter()
        cfg = ScalarLPConfig(T=150, mode="fast", driver=driver)
        fast = solve_scalar_lp(A, b, cfg, jax.random.PRNGKey(1), index=index)
        print(f"  fast-{name:4s}/{driver:5s}: "
              f"violated={fast.violated_frac:.4f} "
              f"scored/iter={int(np.mean(fast.n_scored))} "
              f"wall={clock.perf_counter()-t0:.1f}s")

# ---- constraint-private packing LP ------------------------------------
m2, d2 = 300, 128
A2, b2, c2 = random_packing_lp(jax.random.PRNGKey(2), m=m2, d=d2)
opt = float(c2 @ jnp.full((d2,), 1.0 / d2)) * 0.5
print(f"\nconstraint-private packing LP: m={m2}, d={d2}, OPT={opt:.3f}")
N = lp_dual_rows(np.asarray(A2), np.asarray(c2), opt)
res = solve_constraint_private_lp(
    A2, b2, c2, opt, DualLPConfig(T=150, s=12, alpha=1.0, mode="fast"),
    jax.random.PRNGKey(3), index=FlatIndex(N, use_pallas="never"))
print(f"  fused dual: violated beyond α: {res.n_violated}/{m2} "
      f"(density bound s−1={12-1}) value={float(res.x_bar @ c2):.3f}")

# ---- LP releases through the serving tier -----------------------------
print("\nLP releases through ReleaseService (budget-admitted waves):")
U, M = 64, 128
Q = jax.random.bernoulli(jax.random.PRNGKey(9), 0.3, (M, U)).astype(jnp.float32)
svc = ReleaseService(Q, MWEMConfig(eps=0.5, T=8, mode="fast"), wave_size=2,
                     auto_flush=False)
svc.attach_lp(A, b, ScalarLPConfig(eps=0.5, T=60, mode="fast"))
h = np.full((U,), 1.0 / U, np.float32)
svc.create_session("analyst-a", eps_budget=5.0, delta_budget=0.1,
                   h=h, n_records=1000)
svc.create_session("analyst-b", eps_budget=0.05, delta_budget=0.1,
                   h=h, n_records=1000)
ok = svc.submit_lp("analyst-a", seed=7)
tight = svc.submit_lp("analyst-b")          # budget too small → rejected
print(f"  analyst-a: {ok.status} "
      f"(projected ε={ok.decision.eps_projected:.3f})")
print(f"  analyst-b: {tight.status} ({tight.decision.reason})")
svc.flush()
rel = svc.session("analyst-a").latest_lp
print(f"  released x̄: violated={rel.violated_frac:.4f} "
      f"ε-cost={rel.eps_cost:.3f}  stats={svc.stats.as_dict()}")
