"""Data pipeline: deterministic synthetic corpus + DP synthetic-data release."""

from repro.data.synthetic import SyntheticCorpus, batch_for_step
from repro.data.private import PrivateDataPipeline

__all__ = ["SyntheticCorpus", "batch_for_step", "PrivateDataPipeline"]
