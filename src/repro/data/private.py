"""DP synthetic-data release — Fast-MWEM as a first-class pipeline stage.

The framework integration of the paper's technique (DESIGN.md §5): given a
private token corpus, release its unigram/marginal statistics through
Fast-MWEM under (ε, δ)-DP, then train any of the architecture zoo on
batches sampled from the *synthetic* histogram. The trained model is DP
w.r.t. the corpus by post-processing (Thm B.2) — no per-step noise, no
architecture coupling.

``PrivateDataPipeline.fit`` runs Fast-MWEM (sublinear per-iteration in the
number of marginal queries via the k-MIPS index); ``sample_batch`` draws
training sequences from the released histogram with the same deterministic
(seed, step, shard) contract as the raw pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MWEMConfig, run_mwem
from repro.core.accountant import PrivacyLedger
from repro.core.queries import ngram_marginal_queries
from repro.mips import FlatAbsIndex, IVFIndex, augment_complement


@dataclass
class PrivateDataPipeline:
    vocab_size: int
    eps: float = 1.0
    delta: float = 1e-3
    n_queries: int = 512
    query_arity: int = 64
    T: int = 100
    index_kind: str = "flat"     # flat | ivf
    seed: int = 0
    p_hat: Optional[jax.Array] = None
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)

    def fit(self, tokens: np.ndarray) -> "PrivateDataPipeline":
        """Release the corpus' token histogram privately via Fast-MWEM."""
        tokens = np.asarray(tokens).reshape(-1)
        n = tokens.size
        h = np.bincount(tokens, minlength=self.vocab_size).astype(np.float32) / n
        key = jax.random.PRNGKey(self.seed)
        kq, krun = jax.random.split(key)
        Q = ngram_marginal_queries(kq, self.n_queries, self.vocab_size,
                                   arity=self.query_arity)
        if self.index_kind == "flat":
            index = FlatAbsIndex(Q)
        else:
            index = IVFIndex(augment_complement(np.asarray(Q)), seed=self.seed)
        cfg = MWEMConfig(eps=self.eps, delta=self.delta, T=self.T,
                         mode="fast", n_records=n)
        res = run_mwem(jnp.asarray(Q), jnp.asarray(h), cfg, krun, index=index,
                       ledger=self.ledger)
        self.p_hat = res.p_hat
        return self

    def privacy_spent(self):
        return self.ledger.composed()

    def sample_batch(self, step: int, shard: int, per_shard: int,
                     seq_len: int) -> jax.Array:
        """Sample token sequences from the released histogram (deterministic
        in (seed, step, shard) — same contract as the raw pipeline)."""
        assert self.p_hat is not None, "call fit() first"
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step), shard)
        logits = jnp.log(jnp.maximum(self.p_hat, 1e-12))
        return jax.random.categorical(key, logits, shape=(per_shard, seq_len))
