"""DP synthetic-data release — Fast-MWEM as a first-class pipeline stage.

The framework integration of the paper's technique (DESIGN.md §5): given a
private token corpus, release its unigram/marginal statistics through
Fast-MWEM under (ε, δ)-DP, then train any of the architecture zoo on
batches sampled from the *synthetic* histogram. The trained model is DP
w.r.t. the corpus by post-processing (Thm B.2) — no per-step noise, no
architecture coupling.

``PrivateDataPipeline.fit`` runs Fast-MWEM (sublinear per-iteration in the
number of marginal queries via the k-MIPS index); ``sample_batch`` draws
training sequences from the released histogram with the same deterministic
(seed, step, shard) contract as the raw pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MWEMConfig, release_cost, run_mwem
from repro.core.accountant import PrivacyLedger
from repro.core.queries import ngram_marginal_queries
from repro.mips import FlatAbsIndex, IVFIndex, augment_complement


@dataclass
class PrivateDataPipeline:
    vocab_size: int
    eps: float = 1.0
    delta: float = 1e-3
    n_queries: int = 512
    query_arity: int = 64
    T: int = 100
    index_kind: str = "flat"     # flat | ivf
    seed: int = 0
    p_hat: Optional[jax.Array] = None
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)

    def fit(self, tokens: np.ndarray) -> "PrivateDataPipeline":
        """Release the corpus' token histogram privately via Fast-MWEM."""
        tokens = np.asarray(tokens).reshape(-1)
        n = tokens.size
        h = np.bincount(tokens, minlength=self.vocab_size).astype(np.float32) / n
        key = jax.random.PRNGKey(self.seed)
        kq, krun = jax.random.split(key)
        Q = ngram_marginal_queries(kq, self.n_queries, self.vocab_size,
                                   arity=self.query_arity)
        if self.index_kind == "flat":
            index = FlatAbsIndex(Q)
        else:
            index = IVFIndex(augment_complement(np.asarray(Q)), seed=self.seed)
        cfg = MWEMConfig(eps=self.eps, delta=self.delta, T=self.T,
                         mode="fast", n_records=n)
        res = run_mwem(jnp.asarray(Q), jnp.asarray(h), cfg, krun, index=index,
                       ledger=self.ledger)
        self.p_hat = res.p_hat
        return self

    def fit_via_service(self, tokens: np.ndarray, service, tenant_id: str = "pipeline",
                        eps_budget: Optional[float] = None,
                        delta_budget: Optional[float] = None) -> "PrivateDataPipeline":
        """Release through a shared `repro.serve.ReleaseService` instead of a
        standalone run: the pipeline becomes one tenant among many, its
        release rides a cross-tenant wave, and its privacy spend lands on
        the service session's ledger (adopted as ``self.ledger``).

        Default budgets admit exactly one release (the projected composed
        cost of this request); pass explicit budgets to leave headroom for
        later releases on the same session.
        """
        if service.U != self.vocab_size:
            raise ValueError(f"service domain U={service.U} != "
                             f"vocab_size={self.vocab_size}")
        tokens = np.asarray(tokens).reshape(-1)
        if eps_budget is None or delta_budget is None:
            cfg = service._group_cfg(tokens.size)
            # preview in the service's composition mode, or the sized-to-fit
            # budget could be rejected by a tight-mode admission check
            cost = PrivacyLedger().preview(
                *release_cost(cfg, service.m, service.U, index=service.index),
                tight=service.admission.tight)
            eps_budget = cost[0] if eps_budget is None else eps_budget
            delta_budget = cost[1] if delta_budget is None else delta_budget
        sess = service.create_session(tenant_id, tokens=tokens,
                                      eps_budget=eps_budget,
                                      delta_budget=delta_budget)
        ticket = service.submit(tenant_id, seed=self.seed)
        if ticket.status == "rejected":
            raise RuntimeError(f"release rejected: {ticket.decision.reason}")
        if ticket.status != "done":
            service.flush()
        self.p_hat = jnp.asarray(ticket.release.p_hat)
        self.ledger = sess.ledger
        return self

    def privacy_spent(self):
        return self.ledger.composed()

    def sample_batch(self, step: int, shard: int, per_shard: int,
                     seq_len: int) -> jax.Array:
        """Sample token sequences from the released histogram (deterministic
        in (seed, step, shard) — same contract as the raw pipeline)."""
        assert self.p_hat is not None, "call fit() first"
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step), shard)
        logits = jnp.log(jnp.maximum(self.p_hat, 1e-12))
        return jax.random.categorical(key, logits, shape=(per_shard, seq_len))
