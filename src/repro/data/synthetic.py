"""Deterministic, shardable synthetic token corpus.

Every batch is a pure function of (seed, step, shard) — the property the
elastic runtime (repro.train.elastic) relies on: any host can regenerate
any shard after a failure, with no loader state to checkpoint.

The token stream is a Zipf-ish unigram mixture with Markov structure so
models actually have something learnable (losses go below uniform entropy).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_states: int = 16

    def unigram(self) -> np.ndarray:
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        return (p / p.sum()).astype(np.float32)

    def sample_tokens(self, key: jax.Array, shape) -> jax.Array:
        """Markov-modulated Zipf draw (jit-friendly)."""
        k1, k2 = jax.random.split(key)
        logits = jnp.log(jnp.asarray(self.unigram()))
        # per-position state shifts the distribution to induce structure
        state = jax.random.randint(k1, shape[:-1] + (1,), 0, self.markov_states)
        shift = (state * (self.vocab_size // self.markov_states))
        base = jax.random.categorical(k2, logits, shape=shape)
        return (base + shift) % self.vocab_size


def batch_for_step(corpus: SyntheticCorpus, step: int, shard: int,
                   n_shards: int, per_shard: int, seq_len: int):
    """The deterministic batch contract: (seed, step, shard) → tokens."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(corpus.seed), step), shard)
    return corpus.sample_tokens(key, (per_shard, seq_len))
