"""Deterministic fault injection for the serving tier's chaos suite.

The fault-tolerance layer (DESIGN.md §10) is only trustworthy if its
failure paths actually execute, so this module plants named *fault sites*
at the seams a real deployment fails at:

* ``wave.dispatch``    — the release wave's batched driver call
* ``ledger.commit``    — phase two of the budget commit
* ``journal.append``   — the write-ahead journal's disk write
* ``kernel.mwem_step`` — the megakernel step seam (trace/compile path)
* ``index.probe``      — the k-MIPS probe seam

Each site is one call to `fault_site(name)`; when no plan is armed it is a
single ``is None`` check — zero overhead, no allocation, nothing touches
JAX. Arming is scoped through the `inject` context manager with per-site
`Schedule`s:

    with inject({"wave.dispatch": Schedule(fail_n=2)}) as plan:
        service.flush()          # first two dispatches raise FaultInjected
    plan.hits["wave.dispatch"]   # how often the site was reached

Schedules are deterministic: ``fail_n`` fails the first n hits,
``fail_rate`` draws a seeded per-hit Bernoulli (the seed folds the site
name through crc32, so two sites armed from one seed fail independently
but reproducibly), and ``latency`` sleeps through `repro.obs.clock` —
the repo's single sanctioned time seam — before letting the hit proceed.
`FaultInjected` subclasses ``RuntimeError`` so the serving tier's
retryable-failure classification treats it exactly like a device/runtime
fault (a ``ValueError`` stays a programming error and propagates).
"""

from __future__ import annotations

import threading
import zlib
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.obs import clock

SITES = (
    "wave.dispatch",
    "ledger.commit",
    "journal.append",
    "kernel.mwem_step",
    "index.probe",
)


class FaultInjected(RuntimeError):
    """Raised by an armed fault site. Carries the site name so the obs
    layer can label `dispatch_failures_total{site=...}` per seam."""

    def __init__(self, site: str, hit: int):
        super().__init__(f"injected fault at {site} (hit #{hit})")
        self.site = site
        self.hit = hit


@dataclass(frozen=True)
class Schedule:
    """Per-site failure schedule. All fields compose: an armed site first
    sleeps ``latency`` seconds, then fails if the hit is scheduled to."""

    fail_n: int = 0          # fail the first n hits (fail-once: fail_n=1)
    fail_rate: float = 0.0   # seeded per-hit Bernoulli failure probability
    latency: float = 0.0     # injected delay (seconds) per hit
    seed: int = 0            # drives the fail_rate draws, per-site folded


def fail_once() -> Schedule:
    return Schedule(fail_n=1)


def fail_n(n: int) -> Schedule:
    return Schedule(fail_n=n)


def _site_rng(site: str, seed: int) -> np.random.Generator:
    # stable across processes (never `hash`, which is salted per run)
    return np.random.default_rng(np.uint32(seed) + zlib.crc32(site.encode()))


class FaultPlan:
    """An armed set of per-site schedules plus hit/fail accounting."""

    def __init__(self, schedules: Dict[str, Schedule]):
        unknown = set(schedules) - set(SITES)
        if unknown:
            raise ValueError(f"unknown fault site(s) {sorted(unknown)}; "
                             f"known: {list(SITES)}")
        self.schedules = dict(schedules)
        self.hits: Dict[str, int] = {s: 0 for s in schedules}
        self.failures: Dict[str, int] = {s: 0 for s in schedules}
        self._rngs = {s: _site_rng(s, sch.seed)
                      for s, sch in schedules.items()}
        self._lock = threading.Lock()

    def check(self, site: str) -> None:
        sched = self.schedules.get(site)
        if sched is None:
            return
        with self._lock:
            self.hits[site] += 1
            hit = self.hits[site]
            fail = hit <= sched.fail_n
            if not fail and sched.fail_rate > 0.0:
                fail = bool(self._rngs[site].random() < sched.fail_rate)
            if fail:
                self.failures[site] += 1
        if sched.latency > 0.0:
            clock.sleep(sched.latency)
        if fail:
            raise FaultInjected(site, hit)


_active: Optional[FaultPlan] = None


def fault_site(site: str) -> None:
    """The instrumentation hook. Disarmed: one ``is None`` check."""
    if _active is None:
        return
    _active.check(site)


def active_plan() -> Optional[FaultPlan]:
    return _active


@contextmanager
def inject(schedules: Dict[str, Schedule]):
    """Arm ``schedules`` for the dynamic extent of the block. Nesting
    replaces the outer plan (the chaos suite never needs two at once and
    silent merging would make sweeps ambiguous)."""
    global _active
    prior = _active
    plan = FaultPlan(schedules)
    _active = plan
    try:
        yield plan
    finally:
        _active = prior
