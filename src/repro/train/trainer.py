"""Train-step builder: grad-accumulation scan, remat, sharded update.

``make_train_step`` returns a pure function
    (params, opt_state, batch, key) → (params, opt_state, metrics)
suitable for ``jax.jit`` with in/out shardings derived from the model's
logical specs via ``param_shardings``. Microbatch gradient accumulation is
a ``lax.scan`` over the leading batch split — activation memory scales with
the microbatch, HLO size stays constant.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShardingRules, TrainConfig
from repro.train.optim import make_optimizer
from repro.train.compression import ef_allreduce_grads


def param_shardings(specs, rules: ShardingRules, mesh):
    """Logical spec tree → NamedSharding tree."""
    def to_sharding(logical):
        return NamedSharding(mesh, rules.spec(*logical))
    return jax.tree.map(to_sharding, specs,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_shardings(batch_tree, rules: ShardingRules, mesh):
    def spec_for(x):
        ndim = len(x.shape)
        return NamedSharding(mesh, rules.spec(*(["batch"] + [None] * (ndim - 1))))
    return jax.tree.map(spec_for, batch_tree)


def constrain_like_params(tree, param_specs):
    """Constrain a param-shaped tree (e.g. grad accumulators) to the params'
    logical sharding — without this the f32 accumulation buffers stay
    replicated and every microbatch's gradient sync becomes a full
    all-reduce instead of a reduce-scatter."""
    from repro.models.common import current_mesh_and_rules

    state = current_mesh_and_rules()
    if state is None or param_specs is None:
        return tree
    mesh, rules = state
    from jax.sharding import NamedSharding

    def con(x, spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, rules.spec(*spec)))

    return jax.tree.map(con, tree, param_specs,
                        is_leaf=lambda x: not isinstance(x, dict))


def make_train_step(model, tcfg: TrainConfig, pod_axis: Optional[str] = None,
                    param_specs=None):
    """Build the jittable train step for ``model`` (a repro.models.LM)."""
    opt_init, opt_update = make_optimizer(tcfg)
    remat = False if tcfg.remat == "none" else tcfg.remat

    def loss_fn(params, microbatch):
        return model.loss(params, microbatch, remat=remat)

    def train_step(params, opt_state, batch):
        n_micro = tcfg.microbatches

        if n_micro <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain_like_params(grads, param_specs)
        else:
            def split(x):
                return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads = constrain_like_params(grads, param_specs)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            zeros = constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params), param_specs)
            (loss_sum, grads), _ = jax.lax.scan(accum, (0.0, zeros), micro)
            loss = loss_sum / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        if tcfg.grad_compression and pod_axis is not None:
            grads, opt_state = ef_allreduce_grads(grads, opt_state, pod_axis)

        params, opt_state, metrics = opt_update(grads, opt_state, params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return opt_init, train_step
