"""Training substrate: optimizers, trainer, checkpointing, elasticity."""

from repro.train.optim import make_optimizer
from repro.train.trainer import make_train_step, param_shardings
from repro.train.checkpoint import CheckpointManager

__all__ = ["make_optimizer", "make_train_step", "param_shardings",
           "CheckpointManager"]
