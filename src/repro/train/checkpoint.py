"""Fault-tolerant checkpointing: atomic, asynchronous, resumable.

Layout: ``<dir>/step_<N>/shard_<host>.npz`` + ``meta.json``; a checkpoint
becomes visible only through the atomic ``os.replace`` of its directory
(written under ``.tmp`` first), so a killed writer never leaves a torn
checkpoint. Saves run on a background thread (training continues); restore
scans for the newest complete step. ``keep_n`` old checkpoints are retained
for rollback after a bad node poisons a step.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, arrays: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep_n = keep_n
        self.host_id = host_id
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, state: Any, block: bool = False):
        """Checkpoint ``state`` (any pytree). Asynchronous unless block."""
        self.wait()  # one in-flight save at a time
        flat = _flatten(state)

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:08d}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "n_arrays": len(flat)}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def list_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                meta = os.path.join(self.dir, name, "meta.json")
                if os.path.exists(meta):  # complete (atomic rename happened)
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def restore_latest(self, template: Any):
        """Returns (step, state) or (None, None) when no checkpoint exists."""
        steps = self.list_steps()
        if not steps:
            return None, None
        step = steps[-1]
        path = os.path.join(self.dir, f"step_{step:08d}",
                            f"shard_{self.host_id}.npz")
        with np.load(path) as data:
            arrays = {k: data[k] for k in data.files}
        return step, _unflatten_into(template, arrays)
