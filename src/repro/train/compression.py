"""int8 error-feedback gradient compression for the cross-pod axis.

At 512+ chips the pod-to-pod (DCN/ICI-bridge) all-reduce is the scarcest
bandwidth. We compress the cross-pod gradient exchange to int8 with
per-tensor-block scales and an error-feedback buffer (the quantization
residual is added back into the next step's gradient), which preserves
convergence (Karimireddy et al. 2019) while cutting cross-pod bytes 4×.

The exchange itself is a ring all-reduce built from ``lax.ppermute``:
P−1 reduce-scatter hops + P−1 all-gather hops, each moving int8 chunks and
accumulating in f32 locally — int8 summation never overflows because
accumulation happens post-dequantization.

Intended use: inside ``shard_map`` over the "pod" mesh axis, with the
intra-pod reduction already done by the partitioner (psum over "data").
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 2048


def _quantize(x: jax.Array):
    """Per-block symmetric int8 quantization. x: flat f32."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def _dequantize(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def _axis_size(axis_name: str) -> int:
    """Static axis size inside shard_map/pmap. ``jax.lax.axis_size`` is
    recent API; older JAX gets it from the constant-folded ``psum(1, ·)``."""
    size = getattr(jax.lax, "axis_size", None)
    if size is not None:
        return int(size(axis_name))
    return int(jax.lax.psum(1, axis_name))


def ring_allreduce_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean all-reduce of a flat f32 vector with int8 wire format.

    Must run inside shard_map/pmap over ``axis_name``.
    """
    P = _axis_size(axis_name)
    if P == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    n = x.shape[0]
    pad = (-n) % P
    xp = jnp.pad(x, (0, pad)).reshape(P, -1)     # P chunks
    perm_fwd = [(i, (i + 1) % P) for i in range(P)]

    # reduce-scatter: after P−1 hops, chunk (idx+1) holds the full sum here
    acc = xp
    for step in range(P - 1):
        send_chunk = (idx - step) % P
        payload = jnp.take(acc, send_chunk, axis=0)
        q, s, m = _quantize(payload)
        q = jax.lax.ppermute(q, axis_name, perm_fwd)
        s = jax.lax.ppermute(s, axis_name, perm_fwd)
        recv_chunk = (idx - step - 1) % P
        recovered = _dequantize(q, s, m)
        acc = acc.at[recv_chunk].add(recovered.reshape(acc.shape[1:]))

    # all-gather: circulate the reduced chunks
    own = (idx + 1) % P
    out = jnp.zeros_like(acc)
    cur = jnp.take(acc, own, axis=0)
    out = out.at[own].set(cur)
    for step in range(P - 1):
        q, s, m = _quantize(cur)
        q = jax.lax.ppermute(q, axis_name, perm_fwd)
        s = jax.lax.ppermute(s, axis_name, perm_fwd)
        cur = _dequantize(q, s, m).reshape(acc.shape[1:])
        chunk_id = (own - step - 1) % P
        out = out.at[chunk_id].set(cur)

    return out.reshape(-1)[:n] / P


def ef_allreduce_grads(grads, opt_state, pod_axis: str):
    """Error-feedback int8 cross-pod gradient all-reduce.

    The error buffer lives in ``opt_state["ef_error"]`` (created lazily).
    Returns (new_grads, new_opt_state).
    """
    flat, treedef = jax.tree.flatten(grads)
    sizes = [x.size for x in flat]
    shapes = [x.shape for x in flat]
    vec = jnp.concatenate([x.reshape(-1).astype(jnp.float32) for x in flat])

    err = opt_state.get("ef_error")
    if err is None:
        err = jnp.zeros_like(vec)
    vec = vec + err

    # local quantization error becomes next step's feedback
    q, s, n = _quantize(vec)
    sent = _dequantize(q, s, n)
    new_err = vec - sent

    reduced = ring_allreduce_int8(sent, pod_axis)

    out, offset = [], 0
    for size, shape in zip(sizes, shapes):
        out.append(reduced[offset:offset + size].reshape(shape))
        offset += size
    new_opt_state = dict(opt_state)
    new_opt_state["ef_error"] = new_err
    return jax.tree.unflatten(treedef, out), new_opt_state
