"""Optimizers: Adam and Adafactor with dtype-configurable state.

Pure-functional: ``make_optimizer(tcfg) → (init_fn, update_fn)``. The huge
archs (340B/72B) use Adafactor (factored second moments) or bf16 Adam state
to fit the per-device HBM budget — selected per arch in the launcher.
"""

from __future__ import annotations

import math
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_schedule(tcfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(tcfg.warmup_steps, 1))
    frac = jnp.clip((step - tcfg.warmup_steps)
                    / max(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return tcfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def make_optimizer(tcfg: TrainConfig) -> Tuple[Callable, Callable]:
    state_dtype = jnp.bfloat16 if tcfg.state_dtype == "bfloat16" else jnp.float32

    if tcfg.optimizer == "adam":
        def init_fn(params):
            zeros = lambda p: jnp.zeros(p.shape, state_dtype)
            return {"mu": jax.tree.map(zeros, params),
                    "nu": jax.tree.map(zeros, params),
                    "step": jnp.zeros((), jnp.int32)}

        def update_fn(grads, state, params):
            step = state["step"] + 1
            lr = lr_schedule(tcfg, step)
            grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
            b1, b2 = tcfg.b1, tcfg.b2

            def upd(g, mu, nu, p):
                g = g.astype(jnp.float32)
                mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
                nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
                mu_hat = mu_n / (1 - b1 ** step.astype(jnp.float32))
                nu_hat = nu_n / (1 - b2 ** step.astype(jnp.float32))
                delta = lr * mu_hat / (jnp.sqrt(nu_hat) + tcfg.eps)
                if tcfg.weight_decay:
                    delta = delta + lr * tcfg.weight_decay * p.astype(jnp.float32)
                return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                        mu_n.astype(state_dtype), nu_n.astype(state_dtype))

            out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
            new_params = jax.tree.map(lambda o: o[0], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
            new_mu = jax.tree.map(lambda o: o[1], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
            new_nu = jax.tree.map(lambda o: o[2], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
            new_state = {"mu": new_mu, "nu": new_nu, "step": step}
            return new_params, new_state, {"lr": lr, "grad_norm": gnorm}

        return init_fn, update_fn

    if tcfg.optimizer == "adafactor":
        def init_fn(params):
            def factored(p):
                if p.ndim >= 2:
                    return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                            "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return {"v": jax.tree.map(factored, params),
                    "step": jnp.zeros((), jnp.int32)}

        def update_fn(grads, state, params):
            step = state["step"] + 1
            lr = lr_schedule(tcfg, step)
            grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
            beta2 = 1.0 - (step.astype(jnp.float32)) ** -0.8

            def upd(g, v, p):
                g = g.astype(jnp.float32)
                g2 = g * g + 1e-30
                if p.ndim >= 2:
                    vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                    vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                    rms = (vr[..., None] * vc[..., None, :]
                           / jnp.maximum(jnp.mean(vr, axis=-1,
                                                  keepdims=True)[..., None], 1e-30))
                    precond = g / jnp.sqrt(rms + 1e-30)
                    new_v = {"vr": vr, "vc": vc}
                else:
                    vv = beta2 * v["v"] + (1 - beta2) * g2
                    precond = g / jnp.sqrt(vv + 1e-30)
                    new_v = {"v": vv}
                # relative-scale update clipping (Adafactor's d=1 rule)
                d = jnp.maximum(1.0, jnp.sqrt(jnp.mean(precond * precond)))
                delta = lr * precond / d
                if tcfg.weight_decay:
                    delta = delta + lr * tcfg.weight_decay * p.astype(jnp.float32)
                return ((p.astype(jnp.float32) - delta).astype(p.dtype), new_v)

            is_v = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
            out = jax.tree.map(upd, grads, state["v"], params, is_leaf=is_v)
            is_pair = lambda x: isinstance(x, tuple)
            new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
            new_v = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
            return new_params, {"v": new_v, "step": step}, \
                {"lr": lr, "grad_norm": gnorm}

        return init_fn, update_fn

    raise ValueError(f"unknown optimizer {tcfg.optimizer!r}")
