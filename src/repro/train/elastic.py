"""Elastic scaling + straggler mitigation for 1000+ node runs.

Design (simulated here, since the container has one host):

* **Deterministic, index-based data**: every batch is a pure function of
  (seed, step, shard, n_shards) — `shard_plan`. Any surviving host can
  recompute any failed host's shard; there is no data-loader state to lose.
* **Mesh re-planning**: on node failure the controller computes the largest
  valid mesh from the healthy device count (`plan_mesh`), keeping the model
  axis intact (TP degree is a property of the checkpointed layout) and
  shrinking the data axis — then re-lowers the step and restores the latest
  checkpoint. Growth (nodes coming back) is the same path.
* **Straggler watchdog**: per-step heartbeats; a host slower than
  `threshold ×` the median for `patience` consecutive steps is treated as
  failed (eject + reshard) — slow nodes hurt a synchronous program exactly
  as much as dead ones.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional


def plan_mesh(n_healthy: int, model_degree: int, pods: int = 1):
    """Largest (pods, data, model) grid that fits the healthy devices.

    The model axis is fixed by the checkpoint layout; data shrinks to the
    largest whole multiple.
    """
    per_pod = n_healthy // pods
    data = per_pod // model_degree
    if data < 1:
        raise RuntimeError(
            f"cannot keep model_degree={model_degree} with {n_healthy} devices")
    used = pods * data * model_degree
    shape = (pods, data, model_degree) if pods > 1 else (data, model_degree)
    return shape, used


def shard_plan(seed: int, step: int, n_shards: int, shard: int,
               global_batch: int):
    """Deterministic batch-index assignment: (seed, step) → sample ids.

    Returns the sample indices this shard must produce — pure function, so
    recovery/resharding never replays or skips data.
    """
    per = global_batch // n_shards
    base = (seed * 1_000_003 + step) * global_batch
    return [base + shard * per + i for i in range(per)]


@dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    patience: int = 3
    _strikes: dict = field(default_factory=dict)

    def observe(self, step_times: dict) -> list:
        """step_times: host → seconds for this step. Returns hosts to eject."""
        if not step_times:
            return []
        times = sorted(step_times.values())
        median = times[len(times) // 2]
        eject = []
        for host, t in step_times.items():
            if t > self.threshold * median:
                self._strikes[host] = self._strikes.get(host, 0) + 1
                if self._strikes[host] >= self.patience:
                    eject.append(host)
            else:
                self._strikes[host] = 0
        return eject


@dataclass
class ElasticController:
    """Controller loop state machine (simulation-friendly)."""
    n_devices: int
    model_degree: int
    pods: int = 1
    watchdog: StragglerWatchdog = field(default_factory=StragglerWatchdog)
    healthy: Optional[set] = None
    events: list = field(default_factory=list)

    def __post_init__(self):
        if self.healthy is None:
            self.healthy = set(range(self.n_devices))

    def fail(self, device_ids):
        self.healthy -= set(device_ids)
        self.events.append(("fail", tuple(device_ids), time.time()))

    def recover(self, device_ids):
        self.healthy |= set(device_ids)
        self.events.append(("recover", tuple(device_ids), time.time()))

    def current_plan(self):
        shape, used = plan_mesh(len(self.healthy), self.model_degree, self.pods)
        return {"mesh_shape": shape, "devices_used": used,
                "devices_idle": len(self.healthy) - used}
