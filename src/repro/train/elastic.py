"""Elastic scaling + straggler mitigation for 1000+ node runs.

Design (simulated here, since the container has one host):

* **Deterministic, index-based data**: every batch is a pure function of
  (seed, step, shard, n_shards) — `shard_plan`. Any surviving host can
  recompute any failed host's shard; there is no data-loader state to lose.
* **Mesh re-planning**: on node failure the controller computes the largest
  valid mesh from the healthy device count (`plan_mesh`), keeping the model
  axis intact (TP degree is a property of the checkpointed layout) and
  shrinking the data axis — then re-lowers the step and restores the latest
  checkpoint. Growth (nodes coming back) is the same path.
* **Straggler watchdog**: per-step heartbeats; a host slower than
  `threshold ×` the median for `patience` consecutive steps is treated as
  failed (eject + reshard) — slow nodes hurt a synchronous program exactly
  as much as dead ones.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.obs.events import EventSink, default_sink


def plan_mesh(n_healthy: int, model_degree: int, pods: int = 1):
    """Largest (pods, data, model) grid that fits the healthy devices.

    The model axis is fixed by the checkpoint layout; data shrinks to the
    largest whole multiple.
    """
    per_pod = n_healthy // pods
    data = per_pod // model_degree
    if data < 1:
        raise RuntimeError(
            f"cannot keep model_degree={model_degree} with {n_healthy} devices")
    used = pods * data * model_degree
    shape = (pods, data, model_degree) if pods > 1 else (data, model_degree)
    return shape, used


def shard_plan(seed: int, step: int, n_shards: int, shard: int,
               global_batch: int):
    """Deterministic batch-index assignment: (seed, step) → sample ids.

    Returns the sample indices this shard must produce — pure function, so
    recovery/resharding never replays or skips data.
    """
    per = global_batch // n_shards
    base = (seed * 1_000_003 + step) * global_batch
    return [base + shard * per + i for i in range(per)]


@dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    patience: int = 3
    _strikes: dict = field(default_factory=dict)

    def observe(self, step_times: dict) -> list:
        """step_times: host → seconds for this step. Returns hosts to eject."""
        if not step_times:
            return []
        times = sorted(step_times.values())
        median = times[len(times) // 2]
        eject = []
        for host, t in step_times.items():
            if t > self.threshold * median:
                self._strikes[host] = self._strikes.get(host, 0) + 1
                if self._strikes[host] >= self.patience:
                    eject.append(host)
            else:
                self._strikes[host] = 0
        return eject


@dataclass
class ElasticController:
    """Controller loop state machine (simulation-friendly).

    Fail/recover events route through the obs event sink and are stamped
    with a *monotonic* clock (`repro.obs.clock.monotonic`): recovery logic
    orders events by stamp, and wall-clock time can jump backwards under
    NTP skew mid-incident — exactly when these events fire. The local
    ``events`` list keeps the familiar ``(kind, ids, stamp)`` triples.
    """
    n_devices: int
    model_degree: int
    pods: int = 1
    watchdog: StragglerWatchdog = field(default_factory=StragglerWatchdog)
    healthy: Optional[set] = None
    events: list = field(default_factory=list)
    sink: Optional[EventSink] = None   # default: the process-wide obs sink

    def __post_init__(self):
        if self.healthy is None:
            self.healthy = set(range(self.n_devices))
        if self.sink is None:
            self.sink = default_sink()

    def fail(self, device_ids):
        self.healthy -= set(device_ids)
        ev = self.sink.emit("elastic_fail", devices=tuple(device_ids),
                            n_healthy=len(self.healthy))
        self.events.append(("fail", tuple(device_ids), ev.t_mono))

    def recover(self, device_ids):
        self.healthy |= set(device_ids)
        ev = self.sink.emit("elastic_recover", devices=tuple(device_ids),
                            n_healthy=len(self.healthy))
        self.events.append(("recover", tuple(device_ids), ev.t_mono))

    def current_plan(self):
        shape, used = plan_mesh(len(self.healthy), self.model_degree, self.pods)
        return {"mesh_shape": shape, "devices_used": used,
                "devices_idle": len(self.healthy) - used}
