"""Clique-structured k-MIPS index for factored marginal workloads.

`MarginalIVFIndex` is the IVF idea with the workload's own cliques as the
inverted cells: probing computes the per-clique marginal tables of ``v``
(`MarginalWorkload.marginal_tables` — segment sums, ``O(n_cliques · U)``
work, ``O(chunk · U)`` memory) and ranks cliques by their *exact* best
|cell| — the per-cell scores are already in hand, so the "centroid"
statistic is an exact upper bound rather than a geometric proxy. No
``(m, U)`` table, row gather, or k-means build exists anywhere on this
path, which is what lets it scale past the dense memory ceiling
(DESIGN.md §9).

Exactness: the global top-k by |score| lives inside the top-k cliques by
max |cell|, so with ``nprobe`` covering at least k candidate cells the
probe's top-k equals the exhaustive top-k (``approx_margin = 0``,
``failure_mass = 0`` — the statistic pass touches *every* clique). The
query also surfaces the full (m,) score vector (`has_full_scores`), so the
fused driver's tail scoring and overflow fallback are O(1) lookups into
the same tables.

Search paths are module-level jitted functions taking the workload pytree
as an argument — instances sharing shapes share one compiled program, the
repo's standing anti-retrace pattern.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workload import MarginalWorkload
from repro.kernels.ivf_probe.ref import marginal_probe_topk_ref


@partial(jax.jit, static_argnames=("k", "nprobe"))
def _marginal_query_scores(W, starts, v, k: int, nprobe: int):
    tabs = W.marginal_tables(v)
    aug, top_a, n_scored = marginal_probe_topk_ref(
        tabs, W.cl_cells, starts, W.m, k, nprobe)
    s_full = tabs[W.q_clique, W.q_offset]
    return aug, top_a, s_full, n_scored


class MarginalIVFIndex:
    """k-MIPS over a `MarginalWorkload` with cliques as inverted cells."""

    approx_margin = 0.0
    failure_mass = 0.0
    supports_in_graph = True
    supports_batch_probe = False
    has_full_scores = True

    def __init__(self, workload: MarginalWorkload,
                 nprobe: int | None = None):
        if not isinstance(workload, MarginalWorkload):
            raise TypeError(
                f"MarginalIVFIndex indexes MarginalWorkload, got "
                f"{type(workload).__name__}; dense workloads use the "
                "geometric families (flat/ivf/lsh)")
        self._w = workload
        self.m = workload.m
        self.dim = workload.U
        self.n = 2 * workload.m
        self.n_cliques = workload.n_cliques
        cells = np.asarray(workload.cl_cells)
        self._starts = jnp.asarray(
            np.concatenate([[0], np.cumsum(cells)[:-1]]).astype(np.int32))
        self._min_cells = int(cells.min())
        self.nprobe = min(self.n_cliques,
                          nprobe or max(4, math.ceil(
                              math.sqrt(self.n_cliques))))

    @property
    def workload(self) -> MarginalWorkload:
        return self._w

    def _nprobe_for(self, k: int) -> int:
        """Probed cliques for a top-k call: at least enough valid cells to
        cover k candidates (what makes the probe's top-k exact)."""
        need = math.ceil(k / max(self._min_cells, 1))
        return min(self.n_cliques, max(self.nprobe, need))

    def query(self, v, k: int):
        return self.query_in_graph(jnp.asarray(v, jnp.float32), k)

    def query_in_graph(self, v, k: int):
        aug, top_a, _, _ = _marginal_query_scores(
            self._w, self._starts, v, k, self._nprobe_for(k))
        return aug, top_a

    def query_in_graph_with_scores(self, v, k: int):
        """Probe + the full (m,) signed score vector the tables already
        hold — the fused driver's tail/fallback reuse path."""
        aug, top_a, s_full, _ = _marginal_query_scores(
            self._w, self._starts, v, k, self._nprobe_for(k))
        return aug, top_a, s_full

    def query_cost(self, k: int) -> int:
        """Candidate evaluations per query: the clique-statistic pass plus
        the probed cells."""
        return self.n_cliques + self._nprobe_for(k) * self._w.max_cells
