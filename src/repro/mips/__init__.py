"""k-MIPS index substrate (paper §3.3/§E/§H), TPU-adapted.

All indices share the protocol:
    ``index.query(v, k) -> (idx int32 (k,), raw_scores float32 (k,))``
with fixed-shape, jit-compiled search paths (padded cells / buckets /
fixed-degree adjacency) so retrieval is MXU-batched matmuls + top_k, not
pointer chasing — see DESIGN.md §3 for the hardware adaptation rationale.
"""

from repro.mips.base import MIPSIndex, augment_complement
from repro.mips.flat import FlatIndex, FlatAbsIndex
from repro.mips.ivf import IVFIndex, ShardedIVFIndex
from repro.mips.lsh import LSHIndex
from repro.mips.marginal import MarginalIVFIndex
from repro.mips.nsw import NSWIndex
from repro.mips.transform import (lp_dual_rows, lp_scalar_rows,
                                  mips_to_knn_keys, mips_to_knn_query)

INDEX_TYPES = {
    "flat": FlatIndex,
    "ivf": IVFIndex,
    "lsh": LSHIndex,
    "nsw": NSWIndex,
    "marginal_ivf": MarginalIVFIndex,
}


def build_index(kind: str, vectors, **kwargs) -> MIPSIndex:
    """Factory: build a k-MIPS index of the given kind over ``vectors``."""
    try:
        cls = INDEX_TYPES[kind]
    except KeyError:
        raise ValueError(f"unknown index kind {kind!r}; options {sorted(INDEX_TYPES)}")
    return cls(vectors, **kwargs)


__all__ = [
    "MIPSIndex",
    "augment_complement",
    "FlatIndex",
    "FlatAbsIndex",
    "IVFIndex",
    "ShardedIVFIndex",
    "LSHIndex",
    "MarginalIVFIndex",
    "NSWIndex",
    "lp_dual_rows",
    "lp_scalar_rows",
    "mips_to_knn_keys",
    "mips_to_knn_query",
    "build_index",
    "INDEX_TYPES",
]
