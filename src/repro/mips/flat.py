"""Flat (exact, linear-scan) MIPS index — the Θ(m) baseline.

On TPU this path is the `repro.kernels.mips_topk` Pallas kernel; on CPU the
jnp reference executes the same math. Exact ⇒ approx_margin = 0,
failure_mass = 0. Both indices are fully traceable (`supports_in_graph`),
so the fused MWEM driver inlines them into its scan body.

All search paths are module-level jitted functions: instances sharing
shapes share one compiled program (building a second index never
retraces — the per-tenant recompilation fix, see tests/test_mips.py).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workload import Workload, as_workload
from repro.faults import fault_site
from repro.mips.base import resolve_pallas


@partial(jax.jit, static_argnames=("k", "pallas"))
def _flat_query(vectors, q, k: int, pallas: bool):
    if pallas:
        from repro.kernels.mips_topk import ops as topk_ops

        return topk_ops.mips_topk(vectors, q, k)
    scores = vectors @ q
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_i.astype(jnp.int32), top_s


@partial(jax.jit, static_argnames=("k", "pallas"))
def _flat_abs_query(Qm, v, k: int, pallas: bool):
    if pallas:
        from repro.kernels.mips_topk import ops as topk_ops

        return topk_ops.mips_abs_topk(Qm, v, k)
    aug, top_a, _ = _flat_abs_query_scores(Qm, v, k)
    return aug, top_a


@partial(jax.jit, static_argnames=("k",))
def _flat_abs_query_scores(Qm, v, k: int):
    m = Qm.shape[0]
    s = Qm @ v
    a = jnp.abs(s)
    top_a, top_i = jax.lax.top_k(a, k)
    aug = jnp.where(s[top_i] >= 0, top_i, top_i + m)
    return aug.astype(jnp.int32), top_a, s


@partial(jax.jit, static_argnames=("k",))
def _flat_abs_workload_scores(W, v, k: int):
    """`_flat_abs_query_scores` over an implicit workload: ``W`` is a
    `core.workload.Workload` pytree, so factored families probe without a
    row table. For dense workloads `probe_scores` is the same ``Q @ v`` —
    and for factored ones within their parity block it is the same-shaped
    implicit-row matmul, keeping dense-vs-factored selections bitwise."""
    m = W.m
    s = W.probe_scores(v)
    a = jnp.abs(s)
    top_a, top_i = jax.lax.top_k(a, k)
    aug = jnp.where(s[top_i] >= 0, top_i, top_i + m)
    return aug.astype(jnp.int32), top_a, s


@partial(jax.jit, static_argnames=("k",))
def _flat_abs_query_batch(Qm, Vb, k: int):
    """Whole-wave exhaustive |·| probe: one (B × dim) @ (dim × m) MXU
    matmul reads Q once for every lane — already the amortization the
    batched IVF kernel buys, so no Pallas variant is needed here."""
    m = Qm.shape[0]
    s = Vb @ Qm.T                                       # (B, m)
    top_a, top_i = jax.lax.top_k(jnp.abs(s), k)
    aug = jnp.where(jnp.take_along_axis(s, top_i, axis=1) >= 0,
                    top_i, top_i + m)
    return aug.astype(jnp.int32), top_a


class FlatIndex:
    """Exact top-k by full matvec + top_k over arbitrary vectors."""

    approx_margin = 0.0
    failure_mass = 0.0
    supports_in_graph = True

    def __init__(self, vectors, use_pallas: str = "auto"):
        self._v = jnp.asarray(vectors, jnp.float32)
        self.n, self.dim = self._v.shape
        self._use_pallas = use_pallas

    def _resolve_pallas(self) -> bool:
        return resolve_pallas(self._use_pallas)

    def query(self, v, k: int):
        return self.query_in_graph(jnp.asarray(v, jnp.float32), k)

    def query_in_graph(self, v, k: int):
        fault_site("index.probe")
        return _flat_query(self._v, v, k, self._resolve_pallas())

    def query_cost(self, k: int) -> int:
        return self.n


class FlatAbsIndex:
    """Exact top-k of |⟨q_i, v⟩| without materializing the complement rows.

    Returns *augmented* ids (j < m ⇒ +⟨q_j, v⟩; j ≥ m ⇒ −⟨q_{j−m}, v⟩),
    matching the convention of `augment_complement`. On TPU the scan runs
    through the streaming `mips_abs_topk` kernel — one pass over Q merges
    both signs' candidates (half the HBM traffic of the old two-pass).
    """

    approx_margin = 0.0
    failure_mass = 0.0
    supports_in_graph = True

    def __init__(self, Q, use_pallas: str = "auto"):
        """``Q``: a raw (m, U) matrix or any `core.workload.Workload` —
        factored workloads probe through their implicit score primitives
        (no dense table is ever built; the Pallas row-streaming kernel,
        which needs explicit rows, is unavailable for them)."""
        self._w = as_workload(Q)
        self._q = self._w.Q if self._w.is_dense else None
        self.m, self.dim = self._w.m, self._w.U
        self.n = 2 * self.m
        self._use_pallas = use_pallas

    def _resolve_pallas(self) -> bool:
        if not self._w.is_dense:
            if self._use_pallas == "always":
                raise ValueError(
                    "use_pallas='always' needs a dense row table; factored "
                    "workloads probe via their implicit score path")
            return False
        return resolve_pallas(self._use_pallas)

    @property
    def supports_batch_probe(self) -> bool:
        return self._w.is_dense

    def query(self, v, k: int):
        return self.query_in_graph(jnp.asarray(v, jnp.float32), k)

    def query_in_graph(self, v, k: int):
        fault_site("index.probe")
        if not self._w.is_dense:
            aug, top_a, _ = _flat_abs_workload_scores(self._w, v, k)
            return aug, top_a
        return _flat_abs_query(self._q, v, k, self._resolve_pallas())

    def query_in_graph_batch(self, Vb, k: int):
        fault_site("index.probe")
        if not self._w.is_dense:
            aug, top_a, _ = jax.vmap(
                lambda q: _flat_abs_workload_scores(self._w, q, k))(Vb)
            return aug, top_a
        return _flat_abs_query_batch(self._q, Vb, k)

    @property
    def has_full_scores(self) -> bool:
        """The fused driver prefers `query_in_graph_with_scores` when the
        probe materializes the score vector anyway (the jnp path); the
        streaming Pallas kernel exists precisely to avoid that, so on TPU
        the plain probe + re-gather is the right trade."""
        return not self._resolve_pallas()

    def query_in_graph_with_scores(self, v, k: int):
        """Exhaustive probe that also returns the full (m,) signed score
        vector — the fused driver reuses it for tail scoring and the
        overflow fallback instead of re-touching Q (DESIGN.md §2)."""
        fault_site("index.probe")
        if not self._w.is_dense:
            return _flat_abs_workload_scores(self._w, v, k)
        return _flat_abs_query_scores(self._q, v, k)

    def query_cost(self, k: int) -> int:
        return self.m
