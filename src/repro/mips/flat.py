"""Flat (exact, linear-scan) MIPS index — the Θ(m) baseline.

On TPU this path is the `repro.kernels.mips_topk` Pallas kernel; on CPU the
jnp reference executes the same math. Exact ⇒ approx_margin = 0,
failure_mass = 0. Both indices are fully traceable (`supports_in_graph`),
so the fused MWEM driver inlines them into its scan body.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class FlatIndex:
    """Exact top-k by full matvec + top_k over arbitrary vectors."""

    approx_margin = 0.0
    failure_mass = 0.0
    supports_in_graph = True

    def __init__(self, vectors, use_pallas: str = "auto"):
        self._v = jnp.asarray(vectors, jnp.float32)
        self.n, self.dim = self._v.shape
        self._use_pallas = use_pallas

        @partial(jax.jit, static_argnames=("k",))
        def _query(vectors, q, k: int):
            if self._resolve_pallas():
                from repro.kernels.mips_topk import ops as topk_ops

                return topk_ops.mips_topk(vectors, q, k)
            scores = vectors @ q
            top_s, top_i = jax.lax.top_k(scores, k)
            return top_i.astype(jnp.int32), top_s

        self._query_fn = _query

    def _resolve_pallas(self) -> bool:
        if self._use_pallas == "always":
            return True
        if self._use_pallas == "never":
            return False
        return jax.default_backend() == "tpu"

    def query(self, v, k: int):
        return self._query_fn(self._v, jnp.asarray(v, jnp.float32), k)

    def query_in_graph(self, v, k: int):
        return self._query_fn(self._v, v, k)

    def query_cost(self, k: int) -> int:
        return self.n


class FlatAbsIndex:
    """Exact top-k of |⟨q_i, v⟩| without materializing the complement rows.

    Returns *augmented* ids (j < m ⇒ +⟨q_j, v⟩; j ≥ m ⇒ −⟨q_{j−m}, v⟩),
    matching the convention of `augment_complement`. On TPU the scan runs
    through the streaming `mips_abs_topk` kernel (two signed passes, merged).
    """

    approx_margin = 0.0
    failure_mass = 0.0
    supports_in_graph = True

    def __init__(self, Q, use_pallas: str = "auto"):
        self._q = jnp.asarray(Q, jnp.float32)
        self.m, self.dim = self._q.shape
        self.n = 2 * self.m
        self._use_pallas = use_pallas

        @partial(jax.jit, static_argnames=("k",))
        def _query(Qm, v, k: int):
            if self._resolve_pallas():
                from repro.kernels.mips_topk import ops as topk_ops

                return topk_ops.mips_abs_topk(Qm, v, k)
            aug, top_a, _ = _query_scores(Qm, v, k)
            return aug, top_a

        @partial(jax.jit, static_argnames=("k",))
        def _query_scores(Qm, v, k: int):
            s = Qm @ v
            a = jnp.abs(s)
            top_a, top_i = jax.lax.top_k(a, k)
            aug = jnp.where(s[top_i] >= 0, top_i, top_i + self.m)
            return aug.astype(jnp.int32), top_a, s

        self._query_fn = _query
        self._query_scores_fn = _query_scores

    def _resolve_pallas(self) -> bool:
        if self._use_pallas == "always":
            return True
        if self._use_pallas == "never":
            return False
        return jax.default_backend() == "tpu"

    def query(self, v, k: int):
        return self._query_fn(self._q, jnp.asarray(v, jnp.float32), k)

    def query_in_graph(self, v, k: int):
        return self._query_fn(self._q, v, k)

    @property
    def has_full_scores(self) -> bool:
        """The fused driver prefers `query_in_graph_with_scores` when the
        probe materializes the score vector anyway (the jnp path); the
        streaming Pallas kernel exists precisely to avoid that, so on TPU
        the plain probe + re-gather is the right trade."""
        return not self._resolve_pallas()

    def query_in_graph_with_scores(self, v, k: int):
        """Exhaustive probe that also returns the full (m,) signed score
        vector — the fused driver reuses it for tail scoring and the
        overflow fallback instead of re-touching Q (DESIGN.md §2)."""
        return self._query_scores_fn(self._q, v, k)

    def query_cost(self, k: int) -> int:
        return self.m
