"""Navigable-small-world graph k-MIPS index — the TPU adaptation of HNSW.

HNSW's hierarchy + pointer chasing saves *scalar* distance evaluations on a
CPU; on TPU the economics invert: batched gathers + one matmul per hop are
nearly free, irregular control flow is not. So (DESIGN.md §3):

* build: a kNN graph over the MIPS→kNN-transformed keys via vectorized
  NN-descent (neighbors-of-neighbors refinement, numpy, offline), with a
  reserved fraction of random long-range links for navigability — the role
  the HNSW upper layers play.
* search: fixed-width best-first *beam* search (`ef` frontier), each hop
  gathering `ef·deg` neighbor ids, scoring them in one (ef·deg × dim) @ v
  matvec, merging with `top_k`. A boolean visited mask replaces the hash
  set. `lax.while_loop` with fixed shapes; terminates when the beam stops
  improving.

Defaults mirror the paper's HNSW config (M=32, efSearch=64).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.mips.transform import mips_to_knn_keys


def _nn_descent(Vt: np.ndarray, deg: int, rounds: int, rng: np.random.Generator,
                block: int = 4096) -> np.ndarray:
    """Vectorized NN-descent: iteratively replace neighbors with better
    neighbors-of-neighbors (cosine/IP in the transformed space)."""
    n = Vt.shape[0]
    nbrs = rng.integers(0, n, size=(n, deg)).astype(np.int32)
    for _ in range(rounds):
        # candidates = own neighbors + neighbors of a pivot neighbor + random
        extra = rng.integers(0, n, size=(n, deg)).astype(np.int32)
        cand = np.concatenate([nbrs, nbrs[nbrs[:, 0]], extra], axis=1)
        new_nbrs = np.empty_like(nbrs)
        for i in range(0, n, block):
            cb = cand[i:i + block]                       # (b, ncand)
            sims = np.einsum("bd,bcd->bc", Vt[i:i + block], Vt[cb])
            rows = np.arange(cb.shape[0])[:, None]
            # mask self-loops and duplicates
            sims[cb == (np.arange(i, min(i + block, n))[:, None])] = -np.inf
            order = np.argsort(cb, axis=1)
            sorted_c = cb[rows, order]
            dup = np.concatenate([np.zeros((cb.shape[0], 1), bool),
                                  sorted_c[:, 1:] == sorted_c[:, :-1]], axis=1)
            back = np.argsort(order, axis=1)
            sims[dup[rows, back]] = -np.inf
            top = np.argpartition(-sims, deg - 1, axis=1)[:, :deg]
            new_nbrs[i:i + block] = cb[rows[:, :1], top]
        nbrs = new_nbrs
    return nbrs


@partial(jax.jit, static_argnames=("k", "max_steps"))
def _nsw_query(V, adj, seeds, q, k: int, max_steps: int):
    """Module-level jitted beam search: same-shaped NSWIndex instances
    share one compiled program (no per-instance retrace)."""
    n, ef = V.shape[0], seeds.shape[0]

    def dedupe_mask(ids):
        order = jnp.argsort(ids)
        s = ids[order]
        dup = jnp.concatenate([jnp.array([False]), s[1:] == s[:-1]])
        return ~dup[jnp.argsort(order)]

    beam_idx = seeds
    beam_scores = jnp.where(dedupe_mask(seeds), V[seeds] @ q, -jnp.inf)
    visited = jnp.zeros((n,), bool).at[seeds].set(True)

    def cond(state):
        _, _, _, steps, improved = state
        return improved & (steps < max_steps)

    def body(state):
        beam_idx, beam_scores, visited, steps, _ = state
        cand = adj[beam_idx].reshape(-1)              # (ef·deg,)
        fresh = ~visited[cand] & dedupe_mask(cand)
        cscores = jnp.where(fresh, V[cand] @ q, -jnp.inf)
        visited = visited.at[cand].set(True)
        all_idx = jnp.concatenate([beam_idx, cand])
        all_scores = jnp.concatenate([beam_scores, cscores])
        new_scores, pos = jax.lax.top_k(all_scores, ef)
        new_idx = all_idx[pos]
        improved = jnp.any(new_idx != beam_idx)
        return new_idx, new_scores, visited, steps + 1, improved

    state = (beam_idx, beam_scores, visited, jnp.int32(0), jnp.bool_(True))
    beam_idx, beam_scores, _, steps, _ = jax.lax.while_loop(cond, body, state)
    top_s, pos = jax.lax.top_k(beam_scores, min(k, ef))
    return beam_idx[pos].astype(jnp.int32), top_s


class NSWIndex:
    # The beam search is a fixed-shape `lax.while_loop` (fixed-fanout padded
    # adjacency, (n,) boolean visited mask), so it traces into the fused
    # scan like any other index — the loop's data-dependent *depth* is
    # bounded by `max_steps` and both drivers run the same jitted
    # `_nsw_query`, so host/fused selection parity is bitwise. Under vmap
    # the while_loop runs to the slowest lane's depth — the price of
    # batching a search with data-dependent work.
    supports_in_graph = True

    def __init__(self, vectors, deg: int = 32, ef: int = 64, rounds: int = 6,
                 rand_frac: float = 0.25, max_steps: int | None = None, seed: int = 0,
                 approx_margin: float = 0.0, failure_mass: float | None = None):
        V = np.asarray(vectors, np.float32)
        self.n, self.dim = V.shape
        Vt, _ = mips_to_knn_keys(V)
        Vt = Vt / np.maximum(np.linalg.norm(Vt, axis=1, keepdims=True), 1e-12)
        rng = np.random.default_rng(seed)
        deg = min(deg, max(self.n - 1, 1))
        n_rand = max(1, int(deg * rand_frac)) if self.n > deg + 1 else 0
        n_nn = deg - n_rand
        nn = _nn_descent(Vt, max(n_nn, 1), rounds, rng)[:, :n_nn]
        if n_rand:
            rnd = rng.integers(0, self.n, size=(self.n, n_rand)).astype(np.int32)
            adj = np.concatenate([nn, rnd], axis=1)
        else:
            adj = nn
        self.deg = adj.shape[1]
        self.ef = min(ef, self.n)
        self.max_steps = max_steps or (2 * int(math.ceil(math.log2(max(self.n, 2)))) + 8)
        seeds = rng.choice(self.n, size=self.ef, replace=self.n < self.ef)
        self._v = jnp.asarray(V)
        self._adj = jnp.asarray(adj)
        self._seeds = jnp.asarray(seeds.astype(np.int32))
        self.approx_margin = approx_margin
        self.failure_mass = (1.0 / self.n) if failure_mass is None else failure_mass

    def query(self, v, k: int):
        return _nsw_query(self._v, self._adj, self._seeds,
                          jnp.asarray(v, jnp.float32), k, self.max_steps)

    def query_in_graph(self, v, k: int):
        # same jitted search as `query` — inlined into the caller's trace
        return _nsw_query(self._v, self._adj, self._seeds,
                          jnp.asarray(v, jnp.float32), k, self.max_steps)

    def query_cost(self, k: int) -> int:
        # ~log-depth beam search: ef·deg scored rows per hop.
        return self.ef * self.deg * int(math.ceil(math.log2(max(self.n, 2))))
