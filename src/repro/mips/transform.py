"""MIPS → kNN reduction (paper §E).

Pad every key with ``sqrt(M² − ‖k‖²)`` so all keys share norm ``M``; pad the
query with 0. Inner products are preserved, so maximum inner product equals
minimum L2 / maximum cosine — the regime sign-LSH and NSW graphs navigate
well.
"""

from __future__ import annotations

import numpy as np


def mips_to_knn_keys(V: np.ndarray) -> tuple[np.ndarray, float]:
    V = np.asarray(V, np.float32)
    norms2 = (V * V).sum(axis=1)
    M2 = float(norms2.max())
    aug = np.sqrt(np.maximum(M2 - norms2, 0.0))[:, None]
    return np.concatenate([V, aug], axis=1), float(np.sqrt(M2))


def mips_to_knn_query(q: np.ndarray) -> np.ndarray:
    q = np.asarray(q, np.float32)
    return np.concatenate([q, np.zeros((1,), np.float32)])


def lp_scalar_rows(A, b) -> np.ndarray:
    """Concatenated rows ``[A_i, b_i] ∈ R^{d+1}`` the scalar-private LP
    solver's k-MIPS index is built over (§4.1): the violation score is the
    inner product ``Q_t(i) = ⟨[A_i, b_i], [x, −1]⟩``, and the solver builds
    the matching ``[x, −1]`` probe in-graph inside its fused scan."""
    A = np.asarray(A, np.float32)
    b = np.asarray(b, np.float32)
    return np.concatenate([A, b[:, None]], axis=1)


def lp_dual_rows(A, c, opt: float) -> np.ndarray:
    """Preprocessed dual-oracle vectors ``N_j = −(OPT/c_j)·A[:, j]`` as
    rows (d, m) — the constraint-private solver's index keys (§4.2): the
    oracle maximizes ``⟨y, N_j⟩`` over the dual distribution y."""
    A = np.asarray(A, np.float32)
    c = np.asarray(c, np.float32)
    return -(float(opt) / c)[:, None] * A.T
