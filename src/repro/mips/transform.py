"""MIPS → kNN reduction (paper §E).

Pad every key with ``sqrt(M² − ‖k‖²)`` so all keys share norm ``M``; pad the
query with 0. Inner products are preserved, so maximum inner product equals
minimum L2 / maximum cosine — the regime sign-LSH and NSW graphs navigate
well.
"""

from __future__ import annotations

import numpy as np


def mips_to_knn_keys(V: np.ndarray) -> tuple[np.ndarray, float]:
    V = np.asarray(V, np.float32)
    norms2 = (V * V).sum(axis=1)
    M2 = float(norms2.max())
    aug = np.sqrt(np.maximum(M2 - norms2, 0.0))[:, None]
    return np.concatenate([V, aug], axis=1), float(np.sqrt(M2))


def mips_to_knn_query(q: np.ndarray) -> np.ndarray:
    q = np.asarray(q, np.float32)
    return np.concatenate([q, np.zeros((1,), np.float32)])
