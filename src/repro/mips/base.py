"""Index protocol + complement augmentation (paper §3.4)."""

from __future__ import annotations

from typing import Protocol, Tuple, runtime_checkable

import jax
import numpy as np


def resolve_pallas(use_pallas: str) -> bool:
    """Shared `use_pallas` knob resolution for every index family:
    "always" | "never" | "auto" (TPU only — the automatic fallback where
    Pallas has no compiled backend)."""
    if use_pallas == "always":
        return True
    if use_pallas == "never":
        return False
    if use_pallas != "auto":
        raise ValueError(f"use_pallas must be auto|always|never, "
                         f"got {use_pallas!r}")
    return jax.default_backend() == "tpu"


@runtime_checkable
class MIPSIndex(Protocol):
    """k-MIPS index protocol.

    Attributes:
      approx_margin: the retrieval approximation constant ``c`` of Def. 3.4
        (0 for exact indices). Feeds the (ε+2c) accounting of Thm F.2 or the
        margin lowering of Alg. 6.
      failure_mass: γ — probability mass of the index answering incorrectly
        over a whole run (adds to δ per Thm 3.3).
      supports_in_graph: whether ``query_in_graph`` is traceable — fixed
        output shapes, no host syncs — so the fused MWEM driver can inline
        the search into its ``lax.scan`` body (DESIGN.md §2).
    """

    approx_margin: float
    failure_mass: float
    supports_in_graph: bool

    def query(self, v: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
        """Return (idx, scores): the (approximate) top-k inner products."""
        ...

    def query_in_graph(self, v: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
        """`query` accepting a traced probe, callable inside jit/scan/vmap.

        Indices that cannot be traced (``supports_in_graph=False``) raise
        NotImplementedError; the MWEM driver routes them to the host loop.
        """
        ...

    def query_cost(self, k: int) -> int:
        """Analytic count of candidate score evaluations per query."""
        ...


def augment_complement(Q: np.ndarray) -> np.ndarray:
    """Close a query set under complements: rows ``[Q; 1 − Q]`` (§3.4).

    For probe vectors with ``Σv = 0`` (histogram differences),
    ``⟨1−q, v⟩ = −⟨q, v⟩`` — so top-k over the augmented set retrieves the
    top absolute scores. Augmented id ``j`` ↦ query ``j % m``, sign
    ``+1 if j < m else −1``.
    """
    Q = np.asarray(Q, np.float32)
    return np.concatenate([Q, 1.0 - Q], axis=0)
