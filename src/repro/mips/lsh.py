"""Sign (SimHash) LSH k-MIPS index (Datar et al. 2004; paper §1.1).

Keys are lifted to constant norm through the MIPS→kNN transform (§E) so the
angular metric sign-LSH preserves matches inner-product order. Buckets are
padded (g × 2^b × cap) tables; a query hashes into one bucket per table,
gathers the union of candidates, and exactly reranks them — fixed shapes
throughout.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.mips.transform import mips_to_knn_keys, mips_to_knn_query


@partial(jax.jit, static_argnames=("k",))
def _lsh_query(V, planes, buckets, weights, q, k: int):
    """Module-level jitted search: same-shaped LSHIndex instances share one
    compiled program (no per-instance retrace)."""
    g = planes.shape[0]
    qt = jnp.concatenate([q, jnp.zeros((1,), q.dtype)])
    bits = jnp.einsum("d,gdb->gb", qt, planes) > 0
    codes = (bits.astype(jnp.int32) * weights[None, :]).sum(-1)   # (g,)
    cand = buckets[jnp.arange(g), codes].reshape(-1)              # (g·cap,)
    # Dedupe (an id can live in several tables' buckets).
    order = jnp.argsort(cand)
    sc = cand[order]
    dup = jnp.concatenate([jnp.array([False]), sc[1:] == sc[:-1]])
    dup = dup[jnp.argsort(order)]
    valid = (cand >= 0) & ~dup
    scores = V[jnp.clip(cand, 0)] @ q
    scores = jnp.where(valid, scores, -jnp.inf)
    top_s, pos = jax.lax.top_k(scores, k)
    return cand[pos].astype(jnp.int32), top_s


class LSHIndex:
    supports_in_graph = True  # padded buckets ⇒ fixed-shape, traceable search

    def __init__(self, vectors, n_tables: int = 8, n_bits: int | None = None,
                 cap_factor: float = 4.0, seed: int = 0,
                 approx_margin: float = 0.0, failure_mass: float | None = None):
        V = np.asarray(vectors, np.float32)
        self.n, self.dim = V.shape
        Vt, _ = mips_to_knn_keys(V)
        self.g = n_tables
        self.b = n_bits or max(4, int(math.ceil(math.log2(max(self.n, 16) / 16))))
        self.n_buckets = 1 << self.b
        self.cap = max(8, math.ceil(cap_factor * self.n / self.n_buckets))
        rng = np.random.default_rng(seed)
        planes = rng.standard_normal((self.g, Vt.shape[1], self.b)).astype(np.float32)
        flat_planes = planes.transpose(1, 0, 2).reshape(Vt.shape[1], self.g * self.b)
        codes = (Vt @ flat_planes).reshape(self.n, self.g, self.b) > 0
        weights = (1 << np.arange(self.b)).astype(np.int64)
        codes = (codes @ weights).astype(np.int32)            # (n, g)
        buckets = np.full((self.g, self.n_buckets, self.cap), -1, np.int32)
        fill = np.zeros((self.g, self.n_buckets), np.int32)
        self.dropped = 0
        for t in range(self.g):
            for i, code in enumerate(codes[:, t]):
                f = fill[t, code]
                if f < self.cap:
                    buckets[t, code, f] = i
                    fill[t, code] += 1
                else:
                    self.dropped += 1
        self._v = jnp.asarray(V)
        self._planes = jnp.asarray(planes)
        self._buckets = jnp.asarray(buckets)
        self._weights = jnp.asarray(weights.astype(np.int32))
        self.approx_margin = approx_margin
        self.failure_mass = (1.0 / self.n) if failure_mass is None else failure_mass

    def query(self, v, k: int):
        return _lsh_query(self._v, self._planes, self._buckets, self._weights,
                          jnp.asarray(v, jnp.float32), k)

    def query_in_graph(self, v, k: int):
        return _lsh_query(self._v, self._planes, self._buckets,
                          self._weights, v, k)

    def query_cost(self, k: int) -> int:
        return self.g * self.cap
