"""IVF (inverted file) k-MIPS index — TPU adaptation (paper §H).

FAISS-style IVF partitions the vectors into ``nlist`` Voronoi cells and
searches the ``nprobe`` closest cells. The TPU version keeps cells as a
*padded, capacity-bounded* (nlist × cap) id table. Two search paths share
that structure (DESIGN.md §3):

* **XLA** — gather → one dense (nprobe·cap × dim) @ v matvec → top_k:
  fixed shapes, MXU-batched, but the gathered candidate matrix round-trips
  HBM.
* **Pallas** (``use_pallas``) — the fused `repro.kernels.ivf_probe`
  kernel: centroid top-nprobe through the streaming `mips_topk` kernel,
  then only the probed cells' rows stream HBM→VMEM via scalar-prefetched
  cell ids; the candidate matrix never exists in HBM. Requires the rows
  duplicated in cell-grouped layout (``cell_rows``, built lazily on first
  kernel query — cap_factor× extra HBM, the price of contiguous streams).

``query_in_graph_batch`` serves a whole wave of probes per call
(`supports_batch_probe`); the kernel route dedups cells probed by several
lanes so shared cells are read from HBM once and scoring is one MXU
matmul per streamed tile.

Balanced assignment at build time bounds the padding waste. Defaults
follow the paper: nlist = max(2√n, 20), nprobe = min(nlist/4, 10).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults import fault_site
from repro.mips.base import resolve_pallas


def _vectors_from(vectors, context: str) -> np.ndarray:
    """Densify-fallback for the geometric IVF families (documented in
    DESIGN.md §9): k-means centroids and balanced cell assignment need
    explicit row coordinates, so a `core.workload.Workload` is materialized
    here — or refused past the densify limit. Callers that index the
    complement-augmented row space apply `augment_complement` themselves,
    exactly as with raw matrices. Factored workloads that must scale past
    the limit use `mips.marginal.MarginalIVFIndex`, whose cells are the
    workload's own cliques."""
    from repro.core.workload import Workload

    if isinstance(vectors, Workload):
        return vectors.require_dense(context)
    return np.asarray(vectors, np.float32)


def _kmeans(V: np.ndarray, nlist: int, iters: int, rng: np.random.Generator) -> np.ndarray:
    n = V.shape[0]
    cents = V[rng.choice(n, size=nlist, replace=False)].copy()
    sample = V if n <= 200_000 else V[rng.choice(n, size=200_000, replace=False)]
    s_norm2 = (sample * sample).sum(1)
    for _ in range(iters):
        # blockwise assignment: argmin ‖x−c‖² = argmin (‖c‖² − 2 x·c)
        c_norm2 = (cents * cents).sum(1)
        assign = np.empty(sample.shape[0], np.int32)
        bs = max(1, 2_000_000 // max(nlist, 1))
        for i in range(0, sample.shape[0], bs):
            d = c_norm2[None, :] - 2.0 * (sample[i:i + bs] @ cents.T)
            assign[i:i + bs] = np.argmin(d, axis=1)
        for c in range(nlist):
            members = sample[assign == c]
            if len(members):
                cents[c] = members.mean(0)
            else:  # re-seed empty cell
                cents[c] = sample[rng.integers(sample.shape[0])]
    return cents


def _balanced_assign(V: np.ndarray, cents: np.ndarray, cap: int) -> np.ndarray:
    """Greedy nearest-available-cell assignment, capacity ``cap`` per cell."""
    n, nlist = V.shape[0], cents.shape[0]
    c_norm2 = (cents * cents).sum(1)
    ncand = min(8, nlist)
    pref = np.empty((n, ncand), np.int32)
    best = np.empty(n, np.float32)
    bs = max(1, 2_000_000 // max(nlist, 1))
    for i in range(0, n, bs):
        d = c_norm2[None, :] - 2.0 * (V[i:i + bs] @ cents.T)
        p = np.argpartition(d, ncand - 1, axis=1)[:, :ncand]
        rows = np.arange(p.shape[0])[:, None]
        order = np.argsort(d[rows, p], axis=1)
        pref[i:i + bs] = p[rows, order]
        best[i:i + bs] = d[rows, p[rows, order]][:, 0]
    cells = np.full((nlist, cap), -1, np.int32)
    fill = np.zeros(nlist, np.int32)
    # Confident points (smallest best-distance) pick first.
    for idx in np.argsort(best):
        placed = False
        for c in pref[idx]:
            if fill[c] < cap:
                cells[c, fill[c]] = idx
                fill[c] += 1
                placed = True
                break
        if not placed:  # all preferred cells full → first cell with space
            c = int(np.argmin(fill))
            cells[c, fill[c]] = idx
            fill[c] += 1
    return cells


# Module-level jitted search paths: every IVFIndex instance with the same
# shapes/statics shares one compiled program (the per-instance closure the
# seed used retraced per tenant/index build).

def _query_impl(V, cents, cells, q, k: int, nprobe: int):
    cscores = cents @ q
    _, probe = jax.lax.top_k(cscores, nprobe)
    cand = cells[probe].reshape(-1)                    # (nprobe·cap,)
    valid = cand >= 0
    scores = V[jnp.clip(cand, 0)] @ q
    scores = jnp.where(valid, scores, -jnp.inf)
    top_s, pos = jax.lax.top_k(scores, k)
    return cand[pos].astype(jnp.int32), top_s


_query_xla = jax.jit(_query_impl, static_argnames=("k", "nprobe"))


@partial(jax.jit, static_argnames=("k", "nprobe"))
def _query_xla_batch(V, cents, cells, Vb, k: int, nprobe: int):
    return jax.vmap(
        lambda q: _query_impl(V, cents, cells, q, k, nprobe))(Vb)


class IVFIndex:
    supports_in_graph = True  # padded cells ⇒ fixed-shape, traceable search
    supports_batch_probe = True

    def __init__(self, vectors, nlist: int | None = None, nprobe: int | None = None,
                 cap_factor: float = 2.0, train_iters: int = 10, seed: int = 0,
                 approx_margin: float = 0.0, failure_mass: float | None = None,
                 use_pallas: str = "auto"):
        V = _vectors_from(vectors, "IVFIndex build")
        self.n, self.dim = V.shape
        self.nlist = min(nlist or max(int(2 * math.sqrt(self.n)), 20), self.n)
        self.nprobe = nprobe or max(1, min(self.nlist // 4, 10))
        self.cap = max(4, math.ceil(cap_factor * self.n / self.nlist))
        rng = np.random.default_rng(seed)
        cents = _kmeans(V, self.nlist, train_iters, rng)
        cells = _balanced_assign(V, cents, self.cap)
        self._v = jnp.asarray(V)
        self._cents = jnp.asarray(cents)
        self._cells = jnp.asarray(cells)
        self._use_pallas = use_pallas
        self._cell_rows = None  # the kernel route's cell-grouped row copy
        if resolve_pallas(use_pallas):
            self._rows_by_cell()
        self.approx_margin = approx_margin
        self.failure_mass = (1.0 / self.n) if failure_mass is None else failure_mass

    def _resolve_pallas(self) -> bool:
        return resolve_pallas(self._use_pallas)

    def _rows_by_cell(self) -> jax.Array:
        """(nlist, cap⌈8⌉, dim) rows in cell-grouped layout — the
        contiguous HBM blocks the kernel's scalar-prefetched index_map
        streams. The cap axis is pre-padded to the sublane multiple so the
        per-call `_pad_cell_blocks` in ops.py is a no-op on the hot path
        (no per-probe copy of the whole table). Usually built at __init__;
        the lazy rebuild (a flipped `use_pallas` knob) pins compile-time
        eval so a driver tracing through the index can never cache a
        tracer here."""
        if self._cell_rows is None:
            with jax.ensure_compile_time_eval():
                rows = (jnp.take(self._v, jnp.clip(self._cells, 0), axis=0)
                        * (self._cells >= 0)[..., None])
                pad = (-self.cap) % 8
                if pad:
                    rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0)))
                self._cell_rows = rows
        return self._cell_rows

    def query(self, v, k: int):
        return self.query_in_graph(jnp.asarray(v, jnp.float32), k)

    def query_in_graph(self, v, k: int):
        fault_site("index.probe")
        if self._resolve_pallas():
            from repro.kernels.ivf_probe import ivf_probe_topk

            idx, scores, _ = ivf_probe_topk(
                self._cents, self._rows_by_cell(), self._cells, v, k,
                self.nprobe)
            return idx, scores
        return _query_xla(self._v, self._cents, self._cells, v, k,
                          self.nprobe)

    def query_in_graph_batch(self, Vb, k: int):
        """Probe a whole wave (B, dim) in one call → ((B, k) ids, scores).

        The kernel route reads cells probed by several lanes once; the XLA
        route is the vmapped single probe (bitwise per-lane parity)."""
        fault_site("index.probe")
        if self._resolve_pallas():
            from repro.kernels.ivf_probe import ivf_probe_topk_batch

            idx, scores, _ = ivf_probe_topk_batch(
                self._cents, self._rows_by_cell(), self._cells, Vb, k,
                self.nprobe)
            return idx, scores
        return _query_xla_batch(self._v, self._cents, self._cells, Vb, k,
                                self.nprobe)

    def query_cost(self, k: int) -> int:
        return self.nlist + self.nprobe * self.cap


class ShardedIVFIndex:
    """Per-data-shard IVF structure for the sharded MWEM driver.

    The vector set is split row-wise into ``n_shards`` contiguous chunks —
    the exact layout `run_mwem_sharded` shards Q over the mesh's data axes —
    and an independent IVF (k-means centroids + balanced padded cell table)
    is built per chunk, offline in numpy. Cell ids are *local* row ids in
    ``[0, n_loc)``; shard ``s``'s global rows are ``s·n_loc + local``. The
    stacked ``cents (n_shards, nlist, dim)`` / ``cells (n_shards, nlist,
    cap)`` arrays device_put directly onto the mesh (centroid columns
    model-sharded, cell tables replicated over "model") — the structure is
    never gathered.

    Not a host-query index: searches only make sense inside the shard_map
    body (``supports_sharded``), where each shard probes its own cells and
    candidates meet at the all-gather. ``use_pallas`` routes that per-shard
    probe through the fused `kernels.ivf_probe` kernel when the mesh has no
    model sharding (the kernel fuses dot+top-k, so partial-dot psums can't
    interpose); the driver falls back to XLA automatically otherwise.
    """

    supports_in_graph = False
    supports_sharded = True

    def __init__(self, vectors, n_shards: int, nlist: int | None = None,
                 nprobe: int | None = None, cap_factor: float = 2.0,
                 train_iters: int = 10, seed: int = 0,
                 approx_margin: float = 0.0,
                 failure_mass: float | None = None,
                 use_pallas: str = "auto"):
        V = _vectors_from(vectors, "ShardedIVFIndex build")
        self.n, self.dim = V.shape
        if self.n % n_shards:
            raise ValueError(f"n={self.n} must divide over {n_shards} shards")
        self.n_shards = int(n_shards)
        self.n_loc = self.n // self.n_shards
        self.nlist = min(nlist or max(int(2 * math.sqrt(self.n_loc)), 8),
                         self.n_loc)
        self.nprobe = nprobe or max(1, min(self.nlist // 4, 10))
        self.cap = max(4, math.ceil(cap_factor * self.n_loc / self.nlist))
        rng = np.random.default_rng(seed)
        cents = np.empty((self.n_shards, self.nlist, self.dim), np.float32)
        cells = np.empty((self.n_shards, self.nlist, self.cap), np.int32)
        for s in range(self.n_shards):
            Vs = V[s * self.n_loc:(s + 1) * self.n_loc]
            cents[s] = _kmeans(Vs, self.nlist, train_iters, rng)
            cells[s] = _balanced_assign(Vs, cents[s], self.cap)
        self.cents = jnp.asarray(cents)
        self.cells = jnp.asarray(cells)
        self._use_pallas = use_pallas
        self.approx_margin = approx_margin
        self.failure_mass = (1.0 / self.n) if failure_mass is None else failure_mass

    def _resolve_pallas(self) -> bool:
        return resolve_pallas(self._use_pallas)

    def query_cost(self, k: int) -> int:
        """Scored rows per iteration across all shards (excluding the tail)."""
        return self.n_shards * (self.nlist + self.nprobe * self.cap)
