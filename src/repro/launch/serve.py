"""Serving launcher: batched decode over a smoke/full config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 8 --new-tokens 16
"""

from __future__ import annotations

import argparse
from repro.obs.clock import perf_counter

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.input_embeds:
        raise SystemExit("vlm arch serves after multimodal fusion — use a "
                         "text arch for this driver")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).tolist(),
                max_new_tokens=args.new_tokens,
                temperature=args.temperature)
        for _ in range(args.requests)
    ]
    engine = ServeEngine(model, params, batch_size=args.batch_size,
                         max_len=args.prompt_len + args.new_tokens + 4,
                         seed=args.seed)
    t0 = perf_counter()
    engine.run(requests)
    dt = perf_counter() - t0
    total = sum(len(r.out_tokens) for r in requests)
    print(f"served {len(requests)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s)")
    for i, r in enumerate(requests[:4]):
        print(f"req{i}: {r.out_tokens[:12]} …")


if __name__ == "__main__":
    main()
