"""Training launcher: end-to-end driver with checkpoint/restart.

Runs any registry arch (full or smoke config) on the local devices or the
production mesh, with the deterministic data pipeline (optionally the DP
MWEM-released pipeline), fault-tolerant checkpointing, and the straggler/
elasticity hooks from repro.train.elastic.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --smoke --steps 200 --batch 8 --seq 256 [--dp-data] \
        [--ckpt-dir /tmp/ckpt] [--resume]
"""

from __future__ import annotations

import argparse
from repro.obs.clock import perf_counter

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--dp-data", action="store_true",
                    help="train on the Fast-MWEM released histogram")
    ap.add_argument("--dp-eps", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import TrainConfig
    from repro.data.synthetic import SyntheticCorpus, batch_for_step
    from repro.models import build_model
    from repro.train.checkpoint import CheckpointManager
    from repro.train.trainer import make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 5),
                       microbatches=args.microbatches, seed=args.seed)
    opt_init, train_step = make_train_step(model, tcfg)
    train_step = jax.jit(train_step)

    key = jax.random.PRNGKey(args.seed)
    params, _ = model.init(key)
    opt_state = opt_init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq} steps={args.steps}")

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=args.seed)
    pipeline = None
    if args.dp_data:
        from repro.data.private import PrivateDataPipeline

        print("fitting Fast-MWEM DP release of the corpus statistics …")
        raw = np.asarray(batch_for_step(corpus, 0, 0, 1, 64, args.seq))
        pipeline = PrivateDataPipeline(vocab_size=cfg.vocab_size,
                                       eps=args.dp_eps, seed=args.seed)
        pipeline.fit(raw)
        eps, delta = pipeline.privacy_spent()
        print(f"DP pipeline ready: (ε={eps:.3f}, δ={delta:.2e})")

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        if args.resume:
            step, state = ckpt.restore_latest(
                {"params": params, "opt": opt_state})
            if step is not None:
                params, opt_state = state["params"], state["opt"]
                start_step = step
                print(f"resumed from step {step}")

    losses = []
    t0 = perf_counter()
    for step in range(start_step, args.steps):
        if pipeline is not None:
            tokens = pipeline.sample_batch(step, 0, args.batch, args.seq)
        else:
            tokens = batch_for_step(corpus, step, 0, 1, args.batch, args.seq)
        params, opt_state, metrics = train_step(params, opt_state,
                                                {"tokens": tokens})
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            rate = (step + 1 - start_step) * args.batch * args.seq \
                / (perf_counter() - t0)
            print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                  f"tok/s {rate:,.0f}")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state}, block=True)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
