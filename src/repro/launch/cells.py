"""Dry-run cell construction: (arch × shape × mesh) → jittable step + specs.

A *cell* is one entry of the assignment matrix: the train / prefill /
decode step of one architecture at one input shape, with every argument an
allocation-free ShapeDtypeStruct carrying its NamedSharding. `build_cell`
returns everything `dryrun.py` needs to `.lower().compile()` it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.configs.base import ModelConfig, ShapeConfig, ShardingRules, TrainConfig
from repro.models import build_model
from repro.models.common import sharding_ctx
from repro.train.trainer import make_train_step
from repro.launch.mesh import batch_axes

# ---------------------------------------------------------------- rules ----
MODEL_DEGREE = 16  # fixed model-axis size of the production meshes


def _ssm_tp_ok(cfg: ModelConfig) -> bool:
    """The fused in_proj output (z|x|B|C|dt) must split evenly for SSM TP."""
    d_inner = cfg.ssm_expand * cfg.d_model
    width = 2 * d_inner + 2 * cfg.ssm_state + d_inner // cfg.ssm_headdim
    return width % MODEL_DEGREE == 0


def rules_for(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool) -> ShardingRules:
    """Per-arch rules: every sharded axis must divide the mesh axis (jit
    input shardings require exact tiling; replication is the fallback)."""
    batch = ("pod", "data") if multi_pod else "data"
    div = lambda n: (n % MODEL_DEGREE == 0)
    rnn_ok = True
    if cfg.ssm_state and not _ssm_tp_ok(cfg):
        rnn_ok = False
    if cfg.rglru_width and not div(cfg.rglru_width):
        rnn_ok = False
    kw = dict(
        batch=batch,
        embed="data" if div(cfg.d_model) else None,   # FSDP over data
        mlp="model" if div(cfg.d_ff or MODEL_DEGREE) else None,
        q_heads="model" if div(cfg.n_heads) else None,
        kv_heads="model" if div(cfg.n_kv_heads) else None,
        vocab="model",          # padded_vocab is a multiple of 512
        experts="model" if div(cfg.n_experts or MODEL_DEGREE) else None,
        rnn="model" if rnn_ok else None,
        expert_mlp=None,
    )
    if shape.kind == "decode":
        # kv heads never divide the 16-way model axis on the assigned archs
        # → shard the cache *sequence* over "model" (flash-decoding style:
        # the partitioner turns the softmax into partial-merge collectives).
        if kw["kv_heads"] is None:
            kw["kv_seq"] = "model"
        if shape.global_batch < 16:
            # long-context decode: batch can't fill the batch axes — shard
            # the cache sequence over data (and model) instead.
            kw["batch"] = None
            kw["kv_seq"] = ("data", "model") if kw["kv_heads"] is None \
                else "data"
    # weight sharding over "data" stays on for inference too (ZeRO-style):
    # a 340B bf16 model is 42.5 GB/chip under TP-16 alone — it only fits
    # with the data axis sharding weights as well (per-layer all-gathers).
    return ShardingRules(**kw)


TRAIN_CFGS = {
    "mamba2-130m": TrainConfig(microbatches=2),
    "llama3.2-3b": TrainConfig(microbatches=4),
    "minitron-8b": TrainConfig(microbatches=8),
    "llama3-8b": TrainConfig(microbatches=8),
    "qwen3-moe-30b-a3b": TrainConfig(microbatches=4),
    "llama4-scout-17b-a16e": TrainConfig(microbatches=8),
    "recurrentgemma-2b": TrainConfig(microbatches=4),
    "qwen2-vl-72b": TrainConfig(microbatches=16),
    # 340B: adafactor states + full remat — saving the (B,S,18432) f32
    # sublayer outputs (save_tp) costs more HBM than their psums save
    # (measured: EXPERIMENTS.md §Perf iteration N4). microbatches=16 is the
    # ceiling (1 sequence / data shard / microbatch).
    "nemotron-4-340b": TrainConfig(microbatches=16, optimizer="adafactor",
                                   remat="full"),
    "whisper-large-v3": TrainConfig(microbatches=4),
}


# ------------------------------------------------------------- shardings ---
def to_shardings(spec_tree, rules: ShardingRules, mesh):
    def conv(logical):
        return NamedSharding(mesh, rules.spec(*logical))
    return jax.tree.map(conv, spec_tree, is_leaf=lambda x: isinstance(x, tuple))


def attach(sds_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, sharding_tree)


def opt_spec_tree(opt_state_abs, param_specs, optimizer: str):
    """Logical specs for the optimizer state, mirroring param layout."""
    if optimizer == "adam":
        return {"mu": param_specs, "nu": param_specs, "step": ()}
    # adafactor: factored stats drop one dim
    def factored(spec):
        spec = tuple(spec)
        return {"vr": spec[:-1], "vc": spec[:-2] + spec[-1:]} if len(spec) >= 2 \
            else {"v": spec}
    v = jax.tree.map(factored, param_specs,
                     is_leaf=lambda x: isinstance(x, tuple))
    return {"v": v, "step": ()}


def cache_spec_tree(cache_abs):
    """Logical specs for a decode cache, keyed by leaf path names."""
    flat = jax.tree_util.tree_flatten_with_path(cache_abs)
    specs = []
    for path, leaf in flat[0]:
        name = str(getattr(path[-1], "key", path[-1]))
        rank = len(leaf.shape)
        if name in ("k", "v"):
            spec = ("layers", "batch", "kv_heads", "kv_seq", None)[:rank]
            if rank == 5:
                spec = ("layers", "batch", "kv_heads", "kv_seq", None)
        elif name in ("xk", "xv"):
            spec = ("layers", "batch", "kv_heads", None, None)
        elif name == "conv":
            spec = ("layers", "batch", None, "rnn")
        elif name == "state":
            spec = ("layers", "batch", "rnn", None, None)
        elif name == "h":
            spec = ("layers", "batch", "rnn")
        else:
            spec = (None,) * rank
        assert len(spec) == rank, (name, rank, spec)
        specs.append(tuple(spec))
    return jax.tree_util.tree_unflatten(flat[1], specs)


# ----------------------------------------------------------- input specs ---
def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                rules: ShardingRules):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    bspec = NamedSharding(mesh, rules.spec("batch", None))
    b3 = NamedSharding(mesh, rules.spec(None, "batch", None))
    bde = NamedSharding(mesh, rules.spec("batch", None, None))
    rep = NamedSharding(mesh, P())
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if shape.kind == "decode":
        if cfg.input_embeds:
            tok = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dtype, sharding=bde)
        else:
            tok = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=bspec)
        pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
        return {"tokens": tok, "pos": pos}

    batch = {}
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_len, cfg.d_model), dtype, sharding=bde)
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bspec)
    elif cfg.input_embeds:
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype,
                                               sharding=bde)
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bspec)
        # (B, 3, S) so the microbatch split sees the batch dim first
        batch["positions"] = jax.ShapeDtypeStruct((B, 3, S), jnp.int32,
                                                  sharding=bde)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bspec)
    return batch


# ----------------------------------------------------------------- cells ---
@dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    fn: object            # jittable step function
    args: tuple           # SDS pytrees
    meta: dict


def count_params(params_abs, cfg: ModelConfig):
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abs)[0]:
        keys = [str(getattr(p, "key", p)) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "mlp" in keys and any(k in ("w_gate", "w_up", "w_down")
                                 for k in keys) and cfg.n_experts:
            if leaf.shape and len(leaf.shape) >= 3:
                expert += n
    active = total - expert
    if cfg.n_experts:
        active += int(expert * cfg.moe_top_k / cfg.n_experts)
    return total, active


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool) -> Cell:
    cfg = get_config(arch).with_(vocab_pad_multiple=512)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        raise ValueError(f"{arch} is pure full-attention; long_500k skipped "
                         "(see DESIGN.md §5)")
    rules = rules_for(cfg, shape, multi_pod)
    model = build_model(cfg)
    params_abs, specs = model.init(abstract=True)
    params_sh = to_shardings(specs, rules, mesh)
    params_in = attach(params_abs, params_sh)
    n_params, n_active = count_params(params_abs, cfg)
    meta = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "n_params": n_params, "n_active_params": n_active,
            "tokens_per_step": shape.global_batch *
            (1 if shape.kind == "decode" else shape.seq_len),
            "kind": shape.kind}

    if shape.kind == "train":
        tcfg = TRAIN_CFGS[arch]
        meta["microbatches"] = tcfg.microbatches
        meta["optimizer"] = tcfg.optimizer
        opt_init, train_step = make_train_step(model, tcfg, param_specs=specs)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        opt_sh = to_shardings(
            opt_spec_tree(opt_abs, specs, tcfg.optimizer), rules, mesh)
        opt_in = attach(opt_abs, opt_sh)
        batch = input_specs(cfg, shape, mesh, rules)

        def fn(params, opt_state, b):
            with sharding_ctx(mesh, rules):
                return train_step(params, opt_state, b)

        return Cell(arch, shape, fn, (params_in, opt_in, batch), meta)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape, mesh, rules)

        def fn(params, b):
            with sharding_ctx(mesh, rules):
                return model.prefill(params, b)

        return Cell(arch, shape, fn, (params_in, batch), meta)

    # decode
    cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                 abstract=True)
    cache_sh = to_shardings(cache_spec_tree(cache_abs), rules, mesh)
    cache_in = attach(cache_abs, cache_sh)
    io = input_specs(cfg, shape, mesh, rules)

    def fn(params, cache, tokens, pos):
        with sharding_ctx(mesh, rules):
            return model.decode_step(params, cache, tokens, pos)

    return Cell(arch, shape, fn, (params_in, cache_in, io["tokens"], io["pos"]),
                meta)


def all_cells():
    """The assignment matrix (plus documented skips)."""
    from repro.configs import ARCH_NAMES

    cells, skips = [], []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            if shape_name == "long_500k" and not cfg.subquadratic:
                skips.append((arch, shape_name,
                              "pure full-attention stack (DESIGN.md §5)"))
                continue
            cells.append((arch, shape_name))
    return cells, skips
