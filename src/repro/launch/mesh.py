"""Mesh construction (assignment §MULTI-POD DRY-RUN + the sharded driver).

Functions — not module-level constants — so importing this module never
touches JAX device state.

``make_mesh_compat`` papers over the ``jax.sharding.AxisType`` API churn:
newer JAX wants explicit axis types on ``jax.make_mesh`` while older
releases raise ``AttributeError`` on the mere mention of the enum. Every
mesh in the repo (production dry-run, tests, the sharded MWEM driver) goes
through it so a JAX upgrade is a one-line change.
"""

from __future__ import annotations

import math

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types when the installed JAX has
    them, plain positional form otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_driver_mesh(n_devices: int | None = None, *, model_degree: int = 1):
    """A ("data", "model") mesh over the available devices for the sharded
    MWEM driver: all parallelism on "data" (query rows) by default, with an
    optional model degree for domain-sharded log-weights."""
    if n_devices is None:
        n_devices = jax.device_count()
    if n_devices % model_degree:
        raise ValueError(f"model_degree {model_degree} does not divide "
                         f"device count {n_devices}")
    return make_mesh_compat((n_devices // model_degree, model_degree),
                            ("data", "model"))


def batch_axes(multi_pod: bool):
    """Mesh axes the global batch shards over."""
    return ("pod", "data") if multi_pod else ("data",)
