"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

A function — not a module-level constant — so importing this module never
touches JAX device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(multi_pod: bool):
    """Mesh axes the global batch shards over."""
    return ("pod", "data") if multi_pod else ("data",)
