import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent (the SPMD
partitioner accepts it at 256 and 512 chips), records
``memory_analysis()`` (fits-in-HBM evidence) and ``cost_analysis()``, and
runs the trip-count-aware HLO analysis that feeds §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --paper-cell  # Fast-MWEM

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json``; existing files are
skipped unless --force.
"""

import argparse
import json
from repro.obs.clock import perf_counter
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False) -> dict:
    import jax

    from repro.analysis.hlo import analyze_hlo
    from repro.analysis.roofline import V5E, model_flops, roofline_terms
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    t0 = perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = build_cell(arch, shape_name, mesh, multi_pod)

    with mesh:
        lowered = jax.jit(cell.fn).lower(*cell.args)
        t_lower = perf_counter() - t0
        compiled = lowered.compile()
        t_compile = perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = analyze_hlo(compiled.as_text())

    tokens = cell.meta["tokens_per_step"]
    mf = model_flops(cell.meta["n_params"], tokens,
                     cell.meta["n_active_params"],
                     kind="train" if cell.meta["kind"] == "train" else "infer")
    flops_dev = hlo.flops
    terms = roofline_terms(flops_dev, hlo.bytes_hbm, hlo.collective_bytes)

    record = {
        **cell.meta,
        "mesh": mesh_tag,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "peak_estimate_per_dev": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes,
            "hbm_capacity": V5E.hbm_bytes,
            "fits": (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
            < V5E.hbm_bytes,
        },
        "xla_cost_analysis": {
            "flops_body_once": ca.get("flops", 0.0),
            "bytes_body_once": ca.get("bytes accessed", 0.0),
        },
        "hlo_analysis": {
            "flops_per_dev": hlo.flops,
            "hbm_bytes_per_dev": hlo.bytes_hbm,
            "collective_bytes_per_dev": hlo.collective_bytes,
            "collective_breakdown": hlo.collective_breakdown,
            "n_collectives": hlo.n_collectives,
            "while_trip_counts": hlo.while_trip_counts,
        },
        "model_flops_global": mf,
        "model_flops_per_dev": mf / n_chips,
        "useful_flop_fraction": (mf / n_chips) / hlo.flops if hlo.flops else 0.0,
        "roofline": terms,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def run_paper_cell(multi_pod: bool, out_dir: str, force: bool = False,
                   mode: str = "lazy", scan_steps: int = 1) -> dict:
    """Distributed Fast-MWEM cell — the paper-representative lowering.

    The cell is `make_mwem_scan`, i.e. the *same* shard-mapped scan
    `run_mwem_sharded` dispatches (specs cannot drift from execution).
    ``mode="exhaustive"`` lowers the Θ(m) baseline; ``"lazy"`` the paper's
    Θ(√m) LazyEM — the pair is the §Perf comparison. ``scan_steps`` is the
    scan's T (1 keeps the recorded numbers per-iteration comparable).
    """
    import jax

    from repro.analysis.hlo import analyze_hlo
    from repro.analysis.roofline import roofline_terms
    from repro.core.distributed import build_distributed_mwem_cell
    from repro.launch.mesh import make_production_mesh

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    # T is part of the cell identity: a T=8 scan must not alias (or be
    # served from) the per-iteration T=1 record
    cell_tag = "iteration" if scan_steps == 1 else f"scan{scan_steps}"
    out_path = os.path.join(out_dir,
                            f"fastmwem-dist-{mode}__{cell_tag}__{mesh_tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    t0 = perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, meta = build_distributed_mwem_cell(mesh, multi_pod, mode=mode,
                                                 T=scan_steps)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = analyze_hlo(compiled.as_text())
    record = {
        **meta,
        "mesh": mesh_tag,
        "compile_s": round(perf_counter() - t0, 2),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
        },
        "hlo_analysis": {
            "flops_per_dev": hlo.flops,
            "hbm_bytes_per_dev": hlo.bytes_hbm,
            "collective_bytes_per_dev": hlo.collective_bytes,
            "collective_breakdown": hlo.collective_breakdown,
        },
        "roofline": roofline_terms(hlo.flops, hlo.bytes_hbm,
                                   hlo.collective_bytes),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper-cell", action="store_true")
    ap.add_argument("--scan-steps", type=int, default=1,
                    help="T of the paper cell's fused scan (per-iteration "
                         "numbers at 1)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    if args.paper_cell:
        for mp in meshes:
            for mode in ("exhaustive", "lazy"):
                rec = run_paper_cell(mp, args.out, args.force, mode=mode,
                                     scan_steps=args.scan_steps)
                r = rec["roofline"]
                print(f"fastmwem-dist[{mode}] × "
                      f"{'2x16x16' if mp else '16x16'}: "
                      f"compute={r['compute_s']:.3e}s "
                      f"memory={r['memory_s']:.3e}s "
                      f"collective={r['collective_s']:.3e}s")
        return

    if args.all:
        from repro.launch.cells import all_cells

        cells, skips = all_cells()
        for arch, shape, why in skips:
            print(f"SKIP {arch} × {shape}: {why}")
        ok = fail = 0
        for arch, shape in cells:
            for mp in meshes:
                tag = "2x16x16" if mp else "16x16"
                try:
                    rec = run_cell(arch, shape, mp, args.out, args.force)
                    r = rec["roofline"]
                    print(f"OK   {arch} × {shape} × {tag}: "
                          f"bottleneck={r['bottleneck']} "
                          f"bound={r['step_lower_bound_s']:.4f}s "
                          f"fit={rec['memory']['fits']} "
                          f"compile={rec.get('compile_s', 0)}s")
                    ok += 1
                except Exception as e:
                    print(f"FAIL {arch} × {shape} × {tag}: {e}")
                    traceback.print_exc()
                    fail += 1
        print(f"\n{ok} cells passed, {fail} failed")
        return

    for mp in meshes:
        rec = run_cell(args.arch, args.shape, mp, args.out, args.force)
        print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
