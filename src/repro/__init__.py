"""Fast-MWEM: private data release in sublinear time — a production JAX framework.

Layers:
  repro.core      — the paper's contribution (MWEM, LazyEM, private LP solvers)
  repro.mips      — k-MIPS index substrate (flat / IVF / LSH / NSW)
  repro.kernels   — Pallas TPU kernels for the compute hot-spots
  repro.models    — the assigned LM architecture zoo
  repro.data      — data pipeline incl. DP synthetic-data release
  repro.train     — optimizer / trainer / checkpoint / elastic runtime
  repro.serve     — KV-cache serving engine
  repro.launch    — mesh + dry-run + train/serve launchers
  repro.analysis  — HLO cost parsing + roofline model
  repro.configs   — architecture configs
"""

__version__ = "0.1.0"
