"""Mechanism telemetry: host-side aggregation of the drivers' scan traces.

Every driver (host / fused / waved / sharded MWEM, both LP solvers)
already returns per-iteration traces — `n_scored`, `overflow`,
selection ids — stacked on device and transferred once. This module
turns that free data into the numbers the paper's claim is about:

* `overflow_rate` — fraction of iterations that fell back from the
  lazy Θ(√m)-expected path to the exhaustive Θ(m) Gumbel-max;
* `n_scored_mean/max/total` — actual scored-rows cost per iteration;
* `lazy_fraction` — fraction of iterations resolved without scoring
  the full candidate set;
* `sqrt_m_ratio` — mean scored rows ÷ √m: ~O(1) when the sublinear
  claim holds, → √m when every iteration degenerates to exhaustive.

`aggregate_traces` is pure (no registry side effects, always runs, so
the `telemetry` record on results exists even with obs disabled —
it's part of the result, like `n_scored` itself). `publish` pushes a
record into the registry and is gated on `trace.enabled()`.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional

import numpy as np

from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry, default_registry


@dataclass(frozen=True)
class MechanismTelemetry:
    """Structured per-run (or per-batch) mechanism statistics."""

    workload: str  # "mwem" | "lp_scalar" | "lp_dual"
    driver: str  # "host" | "fused" | "waved" | "sharded"
    mode: str  # "exact" | "fast"
    m: int  # candidate-set size the mechanism scores over
    T: int  # iterations per lane
    lanes: int  # batch lanes aggregated into this record
    n_scored_total: int
    n_scored_mean: float
    n_scored_max: int
    overflow_count: int
    overflow_rate: float  # overflows / (T * lanes)
    lazy_fraction: float  # iterations that scored < m rows
    sqrt_m_ratio: float  # n_scored_mean / sqrt(m)
    total_seconds: float
    amortized: bool  # True when total_seconds covers >1 lane / whole scan

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def aggregate_traces(
    *,
    workload: str,
    driver: str,
    mode: str,
    m: int,
    n_scored,
    overflow_count: int,
    total_seconds: float,
    amortized: bool,
    lanes: int = 1,
) -> MechanismTelemetry:
    """Fold stacked per-iteration traces into one `MechanismTelemetry`.

    `n_scored` accepts anything array-like — a host list (T,), a stacked
    device trace (T,), or a batched one (B, T); it is flattened, so pass
    `lanes` explicitly for batches.
    """
    ns = np.asarray(n_scored, dtype=np.int64).reshape(-1)
    iters = int(ns.size)
    total = int(ns.sum()) if iters else 0
    mean = float(ns.mean()) if iters else 0.0
    lazy = float((ns < int(m)).mean()) if iters and m > 0 else 0.0
    return MechanismTelemetry(
        workload=workload,
        driver=driver,
        mode=mode,
        m=int(m),
        T=iters // max(lanes, 1),
        lanes=int(lanes),
        n_scored_total=total,
        n_scored_mean=mean,
        n_scored_max=int(ns.max()) if iters else 0,
        overflow_count=int(overflow_count),
        overflow_rate=float(overflow_count) / iters if iters else 0.0,
        lazy_fraction=lazy,
        sqrt_m_ratio=mean / math.sqrt(m) if m > 0 else 0.0,
        total_seconds=float(total_seconds),
        amortized=bool(amortized),
    )


def publish(
    tel: MechanismTelemetry, registry: Optional[MetricsRegistry] = None
) -> MechanismTelemetry:
    """Push one telemetry record into the registry (no-op when obs is off)."""
    if not _trace.enabled():
        return tel
    reg = registry if registry is not None else default_registry()
    labels = dict(workload=tel.workload, driver=tel.driver, mode=tel.mode)
    reg.counter("mechanism_runs_total", **labels).inc(tel.lanes)
    reg.counter("mechanism_iterations_total", **labels).inc(tel.T * tel.lanes)
    reg.counter("mechanism_overflow_total", **labels).inc(tel.overflow_count)
    reg.counter("mechanism_scored_rows_total", **labels).inc(tel.n_scored_total)
    reg.gauge("mechanism_overflow_rate", **labels).set(tel.overflow_rate)
    reg.gauge("mechanism_lazy_fraction", **labels).set(tel.lazy_fraction)
    reg.gauge("mechanism_sqrt_m_ratio", **labels).set(tel.sqrt_m_ratio)
    reg.histogram("mechanism_scored_rows_per_iter", **labels).observe(
        tel.n_scored_mean
    )
    reg.histogram("mechanism_run_seconds", **labels).observe(tel.total_seconds)
    return tel


def record_run(
    *,
    workload: str,
    driver: str,
    mode: str,
    m: int,
    n_scored,
    overflow_count: int,
    total_seconds: float,
    amortized: bool,
    lanes: int = 1,
    registry: Optional[MetricsRegistry] = None,
) -> MechanismTelemetry:
    """aggregate_traces + publish in one call — the driver-side entry point."""
    tel = aggregate_traces(
        workload=workload,
        driver=driver,
        mode=mode,
        m=m,
        n_scored=n_scored,
        overflow_count=overflow_count,
        total_seconds=total_seconds,
        amortized=amortized,
        lanes=lanes,
    )
    return publish(tel, registry=registry)
