"""Monotonic-stamped event sink for discrete occurrences.

Elastic fail/recover, admission rejections, cache flushes — anything
that happens *at a moment* rather than *over a duration* goes through
an `EventSink`. Stamps come from `clock.monotonic()` so ordering
survives wall-clock (NTP) skew; each emit also bumps a per-kind counter
in the registry when obs is enabled, so event rates show up in the same
snapshot as everything else.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.obs import clock
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry, default_registry


@dataclass(frozen=True)
class ObsEvent:
    kind: str
    t_mono: float  # monotonic stamp — order-comparable, not wall time
    attrs: Tuple[Tuple[str, object], ...]

    def attr(self, key: str, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default


class EventSink:
    """Append-only in-process event log + per-kind rate counters."""

    def __init__(
        self, registry: Optional[MetricsRegistry] = None, name: str = "events"
    ) -> None:
        self.events: List[ObsEvent] = []
        self._registry = registry
        self._name = name

    def emit(self, kind: str, **attrs) -> ObsEvent:
        ev = ObsEvent(
            kind=kind,
            t_mono=clock.monotonic(),
            attrs=tuple(sorted(attrs.items())),
        )
        self.events.append(ev)
        if _trace.enabled():
            reg = self._registry if self._registry is not None else default_registry()
            reg.counter(f"{self._name}_total", kind=kind).inc()
        return ev

    def __len__(self) -> int:
        return len(self.events)


_default_sink = EventSink()


def default_sink() -> EventSink:
    return _default_sink
