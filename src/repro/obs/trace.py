"""The `obs.enabled` switch and profiler annotation wrappers.

Two annotation flavors, matching where the code runs:

* `scope(name)` — **in-graph**: `jax.named_scope`, legal inside jitted
  functions / scan bodies. Attaches the name to the emitted HLO ops so
  XLA profiler timelines line up with logical phases (kernel call sites
  in `kernels/*/ops.py`). Pure metadata: cannot change numerics.
* `annotate(name)` — **host-side**: `jax.named_scope` *plus*
  `jax.profiler.TraceAnnotation`, for driver dispatch and wave
  execution on the host. TraceAnnotation shows up on the host timeline
  when a profiler session is active and is a no-op otherwise.

Both collapse to `nullcontext()` when obs is disabled. Neither path
touches the key chain or any traced value, so enabled-vs-disabled
results are bitwise identical (asserted in tests/test_obs.py).

jit-cache caveat: `enabled()` is read at *trace* time, so flipping the
switch after a shape is compiled will not re-trace — the cached
executable keeps (or keeps lacking) its scope names. Harmless: names
are metadata, and the bitwise-parity contract holds either way.
"""

from __future__ import annotations

import contextlib
from typing import ContextManager

import jax

try:  # host-side profiler annotation; absent on some minimal builds
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except ImportError:  # pragma: no cover - jax always ships it in CI
    _TraceAnnotation = None

_enabled = True


def enabled() -> bool:
    return _enabled


def set_enabled(flag: bool) -> None:
    global _enabled
    _enabled = bool(flag)


@contextlib.contextmanager
def disabled():
    """Temporarily switch obs off (parity tests; silent bench lanes)."""
    prev = _enabled
    set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)


def scope(name: str) -> ContextManager:
    """In-graph named scope; safe inside jit/scan bodies."""
    if not _enabled:
        return contextlib.nullcontext()
    return jax.named_scope(name)


def annotate(name: str) -> ContextManager:
    """Host-side phase marker: named scope + profiler TraceAnnotation."""
    if not _enabled:
        return contextlib.nullcontext()
    stack = contextlib.ExitStack()
    stack.enter_context(jax.named_scope(name))
    if _TraceAnnotation is not None:
        stack.enter_context(_TraceAnnotation(name))
    return stack
