"""The repo's single timing seam.

Every piece of `src/` that needs a clock imports it from here —
`tools/check_timing_lint.py` (run in CI) rejects raw ``time.time()`` /
``time.perf_counter()`` calls anywhere else under ``src/``, so timing
policy has one place to change:

* `perf_counter` — monotonic high-resolution clock for *durations*
  (driver dispatch timing, latency histograms).
* `monotonic` — monotonic clock for *event ordering* (the obs event
  sink, elastic fail/recover stamps): wall-clock `time.time()` can jump
  backwards under NTP skew and reorder events; this cannot.
* `wall_time` — the one sanctioned wall-clock read, for human-facing
  timestamps only (never for ordering or arithmetic between events).
* `timestamp` — formatted wall-clock string for artifacts/logs.
* `sleep` — the single sanctioned delay primitive (retry backoff, injected
  latency in `repro.faults`): everything that waits goes through here so a
  test double or fault schedule can control time everywhere at once.
"""

from __future__ import annotations

import time as _time

perf_counter = _time.perf_counter
monotonic = _time.monotonic
wall_time = _time.time
sleep = _time.sleep


def timestamp() -> str:
    """Human-facing wall-clock stamp (ISO-8601-ish, local offset)."""
    return _time.strftime("%Y-%m-%dT%H:%M:%S%z")
