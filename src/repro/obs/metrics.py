"""Metrics core: counters, gauges, log-bucketed histograms, one registry.

Design constraints (DESIGN.md §8):

* **No sample storage.** The serving tier observes one latency per
  release; a histogram that keeps raw samples grows without bound under
  "millions of users" traffic. Buckets are log-spaced with growth factor
  ``GROWTH = 2**0.25`` (~19% per bucket), so any quantile estimate is
  within ~±9% of the true value — plenty for p50/p95/p99 dashboards —
  while storage is O(log(max/min)) ints per series.
* **Pull, don't push.** Instruments mutate plain Python state under one
  registry lock; `snapshot()` / `to_json()` / `to_prometheus()` render
  on demand. Nothing here touches JAX, so the obs layer can never
  perturb a trace.
* **Label sets are part of series identity**, Prometheus-style:
  ``registry.counter("waves_total", kind="mwem")`` and ``kind="lp"`` are
  distinct series under one name; mixing instrument kinds under one name
  is an error.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Dict, Iterable, Mapping, Optional, Tuple

GROWTH = 2.0 ** 0.25
_LOG_GROWTH = math.log(GROWTH)

LabelItems = Tuple[Tuple[str, str], ...]


def _label_items(labels: Mapping[str, object]) -> LabelItems:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def series_key(name: str, labels: LabelItems) -> str:
    """Render ``name{k=v,...}`` — the snapshot/JSON dict key for a series."""
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically non-decreasing count (events, rejections, overflows)."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up (inc({amount}))")
        self.value += float(amount)

    def snapshot(self) -> float:
        return self.value


class Gauge:
    """Last-write-wins scalar (occupancy, remaining budget, ratios)."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = float("nan")

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        base = 0.0 if math.isnan(self.value) else self.value
        self.value = base + float(amount)

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Log-bucketed value distribution with quantile estimation.

    A value ``v > 0`` lands in integer bucket ``floor(log(v)/log(GROWTH))``;
    ``v <= 0`` lands in a dedicated zero-bucket (durations can round to 0
    on coarse clocks). Quantiles are estimated by walking the cumulative
    bucket counts and returning the hit bucket's geometric midpoint, so
    the estimate is exact in rank and within one bucket width in value.
    """

    kind = "histogram"
    __slots__ = ("buckets", "zero_count", "count", "sum", "min", "max")

    def __init__(self) -> None:
        self.buckets: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        if math.isnan(v):
            raise ValueError("histogram.observe(nan)")
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if v <= 0.0:
            self.zero_count += 1
        else:
            idx = math.floor(math.log(v) / _LOG_GROWTH)
            self.buckets[idx] = self.buckets.get(idx, 0) + 1

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]); nan when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return float("nan")
        # nearest-rank on the cumulative bucket counts
        rank = q * (self.count - 1)
        cum = self.zero_count
        if cum > rank:
            return 0.0
        for idx in sorted(self.buckets):
            cum += self.buckets[idx]
            if cum > rank:
                # geometric midpoint of [GROWTH**idx, GROWTH**(idx+1)),
                # clamped to the observed range so p0/p100 stay honest
                mid = GROWTH ** (idx + 0.5)
                return min(max(mid, self.min), self.max)
        return self.max  # unreachable unless float dust; be safe

    def snapshot(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.sum / self.count,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


_INSTRUMENTS = {cls.kind: cls for cls in (Counter, Gauge, Histogram)}


class MetricsRegistry:
    """Holds every (name, labels) series; thread-safe get-or-create."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # (name, label items) -> instrument; kind recorded per name
        self._series: Dict[Tuple[str, LabelItems], object] = {}
        self._kinds: Dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: Mapping[str, object]):
        items = _label_items(labels)
        with self._lock:
            prior = self._kinds.get(name)
            if prior is not None and prior != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {prior}, not {kind}"
                )
            self._kinds[name] = kind
            inst = self._series.get((name, items))
            if inst is None:
                inst = _INSTRUMENTS[kind]()
                self._series[(name, items)] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()
            self._kinds.clear()

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Plain-dict view: {"counters": {...}, "gauges": {...}, "histograms": {...}}."""
        out: Dict[str, Dict[str, object]] = {
            "counters": {},
            "gauges": {},
            "histograms": {},
        }
        with self._lock:
            items = sorted(self._series.items())
        for (name, labels), inst in items:
            out[inst.kind + "s"][series_key(name, labels)] = inst.snapshot()
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (histograms as summary-style quantiles)."""
        with self._lock:
            items = sorted(self._series.items())
            kinds = dict(self._kinds)
        lines = []
        seen_type = set()
        for (name, labels), inst in items:
            if name not in seen_type:
                # log-bucket histograms export as precomputed quantiles,
                # which is Prometheus's "summary" type
                ptype = "summary" if kinds[name] == "histogram" else kinds[name]
                lines.append(f"# TYPE {name} {ptype}")
                seen_type.add(name)
            if inst.kind == "histogram":
                for q in (0.5, 0.9, 0.95, 0.99):
                    qlabels = labels + (("quantile", f"{q:g}"),)
                    lines.append(
                        f"{name}{_prom_labels(qlabels)} {_prom_num(inst.quantile(q))}"
                    )
                lines.append(f"{name}_sum{_prom_labels(labels)} {_prom_num(inst.sum)}")
                lines.append(f"{name}_count{_prom_labels(labels)} {inst.count}")
            else:
                lines.append(f"{name}{_prom_labels(labels)} {_prom_num(inst.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_labels(items: Iterable[Tuple[str, str]]) -> str:
    items = tuple(items)
    if not items:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in items)
    return f"{{{inner}}}"


def _prom_num(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    return f"{v:g}"


_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry every layer publishes into by default."""
    return _default


def reset_default_registry() -> None:
    """Drop all default-registry series (tests; fresh bench runs)."""
    _default.reset()
