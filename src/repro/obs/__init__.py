"""repro.obs — unified observability: metrics, mechanism telemetry, tracing.

One registry (`default_registry`), one switch (`enabled`/`set_enabled`),
zero effect on results: the obs layer reads the traces the drivers
already return and annotates phases with pure-metadata profiler scopes.
Enabled-vs-disabled outputs are bitwise identical (tests/test_obs.py).

Layer map (DESIGN.md §8):

* `metrics` — Counter / Gauge / log-bucketed Histogram + MetricsRegistry
  (snapshot dict, JSON, Prometheus text).
* `telemetry` — MechanismTelemetry records aggregated host-side from
  the drivers' stacked scan traces (overflow rate, scored rows, √m
  ratio); published per run.
* `trace` — `scope` (in-graph named_scope) / `annotate` (host-side
  named_scope + TraceAnnotation), both gated on the obs switch.
* `events` — monotonic-stamped EventSink (elastic fail/recover, …).
* `clock` — the single sanctioned `time` import in `src/`.
"""

from repro.obs import clock
from repro.obs.events import EventSink, ObsEvent, default_sink
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    reset_default_registry,
)
from repro.obs.telemetry import (
    MechanismTelemetry,
    aggregate_traces,
    publish,
    record_run,
)
from repro.obs.trace import annotate, disabled, enabled, scope, set_enabled

__all__ = [
    "clock",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "reset_default_registry",
    "MechanismTelemetry",
    "aggregate_traces",
    "publish",
    "record_run",
    "EventSink",
    "ObsEvent",
    "default_sink",
    "annotate",
    "scope",
    "enabled",
    "set_enabled",
    "disabled",
]
