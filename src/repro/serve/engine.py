"""Batched serving engine.

Drives a `repro.models.LM` through prefill → decode with a shared batched
cache. Requests are padded into fixed (batch, max_len) slots (continuous
batching at the slot level: a finished request's slot is refillable —
`free_slots`). Sampling: greedy or temperature.

The per-token compute path is exactly the `serve_step` the dry-run lowers;
this module adds the request bookkeeping around it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, batch_size: int, max_len: int,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits: jax.Array, temperatures: np.ndarray) -> jax.Array:
        """Per-request sampling: greedy rows (temp ≤ 0) and temperature rows
        coexist in one wave."""
        greedy = jnp.argmax(logits, axis=-1)
        if (temperatures <= 0).all():
            return greedy
        self.key, sub = jax.random.split(self.key)
        temps = jnp.asarray(np.maximum(temperatures, 1e-6), logits.dtype)
        temps = temps.reshape((-1,) + (1,) * (logits.ndim - 1))
        sampled = jax.random.categorical(sub, logits / temps, axis=-1)
        return jnp.where(jnp.asarray(temperatures <= 0).reshape(greedy.shape),
                         greedy, sampled)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a wave of requests (up to batch_size at a time)."""
        for wave_start in range(0, len(requests), self.batch_size):
            wave = requests[wave_start:wave_start + self.batch_size]
            self._run_wave(wave)
        return requests

    def _run_wave(self, wave: List[Request]):
        B = len(wave)
        prompt_len = max(len(r.prompt) for r in wave)
        tokens = np.zeros((B, prompt_len), np.int32)
        for i, r in enumerate(wave):
            tokens[i, prompt_len - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(tokens)}
        logits, cache = self.model.prefill(self.params, batch,
                                           max_len=self.max_len)
        steps = max(r.max_new_tokens for r in wave)
        temperatures = np.array([r.temperature for r in wave], np.float32)
        next_tok = self._sample(logits, temperatures)
        for i, r in enumerate(wave):
            r.out_tokens.append(int(next_tok[i]))
        pos = prompt_len
        for _ in range(steps - 1):
            logits, cache = self._decode(self.params, cache,
                                         next_tok[:, None].astype(jnp.int32),
                                         jnp.int32(pos))
            next_tok = self._sample(logits, temperatures)
            pos += 1
            for i, r in enumerate(wave):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(next_tok[i]))
        for r in wave:
            r.done = True
