"""Batched serving engine.

Drives a `repro.models.LM` through prefill → decode with a shared batched
cache. Requests are padded into fixed (batch, max_len) slots — continuous
batching at the slot level: when a request finishes mid-wave its slot is
freed (`free_slots`) and refilled from the queue by prefilling the new
prompt alone and scattering its cache row into the batched cache, so the
wave keeps decoding at full width instead of draining to its slowest
member. Sampling: greedy or temperature.

The per-token compute path is exactly the `serve_step` the dry-run lowers;
this module adds the request bookkeeping around it.
"""

from __future__ import annotations

import contextlib
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs
from repro.obs.metrics import default_registry


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, batch_size: int, max_len: int,
                 seed: int = 0, mesh=None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        # waves dispatch under the mesh context when one is given, so
        # models whose shardings name mesh axes lower onto it
        self.mesh = mesh
        self._decode = jax.jit(model.decode_step)
        # slot indices currently free inside the active wave (refillable)
        self.free_slots: List[int] = []
        self.refill_count = 0  # requests served via mid-wave slot reuse

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _sample(self, logits: jax.Array, temperatures: np.ndarray) -> jax.Array:
        """Per-request sampling: greedy rows (temp ≤ 0) and temperature rows
        coexist in one wave."""
        greedy = jnp.argmax(logits, axis=-1)
        if (temperatures <= 0).all():
            return greedy
        self.key, sub = jax.random.split(self.key)
        temps = jnp.asarray(np.maximum(temperatures, 1e-6), logits.dtype)
        temps = temps.reshape((-1,) + (1,) * (logits.ndim - 1))
        sampled = jax.random.categorical(sub, logits / temps, axis=-1)
        return jnp.where(jnp.asarray(temperatures <= 0).reshape(greedy.shape),
                         greedy, sampled)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests; waves refill freed slots from the queue."""
        queue: Deque[Request] = deque(requests)
        while queue:
            wave = [queue.popleft()
                    for _ in range(min(self.batch_size, len(queue)))]
            self._run_wave(wave, queue)
        return requests

    def _left_pad(self, prompts: List[List[int]], width: int) -> jax.Array:
        tokens = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, width - len(p):] = p
        return jnp.asarray(tokens)

    def _can_refill(self, req: Request, pos: int) -> bool:
        """A queued request fits the running wave iff its prompt left-pads
        to the wave's current position and its decode budget fits the
        remaining cache length."""
        return (len(req.prompt) <= pos
                and pos + req.max_new_tokens <= self.max_len)

    def _refill_slot(self, cache, slot: int, req: Request, pos: int):
        """Prefill `req` alone (left-padded to the wave position) and
        scatter its cache row into the batched cache at `slot`.

        Cache leaves are stacked over layer units — (n_units, batch, ...) —
        so the batch axis is axis 1 on every leaf.
        """
        tokens = self._left_pad([req.prompt], pos)
        with self._mesh_ctx(), obs.annotate("serve/engine/refill_prefill"):
            logits1, cache1 = self.model.prefill(self.params,
                                                 {"tokens": tokens},
                                                 max_len=self.max_len)
        cache = jax.tree_util.tree_map(
            lambda c, c1: c.at[:, slot].set(c1[:, 0]), cache, cache1)
        first = self._sample(logits1, np.array([req.temperature], np.float32))
        self.refill_count += 1
        if obs.enabled():
            default_registry().counter("engine_refills_total").inc()
        return cache, int(first[0])

    def _run_wave(self, wave: List[Request], queue: Optional[Deque[Request]] = None):
        prompt_len = max(len(r.prompt) for r in wave)
        batch = {"tokens": self._left_pad([r.prompt for r in wave], prompt_len)}
        if obs.enabled():
            default_registry().counter("engine_waves_total").inc()
        with self._mesh_ctx(), obs.annotate("serve/engine/prefill"):
            logits, cache = self.model.prefill(self.params, batch,
                                               max_len=self.max_len)
        slots: List[Optional[Request]] = list(wave)
        temperatures = np.array([r.temperature for r in wave], np.float32)
        next_tok = self._sample(logits, temperatures)
        for i, r in enumerate(slots):
            r.out_tokens.append(int(next_tok[i]))
        pos = prompt_len
        self.free_slots = []
        while True:
            # retire finished requests → their slots become refillable
            for i, r in enumerate(slots):
                if r is not None and len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    slots[i] = None
                    self.free_slots.append(i)
            # mid-wave refill: freed slots pick up queued requests that fit
            while (queue and self.free_slots
                   and self._can_refill(queue[0], pos)):
                slot = self.free_slots.pop(0)
                req = queue.popleft()
                cache, first = self._refill_slot(cache, slot, req, pos)
                req.out_tokens.append(first)
                temperatures[slot] = req.temperature
                next_tok = next_tok.at[slot].set(first)
                slots[slot] = req
            if all(r is None for r in slots):
                break  # wave drained (leftover queue starts a fresh wave)
            if pos >= self.max_len:
                # cache exhausted: truncate the stragglers at max_len
                for r in slots:
                    if r is not None:
                        r.done = True
                break
            with self._mesh_ctx(), obs.annotate("serve/engine/decode"):
                logits, cache = self._decode(
                    self.params, cache,
                    next_tok[:, None].astype(jnp.int32), jnp.int32(pos))
            next_tok = self._sample(logits, temperatures)
            pos += 1
            for i, r in enumerate(slots):
                if r is not None and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(next_tok[i]))
        self.free_slots = []
