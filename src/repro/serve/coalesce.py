"""Deadline/occupancy wave coalescing for the streaming release path.

Under continuous admission the service no longer waits for a full
fixed-size wave: a `DeadlineOccupancyPolicy` watches each compatible
group's queue and dispatches when the wave is **full** or when the oldest
queued ticket has spent **half its latency budget** waiting (DESIGN.md
§11). Short waves are not padded to the batch wave size — a `WaveLadder`
of AOT-precompiled lane counts picks the smallest compiled executable
that fits the occupancy, so a 3-ticket wave runs on the 4-lane executable
instead of replicating a slot 5× to fill an 8-lane one.

The policy is deliberately **pure**: `decide` takes the clock reading as
an argument and returns a frozen `WaveDecision`, so hypothesis can drive
it through arbitrary (occupancy, deadline) trajectories without touching
real time, and the service can journal the decision before acting on it.
Every dispatch decision is WAL-replayable: the service writes the
trigger reason, chosen wave size, and observed occupancy into the
``dispatch-started`` journal record, and `replay_decisions` rebuilds the
decision sequence from a journal — crash recovery can audit exactly why
each wave was cut where it was.

Coalescing never touches mechanism statistics: lanes stay keyed by
``PRNGKey(ticket.seed)``, so however the policy slices the admitted set
into waves, each lane's release is bitwise identical to the fixed-wave
path (tests/test_streaming.py holds this as the headline invariant).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "WaveLadder",
    "WaveDecision",
    "DeadlineOccupancyPolicy",
    "ScriptedPolicy",
    "replay_decisions",
]


@dataclass(frozen=True)
class WaveLadder:
    """The set of lane counts with precompiled batched executables.

    ``sizes`` is sorted ascending and always contains the max wave size.
    The default ladder for ``max_size=8`` is ``(2, 4, 8)`` — powers of
    two keep the executable count logarithmic in the wave size while
    bounding padding waste to <2× for n ≥ 2 (a wave of n lanes runs on
    the ``fit(n) < 2n`` executable).

    The ladder floors at **2 lanes**: XLA lowers the degenerate 1-lane
    vmap with different reduction/tiling choices than any multi-lane
    executable, and the ulp-level score differences flip near-tied EM
    selections (observed on the LP workload at ~10% of seeds). All B ≥ 2
    executables agree bitwise with each other and with the padded
    fixed-wave path, so a singleton wave pads one replica slot — the
    same slot-replication trick the batch drain uses — rather than run
    the one executable whose answers can drift. ``max_size=1`` keeps a
    ``(1,)`` ladder: there the batch path is also single-lane, so the
    two paths share the executable and parity holds trivially.
    """

    sizes: Tuple[int, ...]

    @classmethod
    def for_wave_size(cls, max_size: int) -> "WaveLadder":
        if max_size < 1:
            raise ValueError(f"max_size must be >= 1, got {max_size}")
        if max_size == 1:
            return cls(sizes=(1,))
        sizes = []
        s = 2
        while s < max_size:
            sizes.append(s)
            s *= 2
        sizes.append(max_size)
        return cls(sizes=tuple(sizes))

    @property
    def max_size(self) -> int:
        return self.sizes[-1]

    def fit(self, n: int) -> int:
        """Smallest ladder size that holds ``n`` lanes (capped at max)."""
        if n < 1:
            raise ValueError(f"cannot fit a wave of {n} lanes")
        for s in self.sizes:
            if s >= n:
                return s
        return self.max_size


@dataclass(frozen=True)
class WaveDecision:
    """One coalescer verdict, journaled alongside the wave it cut.

    ``reason`` ∈ {"full", "deadline", "flush", "scripted"} for dispatches
    and {"hold", "empty"} for non-dispatches. ``wave_size`` is the ladder
    executable the wave will run on (0 when not dispatching);
    ``occupancy`` is the queue depth the policy saw.
    """

    dispatch: bool
    reason: str
    wave_size: int
    occupancy: int


@dataclass
class DeadlineOccupancyPolicy:
    """Dispatch when the wave is full or the oldest ticket's latency
    budget is half-spent.

    The half-spent rule bounds queueing delay to 50% of the slowest
    ticket's end-to-end budget while leaving the other half for the scan
    itself; tickets without deadlines only ride full or flushed waves.
    ``decide`` is pure in ``now`` so property tests can replay arbitrary
    clock trajectories.
    """

    wave_size: int
    ladder: WaveLadder = None  # type: ignore[assignment]
    half_frac: float = 0.5

    def __post_init__(self):
        if self.ladder is None:
            self.ladder = WaveLadder.for_wave_size(self.wave_size)
        if not 0.0 < self.half_frac <= 1.0:
            raise ValueError(f"half_frac must be in (0, 1], got {self.half_frac}")

    def decide(self, occupancy: int, now: float,
               oldest_submit: Optional[float] = None,
               oldest_deadline: Optional[float] = None,
               force: bool = False) -> WaveDecision:
        if occupancy <= 0:
            return WaveDecision(False, "empty", 0, occupancy)
        if occupancy >= self.wave_size:
            return WaveDecision(True, "full", self.ladder.max_size, occupancy)
        if force:
            return WaveDecision(True, "flush", self.ladder.fit(occupancy),
                                occupancy)
        if oldest_submit is not None and oldest_deadline is not None:
            budget = oldest_deadline - oldest_submit
            if budget <= 0 or now >= oldest_submit + self.half_frac * budget:
                return WaveDecision(True, "deadline",
                                    self.ladder.fit(occupancy), occupancy)
        return WaveDecision(False, "hold", 0, occupancy)


@dataclass
class ScriptedPolicy:
    """Cut waves at pre-scripted sizes — the parity-test harness.

    ``slices`` is consumed left to right; each entry is the number of
    tickets the next wave takes (clamped to the queue depth). Once the
    script runs dry the policy dispatches whatever is queued. Lets
    tests/test_streaming.py prove that *any* slicing of the admitted set
    produces bitwise-identical answers.
    """

    wave_size: int
    slices: Sequence[int] = ()
    ladder: WaveLadder = None  # type: ignore[assignment]
    _cursor: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.ladder is None:
            self.ladder = WaveLadder.for_wave_size(self.wave_size)

    def decide(self, occupancy: int, now: float,
               oldest_submit: Optional[float] = None,
               oldest_deadline: Optional[float] = None,
               force: bool = False) -> WaveDecision:
        if occupancy <= 0:
            return WaveDecision(False, "empty", 0, occupancy)
        if self._cursor < len(self.slices):
            take = max(1, min(self.slices[self._cursor], occupancy,
                              self.wave_size))
            self._cursor += 1
        else:
            take = min(occupancy, self.wave_size)
        return WaveDecision(True, "scripted", self.ladder.fit(take), take)


def replay_decisions(records: Iterable[dict]) -> List[WaveDecision]:
    """Rebuild the coalescer's dispatch decisions from journal records.

    Reads the ``trigger``/``wave_size``/``occupancy`` fields PR 10 added
    to ``dispatch-started`` records (older journals without them are
    skipped — the WAL stays forward/backward compatible). A recovered
    service can diff this against its live `wave_log` to audit that every
    wave it dispatched before a crash is accounted for.
    """
    out: List[WaveDecision] = []
    for rec in records:
        if rec.get("kind") != "dispatch-started":
            continue
        trigger = rec.get("trigger")
        if trigger is None:
            continue
        out.append(WaveDecision(dispatch=True, reason=trigger,
                                wave_size=int(rec.get("wave_size", 0)),
                                occupancy=int(rec.get("occupancy", 0))))
    return out
