"""Serving substrate.

Two serving tiers live here (DESIGN.md §5):

* `engine` — the LM tier: batched prefill/decode waves with slot-level
  continuous batching (`ServeEngine`).
* `release_service` / `session` / `admission` — the private query-release
  tier: multi-tenant sessions with (ε, δ) budgets, ledger-preview admission
  control, cross-tenant fixed-size release waves through one
  `run_mwem_batch` dispatch, and a zero-ε answer cache over released
  synthetic histograms.
* `journal` / `breaker` — the fault-tolerance layer (DESIGN.md §10):
  write-ahead journaling of the two-phase budget commit with crash
  `recover()`, and the circuit breaker that pins a flaky kernel route to
  the bitwise XLA reference path.
* `coalesce` / `loadgen` — the streaming layer (DESIGN.md §11): the
  deadline/occupancy wave-coalescing policy with its AOT wave-size
  ladder, and the open-loop Poisson load generator that measures
  admission→answer latency distributions against it.
"""

from repro.serve.engine import ServeEngine, Request
from repro.serve.admission import AdmissionController, AdmissionDecision
from repro.serve.breaker import CircuitBreaker
from repro.serve.coalesce import (
    DeadlineOccupancyPolicy,
    ScriptedPolicy,
    WaveDecision,
    WaveLadder,
    replay_decisions,
)
from repro.serve.loadgen import LoadReport, LoadSpec, run_open_loop
from repro.serve.journal import (
    Journal,
    RecoveredState,
    read_records,
    recover,
)
from repro.serve.release_service import (
    ReleaseService,
    ReleaseTicket,
    ServiceStats,
)
from repro.serve.session import (
    Answer,
    AnswerCache,
    ReleasedHistogram,
    ReleasedLP,
    TenantSession,
    query_fingerprint,
)

__all__ = [
    "ServeEngine",
    "Request",
    "AdmissionController",
    "AdmissionDecision",
    "CircuitBreaker",
    "DeadlineOccupancyPolicy",
    "ScriptedPolicy",
    "WaveDecision",
    "WaveLadder",
    "replay_decisions",
    "LoadReport",
    "LoadSpec",
    "run_open_loop",
    "Journal",
    "RecoveredState",
    "read_records",
    "recover",
    "ReleaseService",
    "ReleaseTicket",
    "ServiceStats",
    "Answer",
    "AnswerCache",
    "ReleasedHistogram",
    "ReleasedLP",
    "TenantSession",
    "query_fingerprint",
]
