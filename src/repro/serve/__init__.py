"""Serving substrate: batched request engine over prefill/decode steps."""

from repro.serve.engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request"]
