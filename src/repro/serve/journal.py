"""Write-ahead journal for the release service (DESIGN.md §10).

Privacy budget is irreplaceable, so the serving tier's budget state must
survive the process: every transition of the two-phase budget commit is
appended to a JSONL journal *before* the in-memory state moves, and
`recover()` replays the journal into fresh `TenantSession`s whose ledgers
equal the live service's (bitwise — JSON floats round-trip exactly via
shortest-repr, and commit replays through the same `record_events` path).

Record kinds, in the order one release produces them:

* ``session-created``   — tenant id, histogram, n_records, (ε, δ) budget
* ``reserved``          — phase one: rid + the exact cost bundle held
* ``dispatch-started``  — a wave attempt began for these rids
* ``committed``         — phase two: the rid's bundle entered the ledger
* ``aborted``           — the rid was refunded (expired / failed / shed)
* ``release-delivered`` — the released artifact (p_hat or x_bar) landed

plus two snapshot kinds written only by `ReleaseService.adopt` so the
post-adoption WAL is self-contained (a second recovery — from a fresh
journal file, or from the same file the adopter keeps appending to —
reconstructs the adopted state without re-reading the pre-crash records):

* ``ledger-snapshot``   — one tenant's full committed bundle + next rid
* ``service-snapshot``  — issued seeds and the ticket/release counters

In-doubt resolution (the crash-recovery rule the chaos suite pins): a
reservation with a ``dispatch-started`` record but no ``committed`` /
``aborted`` resolution is replayed as **committed** — the dispatch may
have realized noise (and even delivered) before the crash, so the
conservative reading charges the budget. A reservation that never reached
dispatch is refunded: no randomness was consumed, nothing could have
leaked, and the request is simply gone with the queue.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.faults import fault_site
from repro.obs import trace as obs
from repro.obs.clock import perf_counter
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.serve.session import ReleasedHistogram, ReleasedLP, TenantSession


class Journal:
    """Append-only JSONL write-ahead log.

    Each `append` writes one self-contained JSON object and flushes it to
    the OS; ``fsync=True`` additionally forces it to disk per record (the
    durable-against-power-loss mode — default off so tests and benchmarks
    stay fast while still surviving process crashes).
    """

    def __init__(self, path, fsync: bool = False):
        self.path = os.fspath(path)
        self._fsync = fsync
        self._fh = open(self.path, "a", encoding="utf-8")
        self._seq = 0

    def append(self, rec_kind: str, **payload) -> dict:
        fault_site("journal.append")
        # seq/kind are authoritative — a payload key can never shadow them
        rec = {**payload, "seq": self._seq, "kind": rec_kind}
        self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
        self._fh.flush()
        if self._fsync:
            os.fsync(self._fh.fileno())
        self._seq += 1
        return rec

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_records(path) -> List[dict]:
    """All journal records, in append order. A torn final line (crash mid-
    write) is dropped — everything before it was flushed whole."""
    records: List[dict] = []
    with open(os.fspath(path), "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                break  # torn tail record — the crash interrupted this write
    return records


def encode_bundle(bundle) -> dict:
    events, gamma, slack = bundle
    return {"events": [[e0, d0, label] for e0, d0, label in events],
            "gamma": gamma, "slack": slack}


def decode_bundle(obj) -> tuple:
    return ([(e0, d0, label) for e0, d0, label in obj["events"]],
            obj["gamma"], obj["slack"])


@dataclass
class RecoveredState:
    """What `recover()` reconstructs from a journal."""

    sessions: Dict[str, TenantSession] = field(default_factory=dict)
    # reservations resolved by the in-doubt rule (dispatched, no commit
    # record) — charged conservatively; surface them so an operator can see
    # exactly which budget was burned by the crash
    in_doubt: List[tuple] = field(default_factory=list)   # (tenant_id, rid)
    refunded: List[tuple] = field(default_factory=list)   # never dispatched
    issued_seeds: set = field(default_factory=set)
    # per-tenant: one past the highest rid the journal ever mentioned —
    # recovered ledgers are fast-forwarded to it, and `adopt` re-applies
    # it, so a post-recovery reserve can never reuse a journaled rid
    next_rids: Dict[str, int] = field(default_factory=dict)
    next_release_id: int = 0
    next_ticket_id: int = 0
    seconds: float = 0.0


def recover(path, registry: Optional[MetricsRegistry] = None,
            tight: bool = False) -> RecoveredState:
    """Replay a journal into fresh sessions + ledgers.

    Commits replay in journal order through `PrivacyLedger.record_events`
    — the same call `commit` makes live — so a recovered ledger equals the
    live one (dataclass equality over events/γ/slack) in either
    composition mode; ``tight`` only selects the mode used for the
    recovery-time budget gauges.
    """
    t0 = perf_counter()
    state = RecoveredState()
    # (tenant_id, rid) -> (bundle, dispatched?)
    pending: Dict[tuple, list] = {}

    def saw_rid(tenant_id: str, next_rid: int) -> None:
        state.next_rids[tenant_id] = max(
            state.next_rids.get(tenant_id, 0), int(next_rid))

    for rec in read_records(path):
        kind = rec["kind"]
        if kind == "session-created":
            # a repeated session-created (an adoption snapshot appended to
            # the same WAL) supersedes the earlier replay: the snapshot
            # records that follow carry the full post-recovery state
            sess = TenantSession(
                tenant_id=rec["tenant_id"],
                h=np.asarray(rec["h"], np.float32),
                n_records=int(rec["n_records"]),
                eps_budget=rec["eps_budget"],
                delta_budget=rec["delta_budget"],
            )
            state.sessions[sess.tenant_id] = sess
        elif kind == "reserved":
            key = (rec["tenant_id"], rec["rid"])
            pending[key] = [decode_bundle(rec["bundle"]), False]
            state.issued_seeds.add(int(rec["seed"]))
            saw_rid(rec["tenant_id"], rec["rid"] + 1)
            state.next_ticket_id = max(state.next_ticket_id,
                                       rec["ticket_id"] + 1)
        elif kind == "dispatch-started":
            for tenant_id, rid in rec["rids"]:
                entry = pending.get((tenant_id, rid))
                if entry is not None:
                    entry[1] = True
        elif kind == "committed":
            # tolerate duplicate commit records (a crash between the ledger
            # move and the journal write, then an in-doubt resolution on a
            # previous recovery, can journal the same rid twice)
            entry = pending.pop((rec["tenant_id"], rec["rid"]), None)
            if entry is not None:
                state.sessions[rec["tenant_id"]].ledger.record_events(
                    *entry[0])
        elif kind == "aborted":
            pending.pop((rec["tenant_id"], rec["rid"]), None)
        elif kind == "ledger-snapshot":
            # adoption snapshot: the tenant's full committed bundle in one
            # record (the session-created just before it reset the ledger)
            state.sessions[rec["tenant_id"]].ledger.record_events(
                *decode_bundle(rec["bundle"]))
            saw_rid(rec["tenant_id"], rec.get("next_rid", 0))
        elif kind == "service-snapshot":
            state.issued_seeds |= {int(s) for s in rec["issued_seeds"]}
            state.next_ticket_id = max(state.next_ticket_id,
                                       int(rec["next_ticket_id"]))
            state.next_release_id = max(state.next_release_id,
                                        int(rec["next_release_id"]))
        elif kind == "release-delivered":
            sess = state.sessions[rec["tenant_id"]]
            if rec["release_kind"] == "mwem":
                sess.add_release(ReleasedHistogram(
                    release_id=rec["release_id"],
                    p_hat=np.asarray(rec["p_hat"], np.float32),
                    final_error=rec["final_error"],
                    eps_cost=rec["eps_cost"],
                    delta_cost=rec["delta_cost"],
                    seed=rec["seed"],
                ))
            else:
                sess.add_lp_release(ReleasedLP(
                    release_id=rec["release_id"],
                    x_bar=np.asarray(rec["x_bar"], np.float32),
                    violated_frac=rec["violated_frac"],
                    eps_cost=rec["eps_cost"],
                    delta_cost=rec["delta_cost"],
                    seed=rec["seed"],
                ))
            state.next_release_id = max(state.next_release_id,
                                        rec["release_id"] + 1)
        # unknown kinds are skipped: journals are forward-compatible

    # resolve what the crash left open, in reservation order
    for (tenant_id, rid), (bundle, dispatched) in pending.items():
        if dispatched:
            # noise may already have been realized — charge conservatively
            state.sessions[tenant_id].ledger.record_events(*bundle)
            state.in_doubt.append((tenant_id, rid))
        else:
            state.refunded.append((tenant_id, rid))

    # recovered ledgers must never re-issue a rid the WAL already holds —
    # an in-doubt reservation's record would then resolve the wrong one
    # on the next replay
    for tenant_id, sess in state.sessions.items():
        sess.ledger.advance_rid(state.next_rids.get(tenant_id, 0))

    state.seconds = perf_counter() - t0
    if obs.enabled():
        reg = registry if registry is not None else default_registry()
        reg.histogram("recovery_seconds").observe(state.seconds)
        reg.counter("recovery_in_doubt_total").inc(len(state.in_doubt))
        reg.counter("recovery_refunded_total").inc(len(state.refunded))
        for sess in state.sessions.values():
            eps, delta = sess.ledger.composed(tight=tight)
            reg.gauge("tenant_eps_spent", tenant=sess.tenant_id).set(eps)
            reg.gauge("tenant_delta_spent", tenant=sess.tenant_id).set(delta)
    return state
