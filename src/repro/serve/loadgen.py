"""Open-loop load generation for the streaming release service.

Arrivals are drawn up front from a Poisson process (exponential gaps,
seeded) and are **independent of service state** — the generator never
waits for an answer before offering the next request, so queueing delay
shows up in the measured latency instead of silently throttling the
offered rate (the coordinated-omission trap closed-loop generators fall
into). Between arrivals the generator spins the service's `pump` tick so
deadline-triggered waves fire on time.

Traffic is a tenant-mixed blend of histogram releases, LP solves, and
cached-answer reads (zero-ε post-processing); per-kind admission→answer
latency distributions (p50/p95/p99) and sustained QPS come back in a
`LoadReport`, which `benchmarks/bench_streaming.py` writes into
BENCH_results.json. The chaos tier runs the same generator under
`repro.faults` schedules — the generator counts, rather than propagates,
per-request failures so a fault burst cannot abort the measurement.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.obs.clock import monotonic

__all__ = ["LoadSpec", "LoadReport", "run_open_loop"]


@dataclass
class LoadSpec:
    """One open-loop run: offer ``rate`` req/s for ``duration`` seconds."""

    duration: float = 1.0            # arrival window (seconds of offered load)
    rate: float = 50.0               # mean offered arrivals per second
    seed: int = 0                    # drives arrivals, kinds, tenant picks
    mix: Dict[str, float] = field(default_factory=lambda: {
        "mwem": 0.5, "lp": 0.25, "answer": 0.25})
    deadline: Optional[float] = None  # per-ticket latency budget (seconds)
    max_wall: float = 120.0          # hard wall-clock cap on the whole run
    tenants: Optional[List[str]] = None  # default: every registered session


@dataclass
class LoadReport:
    """Latency distributions and throughput for one open-loop run."""

    latencies: Dict[str, np.ndarray]          # kind -> sorted seconds
    quantiles: Dict[str, Dict[str, float]]    # kind -> {p50, p95, p99}
    counts: Dict[str, int]
    offered_qps: float
    sustained_qps: float                      # completed work / wall time
    wall_seconds: float
    tickets: List[object] = field(default_factory=list, repr=False)

    def as_dict(self) -> dict:
        return dict(
            quantiles={k: dict(v) for k, v in self.quantiles.items()},
            counts=dict(self.counts),
            offered_qps=self.offered_qps,
            sustained_qps=self.sustained_qps,
            wall_seconds=self.wall_seconds,
        )


def _quantiles(lat: np.ndarray) -> Dict[str, float]:
    if lat.size == 0:
        return {"p50": float("nan"), "p95": float("nan"),
                "p99": float("nan")}
    return {"p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99))}


def run_open_loop(svc, spec: LoadSpec,
                  answer_queries=None) -> LoadReport:
    """Drive ``svc`` with the open-loop schedule in ``spec``.

    ``svc`` is a `ReleaseService`; streaming or batch mode both work (the
    generator only calls `pump`/`submit`/`submit_lp`/`answer`/`flush`),
    which is how the parity tier measures both paths with one harness.
    Answer reads are only offered to tenants that already hold a release;
    LP arrivals require `attach_lp` (offered mass falls back to "mwem"
    otherwise). Submission failures are counted, not propagated.
    """
    rng = np.random.default_rng(spec.seed)
    tenants = spec.tenants or list(svc.sessions)
    if not tenants:
        raise ValueError("no tenant sessions to offer load against")
    mix = dict(spec.mix)
    if svc.lp is None and "lp" in mix:
        mix["mwem"] = mix.get("mwem", 0.0) + mix.pop("lp")
    kind_names = sorted(mix)
    probs = np.asarray([mix[k] for k in kind_names], float)
    probs = probs / probs.sum()

    # the whole arrival schedule is fixed before the run starts — open loop
    arrivals: List[float] = []
    t = float(rng.exponential(1.0 / spec.rate))
    while t < spec.duration:
        arrivals.append(t)
        t += float(rng.exponential(1.0 / spec.rate))
    kinds = rng.choice(kind_names, size=len(arrivals), p=probs)
    picks = rng.choice(np.asarray(tenants, object), size=len(arrivals))
    if answer_queries is None:
        answer_queries = rng.random((8, svc.U)).astype(np.float32)
    answer_queries = np.asarray(answer_queries, np.float32)

    tickets: List[object] = []
    answer_lat: List[float] = []
    counts = {"offered": len(arrivals), "answers": 0, "skipped_answers": 0,
              "submit_errors": 0}
    t0 = monotonic()
    for arr, kind, tenant in zip(arrivals, kinds, picks):
        while monotonic() - t0 < arr:
            svc.pump()
        if monotonic() - t0 > spec.max_wall:
            break
        try:
            if kind == "answer":
                sess = svc.sessions[tenant]
                if not sess.releases:
                    counts["skipped_answers"] += 1
                    continue
                q = answer_queries[int(rng.integers(len(answer_queries)))]
                ta = monotonic()
                svc.answer(tenant, q)
                answer_lat.append(monotonic() - ta)
                counts["answers"] += 1
            elif kind == "lp":
                tickets.append(svc.submit_lp(tenant, deadline=spec.deadline))
            else:
                tickets.append(svc.submit(tenant, deadline=spec.deadline))
        except Exception:
            # submit raises are budget-neutral (the reservation was
            # refunded before the raise); the run keeps measuring
            counts["submit_errors"] += 1
    svc.flush()
    wall = monotonic() - t0

    latencies: Dict[str, np.ndarray] = {}
    for kind in ("mwem", "lp"):
        lat = np.sort(np.asarray([t.latency_seconds for t in tickets
                                  if t.kind == kind and t.status == "done"]))
        latencies[kind] = lat
    latencies["answer"] = np.sort(np.asarray(answer_lat))
    for status in ("done", "expired", "failed", "rejected"):
        counts[status] = sum(1 for t in tickets if t.status == status)
    completed = counts["done"] + counts["answers"]
    return LoadReport(
        latencies=latencies,
        quantiles={k: _quantiles(v) for k, v in latencies.items()},
        counts=counts,
        offered_qps=len(arrivals) / max(spec.duration, 1e-9),
        sustained_qps=completed / max(wall, 1e-9),
        wall_seconds=wall,
        tickets=tickets,
    )
