"""Circuit breaker for the Pallas kernel seams (DESIGN.md §10).

A flaky accelerator path must not take the serving tier down with it: the
kernels already have bitwise XLA reference fallbacks (DESIGN.md §3/§7),
so after ``threshold`` *consecutive* runtime failures the breaker opens
and the owning service pins itself to the reference route
(``use_pallas="never"``) — answers stay bitwise-correct, only the
roofline win is given up. A later `reset()` (operator action, or a config
reload after a toolchain fix) closes it again.

The breaker publishes its state as a gauge (0 = closed, 1 = open) plus a
trip counter, so degraded services are visible on the same dashboard as
everything else.
"""

from __future__ import annotations

from typing import Callable, List, Optional

from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry, default_registry


class CircuitBreaker:
    """Consecutive-failure breaker with on-trip / on-reset callbacks."""

    def __init__(self, threshold: int = 3, seam: str = "kernel",
                 registry: Optional[MetricsRegistry] = None):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.seam = seam
        self.consecutive_failures = 0
        self.is_open = False
        self.trips = 0
        self._registry = registry
        self._on_trip: List[Callable[[], None]] = []
        self._publish()

    def on_trip(self, fn: Callable[[], None]) -> None:
        """Register a callback fired once each time the breaker opens —
        the service hangs its degrade-to-ref-path switch here."""
        self._on_trip.append(fn)

    def record_success(self) -> None:
        self.consecutive_failures = 0
        self._publish()

    def record_failure(self) -> bool:
        """Count one runtime failure; returns True iff this one tripped
        the breaker open."""
        self.consecutive_failures += 1
        tripped = (not self.is_open
                   and self.consecutive_failures >= self.threshold)
        if tripped:
            self.is_open = True
            self.trips += 1
            if obs.enabled():
                reg = (self._registry if self._registry is not None
                       else default_registry())
                reg.counter("breaker_trips_total", seam=self.seam).inc()
            for fn in self._on_trip:
                fn()
        self._publish()
        return tripped

    def reset(self) -> None:
        """Close the breaker (operator action after the fault is fixed)."""
        self.is_open = False
        self.consecutive_failures = 0
        self._publish()

    def _publish(self) -> None:
        if not obs.enabled():
            return
        reg = self._registry if self._registry is not None else default_registry()
        reg.gauge("breaker_state", seam=self.seam).set(float(self.is_open))
        reg.gauge("breaker_consecutive_failures", seam=self.seam).set(
            self.consecutive_failures)
