"""Budget admission control for release requests.

A release's privacy cost is fully determined before execution: the drivers
record a fixed event schedule (T × {EM, Laplace} events plus index failure
mass — `repro.core.mwem.release_cost`). Admission therefore *previews* the
tenant ledger with that bundle appended (`PrivacyLedger.preview`) and
rejects any request whose composed (ε, δ) would exceed the session budget —
nothing is spent until the wave actually executes, and the projected totals
reported on rejection are exactly what execution would have composed to.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.mwem import MWEMConfig, release_cost
from repro.serve.session import TenantSession


@dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    tenant_id: str
    eps_projected: float     # composed ε if this release were executed
    delta_projected: float   # composed δ if this release were executed
    eps_budget: float
    delta_budget: float
    eps_cost: float          # this release's marginal composed ε
    delta_cost: float        # this release's marginal composed δ
    reason: str = ""


class AdmissionController:
    """Stateless check: would this (session, release config) overspend?

    ``tight`` selects the composition mode used for the budget comparison
    (Thm B.1 as printed vs the Dwork–Rothblum–Vadhan tail) — the same flag
    the ledger exposes, so admission and post-hoc accounting agree.
    """

    def __init__(self, tight: bool = False):
        self.tight = tight

    def check_release(self, session: TenantSession, cfg: MWEMConfig, m: int,
                      U: int, index=None) -> AdmissionDecision:
        """Convenience wrapper: derive the cost bundle, then `check`."""
        return self.check(session, release_cost(cfg, m, U, index=index))

    def check_lp(self, session: TenantSession, cfg, A,
                 index=None) -> AdmissionDecision:
        """Convenience wrapper for LP solves (either solver's config):
        derive the `lp_release_cost` bundle, then `check` — the same
        preview-don't-spend contract as histogram releases."""
        from repro.core.lp_dual import lp_release_cost

        return self.check(session, lp_release_cost(cfg, A, index=index))

    def check(self, session: TenantSession, cost_bundle,
              reserved=None) -> AdmissionDecision:
        """Decide on a request whose cost is the pre-computed
        ``cost_bundle = (events, gamma, slack)``.

        ``reserved`` is an equally-shaped bundle of the tenant's
        queued-but-unexecuted requests: those already count against the
        budget, so two requests that individually fit but jointly overspend
        cannot both be admitted.
        """
        events, gamma, slack = cost_bundle
        if reserved is not None:
            r_events, r_gamma, r_slack = reserved
            events = list(r_events) + list(events)
            gamma += r_gamma
            slack += r_slack
            # marginal cost baseline includes the reservations, so
            # eps_cost/delta_cost report only *this* request's share
            spent_eps, spent_delta = session.ledger.preview(
                r_events, r_gamma, r_slack, tight=self.tight)
        else:
            spent_eps, spent_delta = session.ledger.composed(tight=self.tight)
        proj_eps, proj_delta = session.ledger.preview(
            events, gamma, slack, tight=self.tight)
        admitted = (proj_eps <= session.eps_budget
                    and proj_delta <= session.delta_budget)
        if admitted:
            reason = "within budget"
        else:
            reason = (f"composed (ε={proj_eps:.4f}, δ={proj_delta:.2e}) "
                      f"exceeds budget (ε={session.eps_budget:.4f}, "
                      f"δ={session.delta_budget:.2e})")
        return AdmissionDecision(
            admitted=admitted,
            tenant_id=session.tenant_id,
            eps_projected=proj_eps,
            delta_projected=proj_delta,
            eps_budget=session.eps_budget,
            delta_budget=session.delta_budget,
            eps_cost=proj_eps - spent_eps,
            delta_cost=proj_delta - spent_delta,
            reason=reason,
        )
