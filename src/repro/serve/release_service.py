"""Multi-tenant private query-release service.

The serving tier the Fast-MWEM paper makes economical: selection is
Θ(√m) per iteration, the whole T-iteration run is one fused scan, and the
vmapped batch driver releases B synthetic histograms per dispatch — so the
service coalesces pending release requests *across tenants* into fixed-size
waves (padding short waves with replica slots, like the LM engine pads
request slots) and answers read traffic from already-released histograms at
zero additional ε (post-processing).

Flow (DESIGN.md §5):

  submit ──► AdmissionController.check (ledger preview, nothing spent)
     │            │
     │ rejected ──┴──► ReleaseTicket(status="rejected", decision)
     ▼
  pending queue, grouped by n_records (a compile-time static)
     ▼ wave of exactly `wave_size` slots
  run_mwem_batch (one dispatch; per-lane ledgers charge each tenant)
     ▼
  TenantSession.releases ──► answer()/AnswerCache (zero-ε reads)

Budget reservations: a queued-but-unexecuted request already counts against
its tenant's budget at admission time (its cost bundle is held as a
reservation and previewed together with the ledger), so two requests that
individually fit but jointly overspend cannot both be admitted.

The LP workload (paper §4, DESIGN.md §6) rides the same machinery:
`attach_lp` registers a scalar-private feasibility LP (public A,
curator-held private b, one shared k-MIPS index over [A_i, b_i]);
`submit_lp` admission-gates on the solver's own `lp_release_cost` bundle
(reservations pool across both workloads), and admitted solves drain in
fixed-size waves through one `solve_lp_batch` dispatch — per-lane ledgers,
pad-by-replication, and marginal-cost replay identical to histogram waves.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accountant import PrivacyLedger
from repro.core.distributed import _data_shards, run_mwem_sharded_batch
from repro.core.lp_dual import lp_release_cost
from repro.core.lp_scalar import ScalarLPConfig, solve_lp_batch
from repro.core.mwem import MWEMConfig, release_cost, run_mwem_batch
from repro.core.workload import as_workload
from repro.mips import (FlatAbsIndex, FlatIndex, IVFIndex, LSHIndex,
                        MarginalIVFIndex, ShardedIVFIndex,
                        augment_complement, lp_scalar_rows)
from repro.obs import trace as obs
from repro.obs.clock import monotonic
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.serve.admission import AdmissionController, AdmissionDecision
from repro.serve.session import (Answer, ReleasedHistogram, ReleasedLP,
                                 TenantSession)


@dataclass
class ReleaseTicket:
    """Handle returned by `submit`/`submit_lp`; resolved by the wave that
    executes it."""

    ticket_id: int
    tenant_id: str
    seed: int
    status: str                      # "queued" | "rejected" | "done"
    decision: AdmissionDecision
    kind: str = "mwem"               # "mwem" | "lp"
    cost_bundle: tuple = ()          # (events, gamma, slack) reservation
    release: Optional[object] = None  # ReleasedHistogram | ReleasedLP
    final_error: float = float("nan")
    submit_time: float = float("nan")   # monotonic stamp at submit()
    latency_seconds: float = float("nan")  # admission → answered


@dataclass
class ServiceStats:
    dispatches: int = 0
    released: int = 0
    lp_released: int = 0
    rejected: int = 0
    padded_slots: int = 0

    def as_dict(self) -> dict:
        return dict(dispatches=self.dispatches, released=self.released,
                    lp_released=self.lp_released, rejected=self.rejected,
                    padded_slots=self.padded_slots)


@dataclass
class _LPWorkload:
    """The service's scalar-LP workload (DESIGN.md §6): public constraint
    matrix A, the curator-held private bounds b, the release config, and
    the k-MIPS index over the concatenated rows [A_i, b_i]. Tenants are
    budget principals drawing private solves against it."""

    A: jax.Array
    b: jax.Array
    cfg: ScalarLPConfig
    index: Optional[object]
    cost: tuple                      # (events, gamma, slack) per release
    pending: List[ReleaseTicket]


class ReleaseService:
    """Coalescing, budget-admitted front end over `run_mwem_batch`.

    One service owns one query workload Q (m × U) and one k-MIPS index over
    it — tenants share the compiled wave executable and differ only in
    their histogram lane, PRNG key, and ledger. Release parameters
    (per-release ε, δ, T, mode) are fixed at construction so every wave is
    one `run_mwem_batch` dispatch of exactly ``wave_size`` lanes; requests
    from datasets of different sizes (``n_records`` is a compile-time
    static through the noise scales) batch in separate per-size groups.

    Passing a ``mesh`` puts the service on a device mesh: the index becomes
    a per-shard `ShardedIVFIndex` and waves drain through
    `run_mwem_sharded_batch` — one mesh-wide scan dispatch per lane, the
    compiled executable shared across lanes, the same per-lane ledger
    charging. Admission, sessions, and the answer cache are unchanged.
    """

    def __init__(self, Q, cfg: MWEMConfig, wave_size: int = 8,
                 index_kind: str = "flat", seed: int = 0,
                 tight_composition: bool = False, auto_flush: bool = True,
                 mesh=None, use_pallas: str = "auto",
                 registry: Optional[MetricsRegistry] = None):
        # the workload seam: a raw (m, U) matrix or any `core.workload`
        # family — `MarginalWorkload` releases run factored end to end
        # through the same admission/cost/wave path (DESIGN.md §9)
        self.workload = as_workload(Q)
        self.Q = self.workload.Q if self.workload.is_dense else None
        self.m, self.U = self.workload.m, self.workload.U
        # where this service publishes its metrics; the process-wide
        # default registry unless the caller isolates it (tests do)
        self.metrics = registry if registry is not None else default_registry()
        # the service-level knob also drives the drivers' fused step body
        # (megakernel vs classic — DESIGN.md §7), so batched waves pick up
        # the VMEM-resident `kernels.mwem_step` route alongside the probe
        self.cfg = replace(cfg, use_pallas=use_pallas)
        self.wave_size = int(wave_size)
        self.auto_flush = auto_flush
        # a mesh routes waves through the sharded driver (one mesh-wide
        # scan dispatch per lane) instead of the vmapped fused batch
        self.mesh = mesh
        self.admission = AdmissionController(tight=tight_composition)
        self.sessions: Dict[str, TenantSession] = {}
        self.stats = ServiceStats()
        self._pending: "OrderedDict[int, List[ReleaseTicket]]" = OrderedDict()
        self.lp: Optional[_LPWorkload] = None
        self._next_ticket = 0
        self._next_release = 0
        self._next_seed = seed
        # `use_pallas` ("auto" | "always" | "never") routes the per-wave
        # probe through the fused kernels where the index supports them
        # (kernels/ivf_probe for IVF, mips_topk for flat) — "auto" falls
        # back to the XLA probe off-TPU automatically
        if cfg.mode == "fast":
            factored = not self.workload.is_dense
            if mesh is not None:
                # the sharded driver needs the per-shard structure, whatever
                # single-device kind was asked for; factored workloads
                # densify here or fail loudly (the documented fallback)
                self.index = ShardedIVFIndex(
                    self.workload.require_dense("ReleaseService[mesh]"),
                    n_shards=_data_shards(mesh)[1],
                    seed=seed, use_pallas=use_pallas)
            elif index_kind in ("ivf", "marginal_ivf") and factored:
                # the clique-structured family is the factored counterpart
                # of IVF — exact probe, no row table (DESIGN.md §9)
                self.index = MarginalIVFIndex(self.workload)
            elif index_kind == "marginal_ivf":
                raise ValueError(
                    "index_kind='marginal_ivf' needs a MarginalWorkload; "
                    "dense services use flat/ivf/lsh")
            elif index_kind == "flat":
                self.index = FlatAbsIndex(self.workload,
                                          use_pallas=use_pallas)
            elif index_kind == "ivf":
                self.index = IVFIndex(augment_complement(np.asarray(self.Q)),
                                      seed=seed, use_pallas=use_pallas)
            elif index_kind == "lsh":
                self.index = LSHIndex(
                    augment_complement(np.asarray(self.workload.require_dense(
                        "ReleaseService[lsh]"))),
                    seed=seed)
            else:
                raise ValueError(f"unknown index kind {index_kind!r}")
        else:
            self.index = None

    # ------------------------------------------------------------ sessions
    def create_session(self, tenant_id: str, *, eps_budget: float,
                       delta_budget: float, tokens=None, h=None,
                       n_records: Optional[int] = None) -> TenantSession:
        """Register a tenant: histogram from raw ``tokens`` (binned over the
        service domain U) or a pre-built normalized ``h`` + ``n_records``."""
        if tenant_id in self.sessions:
            raise ValueError(f"session {tenant_id!r} already exists")
        if tokens is not None:
            sess = TenantSession.from_tokens(tenant_id, tokens, self.U,
                                             eps_budget, delta_budget)
        else:
            if h is None or n_records is None:
                raise ValueError("provide tokens=, or h= with n_records=")
            h = np.asarray(h, np.float32)
            if h.shape != (self.U,):
                raise ValueError(f"h must have shape ({self.U},), got {h.shape}")
            sess = TenantSession(tenant_id=tenant_id, h=h,
                                 n_records=int(n_records),
                                 eps_budget=eps_budget,
                                 delta_budget=delta_budget)
        self.sessions[tenant_id] = sess
        self._register_ledger_gauges(sess)
        return sess

    def _register_ledger_gauges(self, sess: TenantSession) -> None:
        """Hang the obs gauges off the tenant's ledger: after every
        mutating record, the per-tenant ε/δ-spent and remaining-budget
        gauges recompute from `ledger.composed()` in the service's
        composition mode — the snapshot always agrees with the ledger."""
        tight = self.admission.tight
        metrics = self.metrics

        def update(ledger, sess=sess):
            if not obs.enabled():
                return
            eps, delta = ledger.composed(tight=tight)
            labels = dict(tenant=sess.tenant_id)
            metrics.gauge("tenant_eps_spent", **labels).set(eps)
            metrics.gauge("tenant_delta_spent", **labels).set(delta)
            metrics.gauge("tenant_eps_remaining", **labels).set(
                sess.eps_budget - eps)
            metrics.gauge("tenant_delta_remaining", **labels).set(
                sess.delta_budget - delta)

        sess.ledger.add_hook(update)
        update(sess.ledger)  # publish the zero-spend baseline immediately

    def session(self, tenant_id: str) -> TenantSession:
        return self.sessions[tenant_id]

    # ------------------------------------------------------------- submit
    def _group_cfg(self, n_records: int) -> MWEMConfig:
        return replace(self.cfg, n_records=n_records)

    def _reserved(self, tenant_id: str):
        """Cost bundles of this tenant's queued-but-unexecuted tickets —
        across *both* workloads: a queued LP solve reserves budget against
        a pending histogram release and vice versa."""
        groups = list(self._pending.values())
        if self.lp is not None:
            groups.append(self.lp.pending)
        events: list = []
        gamma = slack = 0.0
        for group in groups:
            for t in group:
                if t.tenant_id == tenant_id:
                    ev, g, s = t.cost_bundle
                    events.extend(ev)
                    gamma += g
                    slack += s
        return events, gamma, slack

    def submit(self, tenant_id: str,
               seed: Optional[int] = None) -> ReleaseTicket:
        """Request one release for a tenant.

        Admission previews the tenant ledger with the release's exact cost
        bundle (plus any still-queued reservations) appended; over-budget
        requests are rejected *before* anything is spent, with the
        projected composed (ε, δ) reported on the decision.
        """
        sess = self.sessions[tenant_id]
        cfg = self._group_cfg(sess.n_records)
        bundle = release_cost(cfg, self.m, self.U, index=self.index)
        decision = self.admission.check(sess, bundle,
                                        reserved=self._reserved(tenant_id))
        ticket = ReleaseTicket(
            ticket_id=self._next_ticket, tenant_id=tenant_id,
            seed=self._next_seed if seed is None else seed,
            status="queued" if decision.admitted else "rejected",
            decision=decision, cost_bundle=bundle,
            submit_time=monotonic(),
        )
        self._next_ticket += 1
        if seed is None:
            self._next_seed += 1
        if not decision.admitted:
            sess.rejected_count += 1
            self.stats.rejected += 1
            if obs.enabled():
                self.metrics.counter("admission_rejections_total",
                                     kind="mwem", tenant=tenant_id).inc()
            return ticket
        self._pending.setdefault(sess.n_records, []).append(ticket)
        if self.auto_flush and len(self._pending[sess.n_records]) >= self.wave_size:
            self._run_wave(sess.n_records)
        return ticket

    # ----------------------------------------------------------------- LP
    def attach_lp(self, A, b, cfg: Optional[ScalarLPConfig] = None,
                  index_kind: str = "flat", seed: int = 0,
                  use_pallas: str = "auto") -> None:
        """Register the service's scalar-LP workload (paper §4.1).

        ``A`` is the public constraint matrix, ``b`` the curator-held
        private bounds (Δ∞ sensitivity); tenants draw private solves
        against their budgets via `submit_lp`. Fast mode builds the k-MIPS
        index over the concatenated rows [A_i, b_i] once, here — every LP
        wave shares it and the compiled `solve_lp_batch` executable.
        """
        if self.lp is not None:
            raise ValueError("an LP workload is already attached")
        if self.mesh is not None:
            raise ValueError("LP waves are not mesh-sharded; attach to an "
                             "off-mesh service")
        A = jnp.asarray(A, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        cfg = cfg or ScalarLPConfig()
        if cfg.driver == "host":
            # refuse now, not at wave time: _run_lp_wave pops its tickets
            # before dispatching, so a late solve_lp_batch rejection would
            # strand admitted (budget-reserved) requests
            raise ValueError("LP waves run the fused batch driver; "
                             "cfg.driver='host' cannot serve")
        index = None
        if cfg.mode == "fast":
            rows = lp_scalar_rows(np.asarray(A), np.asarray(b))
            if index_kind == "flat":
                index = FlatIndex(rows, use_pallas=use_pallas)
            elif index_kind == "ivf":
                index = IVFIndex(rows, seed=seed, use_pallas=use_pallas)
            else:
                raise ValueError(f"unknown LP index kind {index_kind!r}")
        self.lp = _LPWorkload(A=A, b=b, cfg=cfg, index=index,
                              cost=lp_release_cost(cfg, A, index=index),
                              pending=[])

    def submit_lp(self, tenant_id: str,
                  seed: Optional[int] = None) -> ReleaseTicket:
        """Request one private LP solve for a tenant.

        Admission previews the tenant ledger with the solve's exact cost
        bundle (`lp_release_cost` — the solver's own `lp_em` /
        `approx_slack` / `index_failure` schedule) plus any still-queued
        reservations from either workload, exactly like `submit`.
        """
        if self.lp is None:
            raise ValueError("no LP workload attached; call attach_lp first")
        sess = self.sessions[tenant_id]
        decision = self.admission.check(sess, self.lp.cost,
                                        reserved=self._reserved(tenant_id))
        ticket = ReleaseTicket(
            ticket_id=self._next_ticket, tenant_id=tenant_id,
            seed=self._next_seed if seed is None else seed,
            status="queued" if decision.admitted else "rejected",
            decision=decision, kind="lp", cost_bundle=self.lp.cost,
            submit_time=monotonic(),
        )
        self._next_ticket += 1
        if seed is None:
            self._next_seed += 1
        if not decision.admitted:
            sess.rejected_count += 1
            self.stats.rejected += 1
            if obs.enabled():
                self.metrics.counter("admission_rejections_total",
                                     kind="lp", tenant=tenant_id).inc()
            return ticket
        self.lp.pending.append(ticket)
        if self.auto_flush and len(self.lp.pending) >= self.wave_size:
            self._run_lp_wave()
        return ticket

    # -------------------------------------------------------------- waves
    def pending_count(self) -> int:
        n = sum(len(g) for g in self._pending.values())
        if self.lp is not None:
            n += len(self.lp.pending)
        return n

    def flush(self) -> List[ReleaseTicket]:
        """Drain every pending group (histogram and LP) through fixed-size
        waves."""
        done: List[ReleaseTicket] = []
        for n_records in list(self._pending):
            while self._pending.get(n_records):
                done.extend(self._run_wave(n_records))
        while self.lp is not None and self.lp.pending:
            done.extend(self._run_lp_wave())
        return done

    def _lane_cost(self, sess: TenantSession, snap, per_run: PrivacyLedger,
                   k: int) -> tuple:
        """Marginal composed (ε, δ) of a tenant's (k+1)-th lane in one wave:
        replay the pre-dispatch snapshot plus k earlier lanes, then preview
        one more — a plain before/after ledger diff would double-count when
        one tenant holds several lanes."""
        tight = self.admission.tight
        ev0, g0, s0 = snap
        scratch = PrivacyLedger(
            target_delta_prime=sess.ledger.target_delta_prime)
        scratch.events = ev0 + list(per_run.events) * k
        scratch.index_failure_mass = g0 + k * per_run.index_failure_mass
        scratch.approx_slack = s0 + k * per_run.approx_slack
        before = scratch.composed(tight=tight)
        after = scratch.preview(per_run.events,
                                per_run.index_failure_mass,
                                per_run.approx_slack, tight=tight)
        return after[0] - before[0], after[1] - before[1]

    def _record_wave_metrics(self, kind: str, n_real: int, n_pad: int) -> None:
        """Per-dispatch wave health: occupancy (real lanes / wave_size) and
        the padding waste the replication trick pays for short waves."""
        if not obs.enabled():
            return
        self.metrics.counter("wave_dispatches_total", kind=kind).inc()
        self.metrics.counter("wave_padded_slots_total", kind=kind).inc(n_pad)
        self.metrics.gauge("wave_occupancy", kind=kind).set(
            n_real / self.wave_size)
        self.metrics.gauge("wave_padding_waste", kind=kind).set(
            n_pad / self.wave_size)

    def _record_ticket_latency(self, ticket: ReleaseTicket) -> None:
        """Admission→answer latency for one resolved ticket, bucketed per
        workload kind ("mwem" | "lp"); the ticket keeps its own stamp too."""
        ticket.latency_seconds = monotonic() - ticket.submit_time
        if obs.enabled():
            self.metrics.histogram("admission_to_answer_seconds",
                                   kind=ticket.kind).observe(
                                       ticket.latency_seconds)

    def _run_lp_wave(self) -> List[ReleaseTicket]:
        """Execute one LP wave: exactly ``wave_size`` seed lanes through one
        `solve_lp_batch` dispatch — the same pad-by-replication, per-lane
        ledger charging, and marginal-cost replay as histogram waves."""
        lp = self.lp
        wave = lp.pending[:self.wave_size]
        del lp.pending[:self.wave_size]
        n_pad = self.wave_size - len(wave)
        self.stats.padded_slots += n_pad
        lanes = wave + [wave[0]] * n_pad
        keys = jnp.stack([jax.random.PRNGKey(t.seed) for t in lanes])
        ledgers: List[Optional[PrivacyLedger]] = [
            self.sessions[t.tenant_id].ledger for t in wave
        ] + [None] * n_pad
        snaps = {t.tenant_id: self.sessions[t.tenant_id].ledger.bundle()
                 for t in wave}
        with obs.annotate("serve/wave/lp"):
            result = solve_lp_batch(lp.A, lp.b, lp.cfg, keys, index=lp.index,
                                    ledgers=ledgers)
        self.stats.dispatches += 1
        self._record_wave_metrics("lp", len(wave), n_pad)
        x_bar = np.asarray(result.x_bar)
        lanes_seen: Dict[str, int] = {}
        for i, ticket in enumerate(wave):
            sess = self.sessions[ticket.tenant_id]
            k = lanes_seen.get(ticket.tenant_id, 0)
            lanes_seen[ticket.tenant_id] = k + 1
            eps_cost, delta_cost = self._lane_cost(
                sess, snaps[ticket.tenant_id], result.ledger, k)
            rel = ReleasedLP(
                release_id=self._next_release,
                x_bar=x_bar[i],
                violated_frac=float(result.violated_fracs[i]),
                eps_cost=eps_cost,
                delta_cost=delta_cost,
                seed=ticket.seed,
            )
            self._next_release += 1
            sess.add_lp_release(rel)
            ticket.release = rel
            ticket.final_error = rel.violated_frac
            ticket.status = "done"
            self.stats.lp_released += 1
            self._record_ticket_latency(ticket)
        return wave

    def _run_wave(self, n_records: int) -> List[ReleaseTicket]:
        """Execute one wave: exactly ``wave_size`` lanes, one dispatch.

        Short waves are padded by replicating the first slot (same
        histogram/key shapes keep the compiled executable; pad lanes carry
        no ledger and their outputs are dropped) — the slot-reuse trick the
        LM engine uses for ragged request batches.
        """
        queue = self._pending[n_records]
        wave = queue[:self.wave_size]
        del queue[:self.wave_size]
        if not queue:
            del self._pending[n_records]
        # sharded lanes dispatch sequentially (no vmap), so padding a short
        # wave would burn a whole extra mesh run per pad slot — skip it
        n_pad = 0 if self.mesh is not None else self.wave_size - len(wave)
        self.stats.padded_slots += n_pad
        lanes = wave + [wave[0]] * n_pad
        cfg = self._group_cfg(n_records)
        h_stack = jnp.asarray(
            np.stack([self.sessions[t.tenant_id].h for t in lanes]))
        keys = jnp.stack([jax.random.PRNGKey(t.seed) for t in lanes])
        ledgers: List[Optional[PrivacyLedger]] = [
            self.sessions[t.tenant_id].ledger for t in wave
        ] + [None] * n_pad
        # pre-dispatch ledger snapshots, for per-ticket marginal costs
        snaps = {t.tenant_id: self.sessions[t.tenant_id].ledger.bundle()
                 for t in wave}
        with obs.annotate("serve/wave/mwem"):
            if self.mesh is not None:
                result = run_mwem_sharded_batch(self.workload, h_stack, cfg,
                                                keys, mesh=self.mesh,
                                                index=self.index,
                                                ledgers=ledgers)
            else:
                result = run_mwem_batch(self.workload, h_stack, cfg, keys,
                                        index=self.index, ledgers=ledgers)
        self.stats.dispatches += 1
        self._record_wave_metrics("mwem", len(wave), n_pad)
        p_hat = np.asarray(result.p_hat)
        lanes_seen: Dict[str, int] = {}
        for i, ticket in enumerate(wave):
            sess = self.sessions[ticket.tenant_id]
            k = lanes_seen.get(ticket.tenant_id, 0)
            lanes_seen[ticket.tenant_id] = k + 1
            eps_cost, delta_cost = self._lane_cost(
                sess, snaps[ticket.tenant_id], result.ledger, k)
            rel = ReleasedHistogram(
                release_id=self._next_release,
                p_hat=p_hat[i],
                final_error=float(result.final_errors[i]),
                eps_cost=eps_cost,
                delta_cost=delta_cost,
                seed=ticket.seed,
            )
            self._next_release += 1
            sess.add_release(rel)
            ticket.release = rel
            ticket.final_error = rel.final_error
            ticket.status = "done"
            self.stats.released += 1
            self._record_ticket_latency(ticket)
        return wave

    # ------------------------------------------------------------- answers
    def answer(self, tenant_id: str, q,
               release_id: Optional[int] = None) -> Answer:
        """Answer a linear query from the tenant's released histogram(s) —
        post-processing, zero additional ε; repeats served from the cache."""
        t0 = monotonic()
        ans = self.sessions[tenant_id].answer(q, release_id=release_id)
        self._record_answer(ans, t0)
        return ans

    def answer_derived(self, tenant_id: str, coeffs,
                       release_id: Optional[int] = None) -> Optional[Answer]:
        t0 = monotonic()
        ans = self.sessions[tenant_id].answer_derived(coeffs,
                                                      release_id=release_id)
        if ans is not None:
            self._record_answer(ans, t0)
        return ans

    def _record_answer(self, ans: Answer, t0: float) -> None:
        if not obs.enabled():
            return
        self.metrics.histogram("admission_to_answer_seconds",
                               kind="answer").observe(monotonic() - t0)
        name = ("answer_cache_hits_total" if ans.cached
                else "answer_cache_misses_total")
        self.metrics.counter(name).inc()

    # ------------------------------------------------------------- metrics
    def metrics_snapshot(self) -> dict:
        """Plain-dict view of the service's registry — admission→answer
        latency quantiles (p50/p95/p99) per workload kind, wave occupancy /
        padding gauges, per-tenant ε/δ-spent gauges kept consistent with
        each session ledger by its hook, cache and rejection counters, and
        the mechanism telemetry the drivers published. `benchmarks/run.py`
        embeds the same snapshot into BENCH_results.json."""
        return self.metrics.snapshot()
