"""Multi-tenant private query-release service.

The serving tier the Fast-MWEM paper makes economical: selection is
Θ(√m) per iteration, the whole T-iteration run is one fused scan, and the
vmapped batch driver releases B synthetic histograms per dispatch — so the
service coalesces pending release requests *across tenants* into fixed-size
waves (padding short waves with replica slots, like the LM engine pads
request slots) and answers read traffic from already-released histograms at
zero additional ε (post-processing).

Flow (DESIGN.md §5):

  submit ──► AdmissionController.check (ledger preview, nothing spent)
     │            │
     │ rejected ──┴──► ReleaseTicket(status="rejected", decision)
     ▼
  pending queue, grouped by n_records (a compile-time static)
     ▼ wave of exactly `wave_size` slots
  run_mwem_batch (one dispatch; per-lane ledgers charge each tenant)
     ▼
  TenantSession.releases ──► answer()/AnswerCache (zero-ε reads)

Budget reservations: a queued-but-unexecuted request already counts against
its tenant's budget at admission time (its cost bundle is held as a
reservation on the tenant *ledger* — `PrivacyLedger.reserve` — and
previewed together with it), so two requests that individually fit but
jointly overspend cannot both be admitted.

Fault tolerance (DESIGN.md §10): budget moves through a two-phase commit —
reserve at submit, commit only after the wave's results land, abort on
expiry/failure/shedding — with every transition written ahead to an
optional JSONL `Journal` so `journal.recover()` can rebuild sessions and
ledgers after a crash. Waves are exception-safe: on a retryable failure
the tickets stay at the queue head and the wave re-dispatches with capped
exponential backoff; because lanes are keyed by ``PRNGKey(ticket.seed)``,
a retried wave is bitwise identical to a clean run, so retries cost zero
additional privacy and commit exactly once. Per-ticket deadlines expire
still-queued tickets with a refunded reservation; a `CircuitBreaker`
around the kernel seams pins the service to the XLA reference route after
repeated runtime failures; and queue-depth load shedding rejects before
any reservation is taken.

Streaming mode (DESIGN.md §11): ``streaming=True`` replaces the
synchronous fixed-wave drain with a pipelined one. Requests are admitted
continuously; every `pump` tick expires overdue tickets, then a
deadline/occupancy coalescing policy (`serve.coalesce`) cuts a wave when
it is full or when the oldest ticket's latency budget is half-spent. The
wave runs on the smallest AOT-precompiled executable in a power-of-two
lane ladder (`prewarm`) instead of padding to the batch wave size, and
dispatch is split launch/finish (`core.launch_mwem_batch` /
`finish_mwem_batch`): the next wave's histogram transfer and journal
writes overlap the in-flight wave's scan, with the scan's carried state
donated inside the compiled driver. Freed slots (expiry between retry
attempts) are refilled from the queue mid-wave — the serve-engine
``free_slots`` trick promoted into the release path. Every coalescer
decision rides the ``dispatch-started`` WAL record (trigger reason, wave
size, occupancy), so `coalesce.replay_decisions` can audit a crashed
service's wave cuts. Lanes stay keyed by ``PRNGKey(ticket.seed)``:
however the policy slices the admitted set, each lane's release is
bitwise identical to the fixed-wave path (tests/test_streaming.py).

The LP workload (paper §4, DESIGN.md §6) rides the same machinery:
`attach_lp` registers a scalar-private feasibility LP (public A,
curator-held private b, one shared k-MIPS index over [A_i, b_i]);
`submit_lp` admission-gates on the solver's own `lp_release_cost` bundle
(reservations pool across both workloads), and admitted solves drain in
fixed-size waves through one `solve_lp_batch` dispatch — per-lane ledgers,
pad-by-replication, and marginal-cost replay identical to histogram waves.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accountant import PrivacyLedger
from repro.core.distributed import _data_shards, run_mwem_sharded_batch
from repro.core.lp_dual import lp_release_cost
from repro.core.lp_scalar import (LPPendingBatch, ScalarLPConfig,
                                  aot_compile_lp_batch, finish_lp_batch,
                                  launch_lp_batch, solve_lp_batch)
from repro.core.mwem import (MWEMConfig, MWEMPendingBatch, aot_compile_batch,
                             finish_mwem_batch, launch_mwem_batch,
                             release_cost, run_mwem_batch)
from repro.core.workload import as_workload
from repro.faults import fault_site
from repro.mips import (FlatAbsIndex, FlatIndex, IVFIndex, LSHIndex,
                        MarginalIVFIndex, ShardedIVFIndex,
                        augment_complement, lp_scalar_rows)
from repro.obs import trace as obs
from repro.obs.clock import monotonic, sleep
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.serve.admission import AdmissionController, AdmissionDecision
from repro.serve.breaker import CircuitBreaker
from repro.serve.coalesce import (DeadlineOccupancyPolicy, WaveDecision,
                                  WaveLadder)
from repro.serve.journal import Journal, RecoveredState, encode_bundle
from repro.serve.session import (Answer, ReleasedHistogram, ReleasedLP,
                                 TenantSession)


def _retryable(exc: BaseException) -> bool:
    """Transient-vs-programming-error classification for wave failures.

    Device/runtime faults (XLA runtime errors subclass ``RuntimeError``,
    injected `FaultInjected` faults do too, I/O hiccups are ``OSError``)
    re-dispatch; ``ValueError``/``TypeError``/``NotImplementedError`` are
    bugs or unsupported configs and propagate to the caller unchanged —
    retrying cannot fix them and would burn the backoff budget."""
    if isinstance(exc, NotImplementedError):
        return False
    return isinstance(exc, (RuntimeError, OSError))


@dataclass
class ReleaseTicket:
    """Handle returned by `submit`/`submit_lp`; resolved by the wave that
    executes it (or by a deadline/retry-limit along the way)."""

    ticket_id: int
    tenant_id: str
    seed: int
    # "queued" | "rejected" | "retrying" | "done" | "failed" | "expired"
    status: str
    decision: AdmissionDecision
    kind: str = "mwem"               # "mwem" | "lp"
    cost_bundle: tuple = ()          # (events, gamma, slack) reservation
    rid: Optional[int] = None        # ledger reservation id (until resolved)
    attempts: int = 0                # dispatch attempts that included this ticket
    deadline: Optional[float] = None  # absolute monotonic expiry, or None
    error: str = ""                  # last failure, when status == "failed"
    release: Optional[object] = None  # ReleasedHistogram | ReleasedLP
    final_error: float = float("nan")
    submit_time: float = float("nan")   # monotonic stamp at submit()
    latency_seconds: float = float("nan")  # admission → answered


@dataclass
class ServiceStats:
    dispatches: int = 0
    released: int = 0
    lp_released: int = 0
    rejected: int = 0
    padded_slots: int = 0
    retries: int = 0
    failed: int = 0
    expired: int = 0
    shed: int = 0
    refilled_slots: int = 0      # queue tickets promoted into freed lanes
    pad_slots_saved: int = 0     # pad lanes avoided by the AOT size ladder

    def as_dict(self) -> dict:
        return dict(dispatches=self.dispatches, released=self.released,
                    lp_released=self.lp_released, rejected=self.rejected,
                    padded_slots=self.padded_slots, retries=self.retries,
                    failed=self.failed, expired=self.expired, shed=self.shed,
                    refilled_slots=self.refilled_slots,
                    pad_slots_saved=self.pad_slots_saved)


@dataclass
class _LPWorkload:
    """The service's scalar-LP workload (DESIGN.md §6): public constraint
    matrix A, the curator-held private bounds b, the release config, and
    the k-MIPS index over the concatenated rows [A_i, b_i]. Tenants are
    budget principals drawing private solves against it."""

    A: jax.Array
    b: jax.Array
    cfg: ScalarLPConfig
    index: Optional[object]
    cost: tuple                      # (events, gamma, slack) per release
    pending: List[ReleaseTicket]


@dataclass
class _InflightWave:
    """One launched-but-unfinished streaming wave: the popped tickets, the
    async dispatch handle, and the journaled coalescer decision. Exactly
    one wave is in flight at a time (`ReleaseService._inflight`) — the
    double buffer: while this wave's scan runs on device, the next wave's
    host prep, transfers, and WAL writes proceed; resolving this handle is
    the only point that blocks."""

    kind: str                        # "mwem" | "lp"
    n_records: Optional[int]         # mwem group key (None for lp)
    tickets: List[ReleaseTicket]
    n_pad: int
    size: int                        # ladder executable lane count
    pending: object                  # MWEMPendingBatch | LPPendingBatch
    decision: WaveDecision
    attempt: int


class ReleaseService:
    """Coalescing, budget-admitted front end over `run_mwem_batch`.

    One service owns one query workload Q (m × U) and one k-MIPS index over
    it — tenants share the compiled wave executable and differ only in
    their histogram lane, PRNG key, and ledger. Release parameters
    (per-release ε, δ, T, mode) are fixed at construction so every wave is
    one `run_mwem_batch` dispatch of exactly ``wave_size`` lanes; requests
    from datasets of different sizes (``n_records`` is a compile-time
    static through the noise scales) batch in separate per-size groups.

    Passing a ``mesh`` puts the service on a device mesh: the index becomes
    a per-shard `ShardedIVFIndex` and waves drain through
    `run_mwem_sharded_batch` — one mesh-wide scan dispatch per lane, the
    compiled executable shared across lanes, the same per-lane ledger
    charging. Admission, sessions, and the answer cache are unchanged.
    """

    def __init__(self, Q, cfg: MWEMConfig, wave_size: int = 8,
                 index_kind: str = "flat", seed: int = 0,
                 tight_composition: bool = False, auto_flush: bool = True,
                 mesh=None, use_pallas: str = "auto",
                 registry: Optional[MetricsRegistry] = None,
                 journal: Optional[Journal] = None, retry_limit: int = 3,
                 backoff_base: float = 0.05, backoff_cap: float = 2.0,
                 default_deadline: Optional[float] = None,
                 max_queue_depth: Optional[int] = None,
                 breaker_threshold: int = 3, streaming: bool = False,
                 policy=None):
        # the workload seam: a raw (m, U) matrix or any `core.workload`
        # family — `MarginalWorkload` releases run factored end to end
        # through the same admission/cost/wave path (DESIGN.md §9)
        self.workload = as_workload(Q)
        self.Q = self.workload.Q if self.workload.is_dense else None
        self.m, self.U = self.workload.m, self.workload.U
        # where this service publishes its metrics; the process-wide
        # default registry unless the caller isolates it (tests do)
        self.metrics = registry if registry is not None else default_registry()
        # the service-level knob also drives the drivers' fused step body
        # (megakernel vs classic — DESIGN.md §7), so batched waves pick up
        # the VMEM-resident `kernels.mwem_step` route alongside the probe
        self.cfg = replace(cfg, use_pallas=use_pallas)
        self.wave_size = int(wave_size)
        self.auto_flush = auto_flush
        # a mesh routes waves through the sharded driver (one mesh-wide
        # scan dispatch per lane) instead of the vmapped fused batch
        self.mesh = mesh
        self.admission = AdmissionController(tight=tight_composition)
        self.sessions: Dict[str, TenantSession] = {}
        self.stats = ServiceStats()
        self._pending: "OrderedDict[int, List[ReleaseTicket]]" = OrderedDict()
        self.lp: Optional[_LPWorkload] = None
        self._next_ticket = 0
        self._next_release = 0
        self._next_seed = seed
        # every seed ever handed to a lane (auto or explicit) — the auto
        # counter skips issued values so two tickets can never share a PRNG
        # stream by accident (identical seeds ⇒ identical releases ⇒ the
        # second tenant pays ε for an answer the first already published)
        self._issued_seeds: set = set()
        # fault-tolerance knobs (DESIGN.md §10)
        self.journal = journal
        self.retry_limit = int(retry_limit)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.default_deadline = default_deadline
        self.max_queue_depth = max_queue_depth
        # streaming drain (DESIGN.md §11): continuous admission, the
        # deadline/occupancy coalescer cuts adaptive-size waves, dispatch
        # is pipelined launch/finish with one wave in flight
        self.streaming = bool(streaming)
        if self.streaming and mesh is not None:
            raise ValueError(
                "streaming waves are single-device: the sharded driver "
                "dispatches lanes sequentially with no launch/finish split")
        self.policy = (policy if policy is not None else
                       (DeadlineOccupancyPolicy(wave_size=self.wave_size)
                        if self.streaming else None))
        self.wave_log: List[WaveDecision] = []
        self._inflight: Optional[_InflightWave] = None
        self.degraded = False
        self.breaker = CircuitBreaker(threshold=breaker_threshold,
                                      registry=self.metrics)
        self.breaker.on_trip(self._degrade_to_ref)
        # `use_pallas` ("auto" | "always" | "never") routes the per-wave
        # probe through the fused kernels where the index supports them
        # (kernels/ivf_probe for IVF, mips_topk for flat) — "auto" falls
        # back to the XLA probe off-TPU automatically
        if cfg.mode == "fast":
            factored = not self.workload.is_dense
            if mesh is not None:
                # the sharded driver needs the per-shard structure, whatever
                # single-device kind was asked for; factored workloads
                # densify here or fail loudly (the documented fallback)
                self.index = ShardedIVFIndex(
                    self.workload.require_dense("ReleaseService[mesh]"),
                    n_shards=_data_shards(mesh)[1],
                    seed=seed, use_pallas=use_pallas)
            elif index_kind in ("ivf", "marginal_ivf") and factored:
                # the clique-structured family is the factored counterpart
                # of IVF — exact probe, no row table (DESIGN.md §9)
                self.index = MarginalIVFIndex(self.workload)
            elif index_kind == "marginal_ivf":
                raise ValueError(
                    "index_kind='marginal_ivf' needs a MarginalWorkload; "
                    "dense services use flat/ivf/lsh")
            elif index_kind == "flat":
                self.index = FlatAbsIndex(self.workload,
                                          use_pallas=use_pallas)
            elif index_kind == "ivf":
                self.index = IVFIndex(augment_complement(np.asarray(self.Q)),
                                      seed=seed, use_pallas=use_pallas)
            elif index_kind == "lsh":
                self.index = LSHIndex(
                    augment_complement(np.asarray(self.workload.require_dense(
                        "ReleaseService[lsh]"))),
                    seed=seed)
            else:
                raise ValueError(f"unknown index kind {index_kind!r}")
        else:
            self.index = None

    # ------------------------------------------------------------ sessions
    def create_session(self, tenant_id: str, *, eps_budget: float,
                       delta_budget: float, tokens=None, h=None,
                       n_records: Optional[int] = None) -> TenantSession:
        """Register a tenant: histogram from raw ``tokens`` (binned over the
        service domain U) or a pre-built normalized ``h`` + ``n_records``."""
        if tenant_id in self.sessions:
            raise ValueError(f"session {tenant_id!r} already exists")
        if tokens is not None:
            sess = TenantSession.from_tokens(tenant_id, tokens, self.U,
                                             eps_budget, delta_budget)
        else:
            if h is None or n_records is None:
                raise ValueError("provide tokens=, or h= with n_records=")
            h = np.asarray(h, np.float32)
            if h.shape != (self.U,):
                raise ValueError(f"h must have shape ({self.U},), got {h.shape}")
            sess = TenantSession(tenant_id=tenant_id, h=h,
                                 n_records=int(n_records),
                                 eps_budget=eps_budget,
                                 delta_budget=delta_budget)
        self.sessions[tenant_id] = sess
        self._register_ledger_gauges(sess)
        self._journal("session-created", tenant_id=tenant_id,
                      h=sess.h.tolist(), n_records=sess.n_records,
                      eps_budget=sess.eps_budget,
                      delta_budget=sess.delta_budget)
        return sess

    def adopt(self, recovered: RecoveredState) -> None:
        """Install sessions rebuilt by `journal.recover` into this (fresh)
        service — ledgers arrive already charged per the journal's
        committed/in-doubt records, and every counter a pre-crash record
        could collide with fast-forwards: seeds, ticket/release ids, and
        each ledger's *reservation* ids (a reused rid would let the next
        replay resolve a pre-crash in-doubt record against a post-adopt
        reservation, silently under-counting spent ε).

        If this service journals, the adopted state is re-journaled as a
        snapshot (session-created / ledger-snapshot / release-delivered
        per tenant, aborted markers for the crash's resolved rids, one
        service-snapshot) so the post-adopt WAL is self-contained: a
        second recovery — from a fresh journal file, or from the same
        file this service keeps appending to — reconstructs the adopted
        state exactly, with the old in-doubt charges carried by the
        ledger snapshot rather than re-resolved (no double charge, no
        loss)."""
        for tenant_id, sess in recovered.sessions.items():
            if tenant_id in self.sessions:
                raise ValueError(
                    f"session {tenant_id!r} already exists; adopt into a "
                    "fresh service")
            self.sessions[tenant_id] = sess
            self._register_ledger_gauges(sess)
            sess.ledger.advance_rid(recovered.next_rids.get(tenant_id, 0))
        self._issued_seeds |= set(recovered.issued_seeds)
        self._next_release = max(self._next_release,
                                 recovered.next_release_id)
        self._next_ticket = max(self._next_ticket, recovered.next_ticket_id)
        self._journal_adoption_snapshot(recovered)

    def _journal_adoption_snapshot(self, recovered: RecoveredState) -> None:
        """Re-journal adopted state (see `adopt`). Record order matters
        for same-WAL appends: each tenant's ``session-created`` resets the
        replayed session before ``ledger-snapshot``/``release-delivered``
        rebuild it, and the ``aborted`` markers resolve the pre-crash
        reservations the old records leave pending (their in-doubt charge
        already lives inside the ledger snapshot)."""
        if self.journal is None:
            return
        for tenant_id, sess in recovered.sessions.items():
            self._journal("session-created", tenant_id=tenant_id,
                          h=sess.h.tolist(), n_records=sess.n_records,
                          eps_budget=sess.eps_budget,
                          delta_budget=sess.delta_budget)
            self._journal("ledger-snapshot", tenant_id=tenant_id,
                          bundle=encode_bundle(sess.ledger.bundle()),
                          next_rid=sess.ledger.next_rid)
            for rel in sess.releases:
                self._journal("release-delivered", tenant_id=tenant_id,
                              release_kind="mwem",
                              release_id=rel.release_id, seed=rel.seed,
                              p_hat=np.asarray(rel.p_hat).tolist(),
                              final_error=rel.final_error,
                              eps_cost=rel.eps_cost,
                              delta_cost=rel.delta_cost)
            for rel in sess.lp_releases:
                self._journal("release-delivered", tenant_id=tenant_id,
                              release_kind="lp",
                              release_id=rel.release_id, seed=rel.seed,
                              x_bar=np.asarray(rel.x_bar).tolist(),
                              violated_frac=rel.violated_frac,
                              eps_cost=rel.eps_cost,
                              delta_cost=rel.delta_cost)
        for tenant_id, rid in recovered.in_doubt + recovered.refunded:
            self._journal("aborted", tenant_id=tenant_id, rid=rid,
                          reason="adoption-snapshot")
        self._journal("service-snapshot",
                      issued_seeds=sorted(self._issued_seeds),
                      next_ticket_id=self._next_ticket,
                      next_release_id=self._next_release)

    def _register_ledger_gauges(self, sess: TenantSession) -> None:
        """Hang the obs gauges off the tenant's ledger: after every
        mutating record, the per-tenant ε/δ-spent and remaining-budget
        gauges recompute from `ledger.composed()` in the service's
        composition mode — the snapshot always agrees with the ledger."""
        tight = self.admission.tight
        metrics = self.metrics

        def update(ledger, sess=sess):
            if not obs.enabled():
                return
            eps, delta = ledger.composed(tight=tight)
            labels = dict(tenant=sess.tenant_id)
            metrics.gauge("tenant_eps_spent", **labels).set(eps)
            metrics.gauge("tenant_delta_spent", **labels).set(delta)
            metrics.gauge("tenant_eps_remaining", **labels).set(
                sess.eps_budget - eps)
            metrics.gauge("tenant_delta_remaining", **labels).set(
                sess.delta_budget - delta)

        sess.ledger.add_hook(update)
        update(sess.ledger)  # publish the zero-spend baseline immediately

    def session(self, tenant_id: str) -> TenantSession:
        return self.sessions[tenant_id]

    # ------------------------------------------------------------- submit
    def _group_cfg(self, n_records: int) -> MWEMConfig:
        return replace(self.cfg, n_records=n_records)

    def _reserved(self, tenant_id: str):
        """Cost bundles of this tenant's open (phase-one) reservations —
        held on the tenant *ledger*, so they pool across both workloads: a
        queued LP solve reserves budget against a pending histogram
        release and vice versa."""
        return self.sessions[tenant_id].ledger.reserved_bundle()

    def _take_seed(self, seed: Optional[int]) -> int:
        """Issue a lane seed. Auto-issued seeds skip every seed already
        handed out (including explicit ones — the historical bug let the
        counter re-issue an explicitly-requested value); explicit seeds are
        honored verbatim and registered so the counter avoids them."""
        if seed is None:
            while self._next_seed in self._issued_seeds:
                self._next_seed += 1
            seed = self._next_seed
            self._next_seed += 1
        seed = int(seed)
        self._issued_seeds.add(seed)
        return seed

    # ----------------------------------------------------- fault tolerance
    def _journal(self, rec_kind: str, **payload) -> None:
        """Write one WAL record, riding the service's own retry/backoff
        policy: a transient append failure (full disk buffer, injected
        fault) retries; a persistent one propagates — budget transitions
        must not proceed unlogged."""
        if self.journal is None:
            return
        for attempt in range(self.retry_limit + 1):
            try:
                self.journal.append(rec_kind, **payload)
                return
            except Exception as exc:
                if not _retryable(exc) or attempt >= self.retry_limit:
                    raise
                self._backoff(attempt)

    def _backoff(self, attempt: int) -> None:
        sleep(min(self.backoff_cap, self.backoff_base * (2.0 ** attempt)))

    def _abort_ticket(self, ticket: ReleaseTicket, reason: str,
                      status: str) -> None:
        """Refund a ticket's phase-one reservation and resolve the ticket
        (``status`` ∈ {"expired", "failed"})."""
        if ticket.rid is not None:
            self.sessions[ticket.tenant_id].ledger.abort(ticket.rid)
            self._journal("aborted", tenant_id=ticket.tenant_id,
                          rid=ticket.rid, reason=reason)
            ticket.rid = None
        ticket.status = status
        if obs.enabled():
            self.metrics.counter("reservations_aborted_total",
                                 reason=reason).inc()

    def _expire_deadlines(self, queue: List[ReleaseTicket]) -> None:
        """Expire still-queued tickets past their deadline: the reservation
        is refunded in full — nothing ran, no randomness was realized, so
        the refund leaks nothing."""
        now = monotonic()
        expired = [t for t in queue
                   if t.deadline is not None and now >= t.deadline]
        for t in expired:
            queue.remove(t)
            self._abort_ticket(t, reason="expired", status="expired")
            self.stats.expired += 1

    def _commit_ticket(self, ticket: ReleaseTicket) -> None:
        """Phase two for one delivered lane. `PrivacyLedger.commit` checks
        its fault site *before* popping the reservation, so a failed
        attempt leaves the reservation intact and the retry commits exactly
        once. The journal record lands *after* the ledger moves: if the
        process dies in between, recovery's in-doubt rule (dispatched, no
        resolution ⇒ committed) reconstructs the same ledger state."""
        sess = self.sessions[ticket.tenant_id]
        for attempt in range(self.retry_limit + 1):
            try:
                sess.ledger.commit(ticket.rid)
                break
            except KeyError:
                raise
            except Exception as exc:
                if not _retryable(exc) or attempt >= self.retry_limit:
                    raise
                self._backoff(attempt)
        rid, ticket.rid = ticket.rid, None
        self._journal("committed", tenant_id=ticket.tenant_id, rid=rid)

    def _note_dispatch_failure(self, exc: BaseException,
                               wave: List[ReleaseTicket], attempt: int,
                               kind: str) -> bool:
        """Account one failed wave attempt; returns True iff the wave
        should re-dispatch (retryable and under the retry budget)."""
        site = getattr(exc, "site", "wave.dispatch")
        if obs.enabled():
            self.metrics.counter("dispatch_failures_total", site=site).inc()
        # failures only count toward the breaker while the Pallas route is
        # still live — once degraded to the reference path, further faults
        # are not the kernels' doing; neither are WAL write failures, which
        # pinning to the reference route could never fix
        if self.cfg.use_pallas != "never" and site != "journal.append":
            self.breaker.record_failure()
        retry = _retryable(exc) and attempt <= self.retry_limit
        for t in wave:
            t.attempts += 1
            t.error = repr(exc)
            t.status = "retrying" if retry else "failed"
        if retry:
            self.stats.retries += 1
            if obs.enabled():
                self.metrics.counter("wave_retries_total", kind=kind).inc()
            self._backoff(attempt - 1)
        return retry

    def _fail_wave(self, wave: List[ReleaseTicket],
                   exc: BaseException) -> None:
        """Resolve a wave that exhausted its retries (or hit a
        programming error): reservations are refunded — the dispatch never
        produced output, so no randomness escaped and the refund is safe."""
        for t in wave:
            self._abort_ticket(t, reason="failed", status="failed")
            t.error = repr(exc)
        self.stats.failed += len(wave)

    def _resolve_stranded(self, tickets: List[ReleaseTicket],
                          exc: BaseException) -> None:
        """Resolve tickets a phase-two failure would otherwise strand.

        The delivery loop runs after the wave was popped from the queue,
        so a ticket it leaves unresolved would hold its reservation open
        forever — a live budget leak. Open reservations are refunded
        (their outputs are dropped undelivered, so nothing escaped),
        best-effort: when the journal is itself the failure, the WAL
        ``aborted`` record may not land, and recovery's in-doubt rule then
        re-charges the rid — a conservative overcharge, never a leak. A
        ticket whose ledger commit landed but whose ``committed`` record
        didn't (rid already cleared) stays charged, matching the same
        rule."""
        for t in tickets:
            if t.status == "done":
                continue
            try:
                if t.rid is not None:
                    self._abort_ticket(t, reason="commit-failed",
                                       status="failed")
                else:
                    t.status = "failed"
            except Exception:
                t.rid = None
                t.status = "failed"
            t.error = repr(exc)
            self.stats.failed += 1

    def _degrade_to_ref(self) -> None:
        """Breaker trip: pin the service to the XLA reference route. The
        megakernel and classic paths are bitwise-identical (DESIGN.md §7),
        so degradation changes throughput, never answers."""
        self.cfg = replace(self.cfg, use_pallas="never")
        indexes = [self.index]
        if self.lp is not None:
            indexes.append(self.lp.index)
        for idx in indexes:
            if idx is not None:
                # the fused drivers key their executable caches on this
                # attribute, so flipping it re-routes cleanly
                idx._use_pallas = "never"
        self.degraded = True
        if obs.enabled():
            self.metrics.counter("service_degraded_total").inc()

    def _shed_check(self, tenant_id: str,
                    kind: str) -> Optional[ReleaseTicket]:
        """Queue-depth load shedding: reject before any seed is issued or
        reservation taken, so a shed request is free to retry later."""
        if self.max_queue_depth is None:
            return None
        depth = self.pending_count()
        if depth < self.max_queue_depth:
            return None
        sess = self.sessions[tenant_id]
        decision = AdmissionDecision(
            admitted=False, tenant_id=tenant_id,
            eps_projected=float("nan"), delta_projected=float("nan"),
            eps_budget=sess.eps_budget, delta_budget=sess.delta_budget,
            eps_cost=float("nan"), delta_cost=float("nan"),
            reason=f"load shed: queue depth {depth} >= "
                   f"{self.max_queue_depth}")
        ticket = ReleaseTicket(
            ticket_id=self._next_ticket, tenant_id=tenant_id, seed=-1,
            status="rejected", decision=decision, kind=kind,
            submit_time=monotonic())
        self._next_ticket += 1
        self.stats.shed += 1
        if obs.enabled():
            self.metrics.counter("load_shed_total", kind=kind).inc()
        return ticket

    def submit(self, tenant_id: str, seed: Optional[int] = None,
               deadline: Optional[float] = None) -> ReleaseTicket:
        """Request one release for a tenant.

        Admission previews the tenant ledger with the release's exact cost
        bundle (plus any still-open reservations) appended; over-budget
        requests are rejected *before* anything is spent, with the
        projected composed (ε, δ) reported on the decision. Admitted
        requests take a phase-one ledger reservation (journaled) that a
        successful wave commits and an expiry/failure refunds.
        ``deadline`` (seconds from now; falls back to the service's
        ``default_deadline``) expires the ticket if it is still queued when
        a wave next drains.
        """
        shed = self._shed_check(tenant_id, kind="mwem")
        if shed is not None:
            return shed
        sess = self.sessions[tenant_id]
        cfg = self._group_cfg(sess.n_records)
        bundle = release_cost(cfg, self.m, self.U, index=self.index)
        decision = self.admission.check(sess, bundle,
                                        reserved=self._reserved(tenant_id))
        ticket = ReleaseTicket(
            ticket_id=self._next_ticket, tenant_id=tenant_id,
            seed=self._take_seed(seed),
            status="queued" if decision.admitted else "rejected",
            decision=decision, cost_bundle=bundle,
            submit_time=monotonic(),
        )
        self._next_ticket += 1
        if not decision.admitted:
            sess.rejected_count += 1
            self.stats.rejected += 1
            if obs.enabled():
                self.metrics.counter("admission_rejections_total",
                                     kind="mwem", tenant=tenant_id).inc()
            return ticket
        ticket.rid = sess.ledger.reserve(*bundle)
        d = deadline if deadline is not None else self.default_deadline
        if d is not None:
            ticket.deadline = ticket.submit_time + d
        try:
            self._journal("reserved", tenant_id=tenant_id, rid=ticket.rid,
                          ticket_id=ticket.ticket_id, workload="mwem",
                          seed=ticket.seed, bundle=encode_bundle(bundle))
        except Exception:
            # an unjournaled reservation must not outlive the failed
            # submit — the ticket never queues, so nothing would ever
            # commit or abort it: refund so the raise is budget-neutral
            sess.ledger.abort(ticket.rid)
            ticket.rid = None
            ticket.status = "failed"
            raise
        self._pending.setdefault(sess.n_records, []).append(ticket)
        if self.streaming:
            if self.auto_flush:
                self.pump()
        elif self.auto_flush and len(self._pending[sess.n_records]) >= self.wave_size:
            self._run_wave(sess.n_records)
        return ticket

    # ----------------------------------------------------------------- LP
    def attach_lp(self, A, b, cfg: Optional[ScalarLPConfig] = None,
                  index_kind: str = "flat", seed: int = 0,
                  use_pallas: str = "auto") -> None:
        """Register the service's scalar-LP workload (paper §4.1).

        ``A`` is the public constraint matrix, ``b`` the curator-held
        private bounds (Δ∞ sensitivity); tenants draw private solves
        against their budgets via `submit_lp`. Fast mode builds the k-MIPS
        index over the concatenated rows [A_i, b_i] once, here — every LP
        wave shares it and the compiled `solve_lp_batch` executable.
        """
        if self.lp is not None:
            raise ValueError("an LP workload is already attached")
        if self.mesh is not None:
            raise ValueError("LP waves are not mesh-sharded; attach to an "
                             "off-mesh service")
        A = jnp.asarray(A, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        cfg = cfg or ScalarLPConfig()
        if cfg.driver == "host":
            # refuse now, not at wave time: _run_lp_wave pops its tickets
            # before dispatching, so a late solve_lp_batch rejection would
            # strand admitted (budget-reserved) requests
            raise ValueError("LP waves run the fused batch driver; "
                             "cfg.driver='host' cannot serve")
        index = None
        if cfg.mode == "fast":
            rows = lp_scalar_rows(np.asarray(A), np.asarray(b))
            if index_kind == "flat":
                index = FlatIndex(rows, use_pallas=use_pallas)
            elif index_kind == "ivf":
                index = IVFIndex(rows, seed=seed, use_pallas=use_pallas)
            else:
                raise ValueError(f"unknown LP index kind {index_kind!r}")
        self.lp = _LPWorkload(A=A, b=b, cfg=cfg, index=index,
                              cost=lp_release_cost(cfg, A, index=index),
                              pending=[])

    def submit_lp(self, tenant_id: str, seed: Optional[int] = None,
                  deadline: Optional[float] = None) -> ReleaseTicket:
        """Request one private LP solve for a tenant.

        Admission previews the tenant ledger with the solve's exact cost
        bundle (`lp_release_cost` — the solver's own `lp_em` /
        `approx_slack` / `index_failure` schedule) plus any still-open
        reservations from either workload, exactly like `submit`; admitted
        solves take the same journaled phase-one reservation.
        """
        if self.lp is None:
            raise ValueError("no LP workload attached; call attach_lp first")
        shed = self._shed_check(tenant_id, kind="lp")
        if shed is not None:
            return shed
        sess = self.sessions[tenant_id]
        decision = self.admission.check(sess, self.lp.cost,
                                        reserved=self._reserved(tenant_id))
        ticket = ReleaseTicket(
            ticket_id=self._next_ticket, tenant_id=tenant_id,
            seed=self._take_seed(seed),
            status="queued" if decision.admitted else "rejected",
            decision=decision, kind="lp", cost_bundle=self.lp.cost,
            submit_time=monotonic(),
        )
        self._next_ticket += 1
        if not decision.admitted:
            sess.rejected_count += 1
            self.stats.rejected += 1
            if obs.enabled():
                self.metrics.counter("admission_rejections_total",
                                     kind="lp", tenant=tenant_id).inc()
            return ticket
        ticket.rid = sess.ledger.reserve(*self.lp.cost)
        d = deadline if deadline is not None else self.default_deadline
        if d is not None:
            ticket.deadline = ticket.submit_time + d
        try:
            self._journal("reserved", tenant_id=tenant_id, rid=ticket.rid,
                          ticket_id=ticket.ticket_id, workload="lp",
                          seed=ticket.seed,
                          bundle=encode_bundle(self.lp.cost))
        except Exception:
            # see submit(): a failed submit must be budget-neutral
            sess.ledger.abort(ticket.rid)
            ticket.rid = None
            ticket.status = "failed"
            raise
        self.lp.pending.append(ticket)
        if self.streaming:
            if self.auto_flush:
                self.pump()
        elif self.auto_flush and len(self.lp.pending) >= self.wave_size:
            self._run_lp_wave()
        return ticket

    # -------------------------------------------------------------- waves
    def pending_count(self) -> int:
        n = sum(len(g) for g in self._pending.values())
        if self.lp is not None:
            n += len(self.lp.pending)
        return n

    def flush(self) -> List[ReleaseTicket]:
        """Drain every pending group (histogram and LP). Batch mode drains
        through fixed-size waves; streaming mode force-pumps the coalescer
        (reason "flush") until every queue and the in-flight wave are
        resolved."""
        if self.streaming:
            done: List[ReleaseTicket] = []
            while True:
                done.extend(self.pump(force=True))
                if (self._inflight is None
                        and not any(self._pending.values())
                        and (self.lp is None or not self.lp.pending)):
                    return done
        done = []
        for n_records in list(self._pending):
            while self._pending.get(n_records):
                done.extend(self._run_wave(n_records))
        while self.lp is not None and self.lp.pending:
            done.extend(self._run_lp_wave())
        return done

    # -------------------------------------------------- streaming pipeline
    def _ladder(self) -> WaveLadder:
        if self.policy is not None and getattr(self.policy, "ladder", None):
            return self.policy.ladder
        return WaveLadder.for_wave_size(self.wave_size)

    def prewarm(self, n_records: Optional[int] = None,
                lp: bool = False) -> Dict[int, bool]:
        """AOT-compile the wave-size ladder ahead of traffic.

        One executable per ladder lane count lands in the batched driver's
        cache (`core.aot_compile_batch`), so streaming waves pick the
        smallest compiled size that fits their occupancy with zero
        first-wave trace+compile cost. Histogram executables are keyed by
        ``n_records`` (a compile-time static through the noise scales) —
        pass it, or omit it to prewarm every registered session's group.
        Returns {lane_count: newly_compiled}.
        """
        ladder = self._ladder()
        out: Dict[int, bool] = {}
        if lp:
            if self.lp is None:
                raise ValueError("no LP workload attached; call attach_lp "
                                 "first")
            for s in ladder.sizes:
                out[s] = aot_compile_lp_batch(self.lp.A, self.lp.b,
                                              self.lp.cfg, s,
                                              index=self.lp.index)
            return out
        groups = ([n_records] if n_records is not None
                  else sorted({s.n_records for s in self.sessions.values()}))
        for n in groups:
            cfg = self._group_cfg(n)
            for s in ladder.sizes:
                compiled = aot_compile_batch(self.workload, cfg, s,
                                             index=self.index)
                out[s] = out.get(s, False) or compiled
        return out

    def pump(self, force: bool = False) -> List[ReleaseTicket]:
        """One coalescer tick.

        Every tick — batch or streaming — expires overdue tickets in all
        queues and refunds their reservations (the PR 10 fix: expiry used
        to run only inside the wave drains, so under continuous admission
        a ticket could sit past its deadline forever while no wave
        formed). In streaming mode the tick then asks the policy, per
        compatible group, whether to cut a wave; cut waves launch
        asynchronously and the previously in-flight wave resolves while
        the new one runs. A ready (or ``force``-drained) in-flight wave is
        resolved at the end of the tick; otherwise it stays in flight and
        the next tick collects it. Returns tickets resolved this tick.
        """
        done: List[ReleaseTicket] = []
        for n_records in list(self._pending):
            queue = self._pending[n_records]
            self._expire_deadlines(queue)
            if not queue:
                del self._pending[n_records]
        if self.lp is not None:
            self._expire_deadlines(self.lp.pending)
        if not self.streaming:
            return done
        for n_records in list(self._pending):
            done.extend(self._pump_queue("mwem", n_records, force))
        if self.lp is not None and self.lp.pending:
            done.extend(self._pump_queue("lp", None, force))
        if self._inflight is not None and (force or self._inflight_ready()):
            done.extend(self._resolve_inflight())
        return done

    def _pump_queue(self, kind: str, n_records: Optional[int],
                    force: bool) -> List[ReleaseTicket]:
        """Coalesce one queue: policy decision → pop → async launch →
        resolve the previous in-flight wave while the new one runs."""
        done: List[ReleaseTicket] = []
        queue = (self.lp.pending if kind == "lp"
                 else self._pending.get(n_records))
        while queue:
            self._expire_deadlines(queue)
            if not queue:
                break
            oldest = queue[0]
            decision = self.policy.decide(
                len(queue), monotonic(),
                oldest_submit=oldest.submit_time,
                oldest_deadline=oldest.deadline,
                force=force)
            if obs.enabled():
                self.metrics.gauge("coalescer_occupancy", kind=kind).set(
                    decision.occupancy)
                self.metrics.counter("wave_trigger_total", kind=kind,
                                     reason=decision.reason).inc()
            if not decision.dispatch:
                break
            take = min(len(queue), decision.wave_size, decision.occupancy)
            wave = queue[:take]
            del queue[:take]
            inflight = self._launch_streaming(kind, n_records, wave, decision)
            prev, self._inflight = self._inflight, inflight
            if prev is not None:
                # the new wave's scan is already running on device — this
                # block only waits on the *previous* wave (double buffer)
                done.extend(self._resolve_wave(prev))
        if kind == "mwem" and not self._pending.get(n_records):
            self._pending.pop(n_records, None)
        return done

    def _refill_wave(self, kind: str, wave: List[ReleaseTicket],
                     queue: List[ReleaseTicket]) -> None:
        """Between dispatch attempts: expire overdue in-wave tickets (the
        failed attempt produced nothing, so the refund leaks nothing) and
        promote queued tickets into the freed lanes — the serve-engine
        ``free_slots`` mid-wave refill lifted into the release path."""
        target = len(wave)
        now = monotonic()
        for t in list(wave):
            if t.deadline is not None and now >= t.deadline:
                wave.remove(t)
                self._abort_ticket(t, reason="expired", status="expired")
                self.stats.expired += 1
        while queue and len(wave) < target:
            t = queue.pop(0)
            if t.deadline is not None and now >= t.deadline:
                self._abort_ticket(t, reason="expired", status="expired")
                self.stats.expired += 1
                continue
            t.status = "queued"
            wave.append(t)
            self.stats.refilled_slots += 1
            if obs.enabled():
                self.metrics.counter("wave_slot_refills_total",
                                     kind=kind).inc()

    def _launch_streaming(self, kind: str, n_records: Optional[int],
                          wave: List[ReleaseTicket], decision: WaveDecision,
                          attempt: int = 0) -> Optional[_InflightWave]:
        """Journal and asynchronously dispatch one streaming wave on the
        smallest fitting ladder executable. Returns the in-flight handle,
        or None when every slot expired away or the dispatch failed
        terminally (tickets already resolved, reservations refunded)."""
        queue = (self.lp.pending if kind == "lp"
                 else self._pending.get(n_records, []))
        while True:
            if attempt > 0:
                self._refill_wave(kind, wave, queue)
            if not wave:
                return None
            size = min(self._ladder().fit(len(wave)), decision.wave_size)
            n_pad = size - len(wave)
            lanes = wave + [wave[0]] * n_pad
            keys = jnp.stack([jax.random.PRNGKey(t.seed) for t in lanes])
            # the decision rides the WAL record (trigger/wave_size/
            # occupancy) so `coalesce.replay_decisions` can rebuild the
            # coalescer's cuts from the journal alone; outside the
            # breaker-attributed try — see _run_lp_wave
            self._journal("dispatch-started", workload=kind, attempt=attempt,
                          rids=[[t.tenant_id, t.rid] for t in wave],
                          trigger=decision.reason, wave_size=size,
                          occupancy=decision.occupancy)
            self.wave_log.append(WaveDecision(True, decision.reason, size,
                                              decision.occupancy))
            try:
                with obs.annotate(f"serve/wave/{kind}/stream"):
                    fault_site("wave.dispatch")
                    if kind == "lp":
                        pending = launch_lp_batch(self.lp.A, self.lp.b,
                                                  self.lp.cfg, keys,
                                                  index=self.lp.index)
                    else:
                        # device_put starts the histogram transfer now, so
                        # it overlaps the still-running previous wave; the
                        # scan's carried state is donated inside the
                        # compiled driver (core._fused_driver)
                        h_stack = jax.device_put(np.stack(
                            [self.sessions[t.tenant_id].h for t in lanes]))
                        pending = launch_mwem_batch(
                            self.workload, h_stack,
                            self._group_cfg(n_records), keys,
                            index=self.index)
            except Exception as exc:
                attempt += 1
                if self._note_dispatch_failure(exc, wave, attempt, kind):
                    continue
                self._fail_wave(wave, exc)
                if not _retryable(exc):
                    raise
                return None
            return _InflightWave(kind=kind, n_records=n_records,
                                 tickets=wave, n_pad=n_pad, size=size,
                                 pending=pending,
                                 decision=WaveDecision(
                                     True, decision.reason, size,
                                     decision.occupancy),
                                 attempt=attempt)

    def _inflight_ready(self) -> bool:
        """Whether the in-flight wave's device work has landed (so
        resolving it will not block). Falls back to "ready" when the array
        type cannot say — resolving then blocks, which is correct, just
        not overlapped."""
        fl = self._inflight
        if fl is None:
            return False
        arr = (fl.pending.x_bar if fl.kind == "lp"
               else fl.pending.final_state.p_sum)
        is_ready = getattr(arr, "is_ready", None)
        return True if is_ready is None else bool(is_ready())

    def _resolve_inflight(self) -> List[ReleaseTicket]:
        fl, self._inflight = self._inflight, None
        if fl is None:
            return []
        return self._resolve_wave(fl)

    def _resolve_wave(self, fl: _InflightWave) -> List[ReleaseTicket]:
        """Block on one launched wave and run phase two. A retryable
        finish failure re-*launches* the wave (a failed computation cannot
        be re-blocked) with freed slots refilled; lanes are keyed by
        ``PRNGKey(ticket.seed)``, so the relaunch is bitwise identical and
        costs zero additional privacy — same contract as the batch retry
        loop."""
        while True:
            try:
                with obs.annotate(f"serve/wave/{fl.kind}/finish"):
                    if fl.kind == "lp":
                        result = finish_lp_batch(fl.pending)
                    else:
                        result = finish_mwem_batch(fl.pending)
            except Exception as exc:
                fl.attempt += 1
                if self._note_dispatch_failure(exc, fl.tickets, fl.attempt,
                                               fl.kind):
                    relaunched = self._launch_streaming(
                        fl.kind, fl.n_records, fl.tickets, fl.decision,
                        attempt=fl.attempt)
                    if relaunched is None:
                        return []
                    fl = relaunched
                    continue
                self._fail_wave(fl.tickets, exc)
                if not _retryable(exc):
                    raise
                return []
            break
        self.breaker.record_success()
        self.stats.dispatches += 1
        self.stats.padded_slots += fl.n_pad
        saved = self.wave_size - fl.size
        if saved > 0:
            # lanes the fixed-size path would have padded by replication
            self.stats.pad_slots_saved += saved
            if obs.enabled():
                self.metrics.counter("wave_pad_slots_saved_total",
                                     kind=fl.kind).inc(saved)
        self._record_wave_metrics(fl.kind, len(fl.tickets), fl.n_pad,
                                  lanes=fl.size)
        if obs.enabled():
            self.metrics.histogram("wave_latency_seconds", kind=fl.kind,
                                   lanes=fl.size).observe(
                                       result.total_seconds)
        if fl.kind == "lp":
            return self._deliver_lp(fl.tickets, result,
                                    trigger=fl.decision.reason)
        return self._deliver_mwem(fl.tickets, result,
                                  trigger=fl.decision.reason)

    def _lane_cost(self, sess: TenantSession, snap, per_run: PrivacyLedger,
                   k: int) -> tuple:
        """Marginal composed (ε, δ) of a tenant's (k+1)-th lane in one wave:
        replay the pre-dispatch snapshot plus k earlier lanes, then preview
        one more — a plain before/after ledger diff would double-count when
        one tenant holds several lanes."""
        tight = self.admission.tight
        ev0, g0, s0 = snap
        scratch = PrivacyLedger(
            target_delta_prime=sess.ledger.target_delta_prime)
        scratch.events = ev0 + list(per_run.events) * k
        scratch.index_failure_mass = g0 + k * per_run.index_failure_mass
        scratch.approx_slack = s0 + k * per_run.approx_slack
        before = scratch.composed(tight=tight)
        after = scratch.preview(per_run.events,
                                per_run.index_failure_mass,
                                per_run.approx_slack, tight=tight)
        return after[0] - before[0], after[1] - before[1]

    def _record_wave_metrics(self, kind: str, n_real: int, n_pad: int,
                             lanes: Optional[int] = None) -> None:
        """Per-dispatch wave health: occupancy (real lanes / executed
        lanes) and the padding waste the replication trick pays for short
        waves. ``lanes`` is the executed executable width — the adaptive
        ladder size in streaming mode, ``wave_size`` in batch mode."""
        if not obs.enabled():
            return
        lanes = lanes if lanes is not None else self.wave_size
        self.metrics.counter("wave_dispatches_total", kind=kind).inc()
        self.metrics.counter("wave_padded_slots_total", kind=kind).inc(n_pad)
        self.metrics.gauge("wave_occupancy", kind=kind).set(n_real / lanes)
        self.metrics.gauge("wave_padding_waste", kind=kind).set(n_pad / lanes)

    def _record_ticket_latency(self, ticket: ReleaseTicket,
                               trigger: Optional[str] = None) -> None:
        """Admission→answer latency for one resolved ticket, bucketed per
        workload kind ("mwem" | "lp"); the ticket keeps its own stamp too.
        Streaming waves pass the coalescer ``trigger`` so the distribution
        also splits by why the wave was cut (full vs deadline vs flush) —
        on a separate series, so the per-kind one batch mode populates
        keeps its identity."""
        ticket.latency_seconds = monotonic() - ticket.submit_time
        if obs.enabled():
            self.metrics.histogram("admission_to_answer_seconds",
                                   kind=ticket.kind).observe(
                                       ticket.latency_seconds)
            if trigger is not None:
                self.metrics.histogram("admission_to_answer_seconds",
                                       kind=ticket.kind,
                                       trigger=trigger).observe(
                                           ticket.latency_seconds)

    def _run_lp_wave(self) -> List[ReleaseTicket]:
        """Execute one LP wave: exactly ``wave_size`` seed lanes through one
        `solve_lp_batch` dispatch — the same pad-by-replication, retry
        discipline, two-phase commit, and marginal-cost replay as
        histogram waves (see `_run_wave`)."""
        lp = self.lp
        attempt = 0
        while True:
            self._expire_deadlines(lp.pending)
            if not lp.pending:
                return []
            # peek, don't pop: a failed dispatch leaves the tickets at the
            # queue head for the retry
            wave = lp.pending[:self.wave_size]
            n_pad = self.wave_size - len(wave)
            lanes = wave + [wave[0]] * n_pad
            keys = jnp.stack([jax.random.PRNGKey(t.seed) for t in lanes])
            # outside the breaker-attributed try: a WAL failure is not the
            # kernels' doing — it rides _journal's own retry policy, and a
            # persistent one propagates with the queue and reservations
            # intact (tickets were only peeked) instead of tripping the
            # breaker into a permanent degrade
            self._journal("dispatch-started", workload="lp",
                          attempt=attempt,
                          rids=[[t.tenant_id, t.rid] for t in wave])
            try:
                with obs.annotate("serve/wave/lp"):
                    fault_site("wave.dispatch")
                    result = solve_lp_batch(lp.A, lp.b, lp.cfg, keys,
                                            index=lp.index)
            except Exception as exc:
                attempt += 1
                if self._note_dispatch_failure(exc, wave, attempt, "lp"):
                    continue
                del lp.pending[:len(wave)]
                self._fail_wave(wave, exc)
                if not _retryable(exc):
                    raise
                return []
            self.breaker.record_success()
            break
        del lp.pending[:len(wave)]
        self.stats.padded_slots += n_pad
        self.stats.dispatches += 1
        self._record_wave_metrics("lp", len(wave), n_pad)
        return self._deliver_lp(wave, result)

    def _deliver_lp(self, wave: List[ReleaseTicket], result,
                    trigger: Optional[str] = None) -> List[ReleaseTicket]:
        """Phase two for an executed LP wave: per-ticket commit, marginal
        cost replay, journaled delivery. Shared verbatim between the batch
        drain and the streaming pipeline (``trigger`` is the coalescer
        reason, streaming only), so the two paths cannot drift."""
        # pre-commit ledger snapshots, for per-ticket marginal costs
        snaps = {t.tenant_id: self.sessions[t.tenant_id].ledger.bundle()
                 for t in wave}
        x_bar = np.asarray(result.x_bar)
        lanes_seen: Dict[str, int] = {}
        for i, ticket in enumerate(wave):
            # phase two per ticket, exception-safe: a commit/journal
            # failure fails *this* ticket (refunding its still-open
            # reservation) and moves on; a programming error fails the
            # rest of the wave too, then propagates — either way no
            # popped ticket is left stranded with a reservation held
            try:
                sess = self.sessions[ticket.tenant_id]
                self._commit_ticket(ticket)
                k = lanes_seen.get(ticket.tenant_id, 0)
                lanes_seen[ticket.tenant_id] = k + 1
                eps_cost, delta_cost = self._lane_cost(
                    sess, snaps[ticket.tenant_id], result.ledger, k)
                rel = ReleasedLP(
                    release_id=self._next_release,
                    x_bar=x_bar[i],
                    violated_frac=float(result.violated_fracs[i]),
                    eps_cost=eps_cost,
                    delta_cost=delta_cost,
                    seed=ticket.seed,
                )
                self._next_release += 1
                # WAL before state: if the delivery record can't land,
                # the session must not keep an artifact recovery would
                # lose (the charge stands either way — in-doubt rule)
                self._journal("release-delivered",
                              tenant_id=ticket.tenant_id,
                              ticket_id=ticket.ticket_id, release_kind="lp",
                              release_id=rel.release_id, seed=ticket.seed,
                              x_bar=x_bar[i].tolist(),
                              violated_frac=rel.violated_frac,
                              eps_cost=eps_cost, delta_cost=delta_cost)
                sess.add_lp_release(rel)
                ticket.release = rel
                ticket.final_error = rel.violated_frac
                ticket.status = "done"
                self.stats.lp_released += 1
                self._record_ticket_latency(ticket, trigger)
            except Exception as exc:
                if not _retryable(exc):
                    self._resolve_stranded(wave[i:], exc)
                    raise
                self._resolve_stranded([ticket], exc)
        return wave

    def _run_wave(self, n_records: int) -> List[ReleaseTicket]:
        """Execute one wave: exactly ``wave_size`` lanes, one dispatch.

        Short waves are padded by replicating the first slot (same
        histogram/key shapes keep the compiled executable; pad lanes carry
        no budget reservation and their outputs are dropped) — the
        slot-reuse trick the LM engine uses for ragged request batches.

        Exception safety (DESIGN.md §10): tickets are *peeked*, not
        popped. A retryable dispatch failure leaves them at the queue head
        and re-dispatches after capped exponential backoff; since every
        lane is keyed by ``PRNGKey(ticket.seed)``, the retry realizes
        bitwise-identical noise, so it costs zero additional privacy and
        commits exactly once. Budget commits only after the wave's results
        land — each lane's phase-one reservation is committed per ticket,
        then the delivered artifact is journaled.
        """
        queue = self._pending[n_records]
        attempt = 0
        while True:
            self._expire_deadlines(queue)
            if not queue:
                del self._pending[n_records]
                return []
            # peek, don't pop: a failed dispatch leaves the tickets at the
            # queue head for the retry
            wave = queue[:self.wave_size]
            # sharded lanes dispatch sequentially (no vmap), so padding a
            # short wave would burn a whole extra mesh run per pad slot
            n_pad = 0 if self.mesh is not None else self.wave_size - len(wave)
            lanes = wave + [wave[0]] * n_pad
            cfg = self._group_cfg(n_records)
            h_stack = jnp.asarray(
                np.stack([self.sessions[t.tenant_id].h for t in lanes]))
            keys = jnp.stack([jax.random.PRNGKey(t.seed) for t in lanes])
            # outside the breaker-attributed try — see _run_lp_wave
            self._journal("dispatch-started", workload="mwem",
                          attempt=attempt,
                          rids=[[t.tenant_id, t.rid] for t in wave])
            try:
                with obs.annotate("serve/wave/mwem"):
                    fault_site("wave.dispatch")
                    if self.mesh is not None:
                        result = run_mwem_sharded_batch(
                            self.workload, h_stack, cfg, keys,
                            mesh=self.mesh, index=self.index)
                    else:
                        result = run_mwem_batch(self.workload, h_stack, cfg,
                                                keys, index=self.index)
            except Exception as exc:
                attempt += 1
                if self._note_dispatch_failure(exc, wave, attempt, "mwem"):
                    continue
                del queue[:len(wave)]
                if not queue:
                    del self._pending[n_records]
                self._fail_wave(wave, exc)
                if not _retryable(exc):
                    raise
                return []
            self.breaker.record_success()
            break
        del queue[:len(wave)]
        if not queue:
            del self._pending[n_records]
        self.stats.padded_slots += n_pad
        self.stats.dispatches += 1
        self._record_wave_metrics("mwem", len(wave), n_pad)
        return self._deliver_mwem(wave, result)

    def _deliver_mwem(self, wave: List[ReleaseTicket], result,
                      trigger: Optional[str] = None) -> List[ReleaseTicket]:
        """Phase two for an executed histogram wave — see `_deliver_lp`."""
        # pre-commit ledger snapshots, for per-ticket marginal costs
        snaps = {t.tenant_id: self.sessions[t.tenant_id].ledger.bundle()
                 for t in wave}
        p_hat = np.asarray(result.p_hat)
        lanes_seen: Dict[str, int] = {}
        for i, ticket in enumerate(wave):
            # exception-safe phase two — see _run_lp_wave
            try:
                sess = self.sessions[ticket.tenant_id]
                self._commit_ticket(ticket)
                k = lanes_seen.get(ticket.tenant_id, 0)
                lanes_seen[ticket.tenant_id] = k + 1
                eps_cost, delta_cost = self._lane_cost(
                    sess, snaps[ticket.tenant_id], result.ledger, k)
                rel = ReleasedHistogram(
                    release_id=self._next_release,
                    p_hat=p_hat[i],
                    final_error=float(result.final_errors[i]),
                    eps_cost=eps_cost,
                    delta_cost=delta_cost,
                    seed=ticket.seed,
                )
                self._next_release += 1
                # WAL before state — see _run_lp_wave
                self._journal("release-delivered",
                              tenant_id=ticket.tenant_id,
                              ticket_id=ticket.ticket_id,
                              release_kind="mwem",
                              release_id=rel.release_id, seed=ticket.seed,
                              p_hat=p_hat[i].tolist(),
                              final_error=rel.final_error,
                              eps_cost=eps_cost, delta_cost=delta_cost)
                sess.add_release(rel)
                ticket.release = rel
                ticket.final_error = rel.final_error
                ticket.status = "done"
                self.stats.released += 1
                self._record_ticket_latency(ticket, trigger)
            except Exception as exc:
                if not _retryable(exc):
                    self._resolve_stranded(wave[i:], exc)
                    raise
                self._resolve_stranded([ticket], exc)
        return wave

    # ------------------------------------------------------------- answers
    def answer(self, tenant_id: str, q,
               release_id: Optional[int] = None) -> Answer:
        """Answer a linear query from the tenant's released histogram(s) —
        post-processing, zero additional ε; repeats served from the cache."""
        t0 = monotonic()
        ans = self.sessions[tenant_id].answer(q, release_id=release_id)
        self._record_answer(ans, t0)
        return ans

    def answer_derived(self, tenant_id: str, coeffs,
                       release_id: Optional[int] = None) -> Optional[Answer]:
        t0 = monotonic()
        ans = self.sessions[tenant_id].answer_derived(coeffs,
                                                      release_id=release_id)
        if ans is not None:
            self._record_answer(ans, t0)
        return ans

    def _record_answer(self, ans: Answer, t0: float) -> None:
        if not obs.enabled():
            return
        self.metrics.histogram("admission_to_answer_seconds",
                               kind="answer").observe(monotonic() - t0)
        name = ("answer_cache_hits_total" if ans.cached
                else "answer_cache_misses_total")
        self.metrics.counter(name).inc()

    # ------------------------------------------------------------- metrics
    def metrics_snapshot(self) -> dict:
        """Plain-dict view of the service's registry — admission→answer
        latency quantiles (p50/p95/p99) per workload kind, wave occupancy /
        padding gauges, per-tenant ε/δ-spent gauges kept consistent with
        each session ledger by its hook, cache and rejection counters, and
        the mechanism telemetry the drivers published. `benchmarks/run.py`
        embeds the same snapshot into BENCH_results.json."""
        return self.metrics.snapshot()
