"""Per-tenant release sessions and the zero-ε answer cache.

A `TenantSession` owns one private dataset histogram, a global (ε, δ)
budget, and a `PrivacyLedger` charged for every release executed on the
tenant's behalf. Released synthetic histograms are retained as
`ReleasedHistogram`s; answering linear queries against them is
post-processing (Hardt–Ligett–McSherry) and costs no additional privacy —
the `AnswerCache` makes the repeat-query hot path a dict lookup that never
touches the ledger and returns the stored float bitwise.

Derivability: any linear combination of already-answered queries is itself
answerable from the cache alone (⟨Σ cᵢ qᵢ, p̂⟩ = Σ cᵢ ⟨qᵢ, p̂⟩), so
`AnswerCache.derive` serves aggregate/rollup queries without re-reading the
histogram — still zero ε.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.accountant import PrivacyLedger


def query_fingerprint(q) -> str:
    """Stable content hash of a linear query vector (float32 bytes)."""
    return hashlib.sha1(
        np.ascontiguousarray(np.asarray(q, np.float32)).tobytes()
    ).hexdigest()


@dataclass(frozen=True)
class ReleasedHistogram:
    """One synthetic histogram released for a tenant (post-processing-safe)."""

    release_id: int
    p_hat: np.ndarray          # (U,) synthetic distribution
    final_error: float         # ‖Q(p̂−h)‖_∞ on the service workload
    eps_cost: float            # composed ε this release added to the ledger
    delta_cost: float          # composed δ this release added to the ledger
    seed: int = 0


@dataclass(frozen=True)
class ReleasedLP:
    """One private LP solution released for a tenant.

    ``x_bar`` is the DP output: any function of x̄ *alone* is
    post-processing and costs no further privacy. ``violated_frac`` is a
    curator-side quality diagnostic — it touches the private ``b`` again
    (same caveat as `ReleasedHistogram.final_error`, which touches h), so
    a deployment that surfaces it to tenants should noise or withhold it.
    """

    release_id: int
    x_bar: np.ndarray          # (d,) averaged simplex iterate
    violated_frac: float       # fraction of constraints with A x̄ > b + α
    eps_cost: float            # composed ε this release added to the ledger
    delta_cost: float          # composed δ this release added to the ledger
    seed: int = 0


@dataclass
class Answer:
    value: float
    cached: bool
    release_id: int
    fingerprint: str


class AnswerCache:
    """(release_id, query fingerprint) → float answer, plus hit statistics."""

    def __init__(self):
        self._store: Dict[tuple, float] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def lookup(self, release_id: int, fp: str) -> Optional[float]:
        got = self._store.get((release_id, fp))
        if got is None:
            self.misses += 1
        else:
            self.hits += 1
        return got

    def insert(self, release_id: int, fp: str, value: float) -> None:
        self._store[(release_id, fp)] = value

    def derive(self, release_id: int, coeffs: Dict[str, float]) -> Optional[float]:
        """Answer Σ cᵢ qᵢ by linearity of ⟨·, p̂⟩ — cache-only, no histogram
        read. Returns None unless *every* component is cached."""
        total = 0.0
        for fp, c in coeffs.items():
            got = self._store.get((release_id, fp))
            if got is None:
                self.misses += 1
                return None
            total += c * got
        self.hits += 1
        return total


@dataclass
class TenantSession:
    """One tenant's standing state inside a `ReleaseService`."""

    tenant_id: str
    h: np.ndarray                  # (U,) normalized private histogram
    n_records: int                 # dataset size n → sensitivity Δu = 1/n
    eps_budget: float
    delta_budget: float
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)
    releases: List[ReleasedHistogram] = field(default_factory=list)
    lp_releases: List[ReleasedLP] = field(default_factory=list)
    cache: AnswerCache = field(default_factory=AnswerCache)
    rejected_count: int = 0

    @classmethod
    def from_tokens(cls, tenant_id: str, tokens, domain_size: int,
                    eps_budget: float, delta_budget: float) -> "TenantSession":
        """Build the histogram from a raw token/record array."""
        tokens = np.asarray(tokens).reshape(-1)
        h = np.bincount(tokens, minlength=domain_size).astype(np.float32)
        h /= tokens.size
        return cls(tenant_id=tenant_id, h=h, n_records=int(tokens.size),
                   eps_budget=eps_budget, delta_budget=delta_budget)

    def spent(self, tight: bool = False) -> tuple:
        return self.ledger.composed(tight=tight)

    def remaining(self, tight: bool = False) -> tuple:
        return self.ledger.remaining(self.eps_budget, self.delta_budget,
                                     tight=tight)

    @property
    def latest(self) -> Optional[ReleasedHistogram]:
        return self.releases[-1] if self.releases else None

    def add_release(self, rel: ReleasedHistogram) -> None:
        self.releases.append(rel)

    @property
    def latest_lp(self) -> Optional[ReleasedLP]:
        return self.lp_releases[-1] if self.lp_releases else None

    def add_lp_release(self, rel: ReleasedLP) -> None:
        self.lp_releases.append(rel)

    def _release(self, release_id: Optional[int]) -> ReleasedHistogram:
        if not self.releases:
            raise LookupError(f"tenant {self.tenant_id!r} has no releases yet")
        if release_id is None:
            return self.releases[-1]
        for rel in self.releases:
            if rel.release_id == release_id:
                return rel
        raise LookupError(f"unknown release {release_id} for {self.tenant_id!r}")

    def answer(self, q, release_id: Optional[int] = None) -> Answer:
        """⟨q, p̂⟩ from a released histogram — zero additional ε.

        Repeat queries hit the cache and return the stored float bitwise;
        the session ledger is never touched on this path (asserted by
        `tests/test_release_service.py`).
        """
        rel = self._release(release_id)
        fp = query_fingerprint(q)
        got = self.cache.lookup(rel.release_id, fp)
        if got is not None:
            return Answer(got, cached=True, release_id=rel.release_id,
                          fingerprint=fp)
        value = float(np.asarray(q, np.float32) @ np.asarray(rel.p_hat,
                                                            np.float32))
        self.cache.insert(rel.release_id, fp, value)
        return Answer(value, cached=False, release_id=rel.release_id,
                      fingerprint=fp)

    def answer_derived(self, coeffs: Dict[str, float],
                       release_id: Optional[int] = None) -> Optional[Answer]:
        """Linear combination of cached answers (rollups) — cache-only."""
        rel = self._release(release_id)
        value = self.cache.derive(rel.release_id, coeffs)
        if value is None:
            return None
        return Answer(value, cached=True, release_id=rel.release_id,
                      fingerprint="+".join(sorted(coeffs)))
