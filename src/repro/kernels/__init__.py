"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package contains:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    — the jit'd public wrapper (padding, interpret-mode fallback)
  ref.py    — the pure-jnp oracle the kernel is validated against

Kernels:
  mips_topk       — streaming tiled top-k inner-product search (the flat-scan
                    baseline of Fast-MWEM at HBM-bandwidth roofline); one
                    pass covers plain / absolute / complement-augmented
                    rankings
  ivf_probe       — scalar-prefetched IVF probe: streams only the probed
                    cells' rows HBM→VMEM (never materializing the gathered
                    candidate matrix) and amortizes the stream across a
                    serve wave of probes via a dedup + MXU-batched variant
  mwu_update      — fused multiplicative-weights update + online softmax stats
  mwem_step       — the iteration megakernel: measure → MWU → renormalize →
                    accumulate in one VMEM-resident pass per scan lane, the
                    winner row scalar-prefetched straight from the query
                    table, plus the gather-score kernel that streams the
                    lazy-EM tail candidates once (DESIGN.md §7)
  flash_attention — GQA flash attention (full/causal/window/chunk masking)
  ssd_scan        — Mamba-2 SSD chunked state-passing scan
"""
