"""Public jit'd wrapper for the flash attention kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("mode", "window", "q_offset", "block_q",
                                   "block_kv", "interpret", "logit_softcap"))
def flash_attention(q, k, v, *, mode: str = "causal", window: int = 0,
                    q_offset: int = 0, block_q: int = 128, block_kv: int = 128,
                    interpret: bool | None = None, logit_softcap: float = 0.0):
    """GQA flash attention. q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D)."""
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, max(8, Sq))
    block_kv = min(block_kv, max(8, Skv))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = D ** -0.5
    qp = _pad_axis(q, 2, block_q)
    kp = _pad_axis(k, 2, block_kv)
    vp = _pad_axis(v, 2, block_kv)
    out = flash_attention_pallas(
        qp, kp, vp, mode=mode, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
        sq_real=Sq, skv_real=Skv, logit_softcap=logit_softcap)
    return out[:, :, :Sq]
