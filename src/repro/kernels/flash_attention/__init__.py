from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]
