"""Pure-jnp oracle for GQA attention with the framework's mask modes."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def make_mask(sq: int, skv: int, mode: str, window: int = 0,
              q_offset: int = 0) -> jax.Array:
    """(sq, skv) boolean mask; True = attend.

    Row i's *global* position is ``q_offset + i`` (decode: q_offset = cache
    length). Modes: full | causal | window (sliding, size `window`) |
    chunk (attend within `window`-sized chunks, causal inside).
    """
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    if mode == "full":
        return jnp.ones((sq, skv), bool)
    if mode == "causal":
        return kpos <= qpos
    if mode == "window":
        return (kpos <= qpos) & (kpos > qpos - window)
    if mode == "chunk":
        return (kpos <= qpos) & ((kpos // window) == (qpos // window))
    raise ValueError(f"unknown mask mode {mode!r}")


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, mode: str = "causal",
                  window: int = 0, q_offset: int = 0, scale: float | None = None,
                  logit_softcap: float = 0.0) -> jax.Array:
    """GQA attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q's dtype; softmax in f32.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, g, axis=1)
    vf = jnp.repeat(vf, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if logit_softcap > 0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    mask = make_mask(Sq, Skv, mode, window, q_offset)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows → zero output
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vf)
    return out.astype(q.dtype)
