"""GQA flash attention Pallas kernel (forward).

IO-aware attention: never materializes the (Sq × Skv) logit matrix in HBM.
Q/K/V stream through VMEM in (block_q × d) / (block_kv × d) tiles; the
softmax is computed online (running max `m`, running denominator `l`,
rescaled accumulator) across the kv tiles, which form the innermost,
sequential grid dimension — the standard FlashAttention-2 schedule mapped
onto the TPU grid.

GQA is handled *in the index map*: kv tiles for query head ``h`` are
fetched from kv head ``h // group`` — no repeat/materialization of K/V.

Mask modes (static): full | causal | window | chunk, plus a `q_offset` for
decode (query row i sits at global position q_offset + i). Fully-masked
kv tiles are skipped with `pl.when` — for causal masks this halves the
work; for window/chunk masks it makes the kernel O(S·window) instead of
O(S²), which is what makes `long_500k` decodes tractable.

Grid: (B, Hq, q_tiles, kv_tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            mode: str, window: int, q_offset: int, scale: float,
            block_q: int, block_kv: int, sq_real: int, skv_real: int,
            logit_softcap: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    q_start = qi * block_q + q_offset          # global position of row 0
    kv_start = ki * block_kv

    # --- tile-level mask reasoning: skip kv tiles no q row can see ---
    first_q = q_start
    last_q = q_start + block_q - 1
    if mode in ("causal", "window", "chunk"):
        needed = kv_start <= last_q                      # causal reach
        if mode == "window":
            needed = needed & (kv_start + block_kv - 1 > first_q - window)
        if mode == "chunk":
            needed = needed & ((kv_start + block_kv - 1) // window >= first_q // window) \
                            & (kv_start // window <= last_q // window)
    else:
        needed = ki >= 0                                 # always true, traced

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(needed)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bkv, d)
        s = q @ k.T                                       # (bq, bkv)
        if logit_softcap > 0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        qpos = q_start + jax.lax.iota(jnp.int32, block_q)[:, None]
        kpos = kv_start + jax.lax.iota(jnp.int32, block_kv)[None, :]
        mask = (kpos < skv_real) & (qpos < q_offset + sq_real)
        if mode == "causal":
            mask &= kpos <= qpos
        elif mode == "window":
            mask &= (kpos <= qpos) & (kpos > qpos - window)
        elif mode == "chunk":
            mask &= (kpos <= qpos) & ((kpos // window) == (qpos // window))
        s = jnp.where(mask, s, NEG_INF)

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, mode: str, window: int, q_offset: int,
                           scale: float, block_q: int, block_kv: int,
                           interpret: bool, sq_real: int, skv_real: int,
                           logit_softcap: float = 0.0):
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Sq % block_q == 0 and Skv % block_kv == 0
    group = Hq // Hkv
    grid = (B, Hq, Sq // block_q, Skv // block_kv)
    kern = functools.partial(
        _kernel, mode=mode, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_kv=block_kv, sq_real=sq_real, skv_real=skv_real,
        logit_softcap=logit_softcap)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
