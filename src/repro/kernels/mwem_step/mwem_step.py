"""VMEM-resident fused MWEM step (measure → MWU → renormalize) kernel.

One grid program per scan lane: the lane's whole (U,) weight state —
log-weights, density, output accumulator — lives in VMEM for the entire
step, and the *selected* query row streams HBM→VMEM exactly once, picked
straight out of the (m, U) row table by a scalar-prefetched index_map (the
`ivf_probe` cell-id trick applied to the winner id), so the step never
materializes an XLA gather of ``Q[sel]`` in HBM. Per-iteration HBM traffic
for the MWU half drops from the classic route's read/write per sub-op
(~11 U-vectors: softmax, measure/estimate dots, update, max-shift,
renormalize, accumulate — each a separate HBM round-trip) to 9 U-vector
moves total (5 reads: log_w, p, p_sum, q_row, h; 3 writes + noise), and the
carried density means the *next* step skips its softmax reads too.

What stays outside (DESIGN.md §7): the probe, the lazy-EM Gumbel top-k,
and the `lax.cond` exhaustive overflow fallback — they branch on data the
kernel cannot see (tail membership, overflow flag) and keeping them in XLA
is what preserves bitwise host parity and the PR 5 conformance tier. The
kernel receives only the resolved winner id ``sel`` and the realized
Laplace noise.

Bitwise contract vs `ref.mwem_step_ref`: the body is whole-U single-block
(no tiling, no online rescaling), reductions go through `jnp.dot`/
`jnp.max`/`jnp.sum` — the same primitives the ref lowers to — and
``softmax(lw - max(lw))`` is computed as ``e = exp(lw - max); e / sum(e)``,
which equals `jax.nn.softmax` bit-for-bit because the max-shift is explicit
in both. `ops.mwem_step_supported` gates the route to lane-aligned U so no
padding lanes ever enter the reductions.

Grid: (B,); all state blocks (1, U); the row table block (1, U) indexed by
the prefetched ``sel[b]``; h broadcast or per-lane; noise (1,) per lane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sel_ref, lw_ref, p_ref, ps_ref, q_ref, h_ref, noise_ref,
            out_lw_ref, out_p_ref, out_ps_ref, *, rule: str, eta: float):
    del sel_ref  # consumed by q_ref's index_map (scalar-prefetched row pick)
    lw = lw_ref[0].astype(jnp.float32)
    q = q_ref[0].astype(jnp.float32)
    if rule == "paper":
        lw1 = lw - eta * q
    else:
        measured = jnp.dot(q, h_ref[0].astype(jnp.float32)) + noise_ref[0]
        est = jnp.dot(q, p_ref[0])
        if rule == "signed":
            lw1 = lw + eta * jnp.sign(measured - est) * q
        else:  # "hardt" (ops validates the rule set)
            lw1 = lw + q * (measured - est) / 2.0
    lw2 = lw1 - jnp.max(lw1)
    e = jnp.exp(lw2)
    p_new = e / jnp.sum(e)   # max(lw2) == 0 ⇒ bitwise jax.nn.softmax(lw2)
    out_lw_ref[0] = lw2
    out_p_ref[0] = p_new
    out_ps_ref[0] = ps_ref[0] + p_new


def mwem_step_pallas(sel: jax.Array, lw: jax.Array, p: jax.Array,
                     ps: jax.Array, q_rows: jax.Array, h: jax.Array,
                     noise: jax.Array, *, rule: str, eta: float,
                     interpret: bool):
    """Apply one fused MWEM step to B lanes.

    Args:
      sel: (B,) int32 winner row ids into ``q_rows`` (scalar-prefetched).
      lw/p/ps: (B, U) carried log-weights / density / output accumulator.
      q_rows: (R, U) row table — only the ``sel[b]`` rows cross HBM→VMEM.
      h: (1, U) shared or (B, U) per-lane histogram.
      noise: (B,) realized Laplace measurement noise.

    Returns ``(lw', p', ps')``, each (B, U) f32.
    """
    B, U = lw.shape
    per_lane_h = h.shape[0] > 1
    kern = functools.partial(_kernel, rule=rule, eta=eta)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, U), lambda b, sel_ref: (b, 0)),
            pl.BlockSpec((1, U), lambda b, sel_ref: (b, 0)),
            pl.BlockSpec((1, U), lambda b, sel_ref: (b, 0)),
            pl.BlockSpec((1, U), lambda b, sel_ref: (sel_ref[b], 0)),
            pl.BlockSpec((1, U), (lambda b, sel_ref: (b, 0)) if per_lane_h
                         else (lambda b, sel_ref: (0, 0))),
            pl.BlockSpec((1,), lambda b, sel_ref: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, U), lambda b, sel_ref: (b, 0)),
            pl.BlockSpec((1, U), lambda b, sel_ref: (b, 0)),
            pl.BlockSpec((1, U), lambda b, sel_ref: (b, 0)),
        ],
    )
    out_shape = [jax.ShapeDtypeStruct((B, U), jnp.float32)] * 3
    return pl.pallas_call(kern, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(sel, lw, p, ps, q_rows, h,
                                               noise)


def _score_kernel(ids_ref, rows_ref, v_ref, sign_ref, out_ref):
    del ids_ref  # consumed by rows_ref's index_map
    out_ref[0] = jnp.dot(rows_ref[0].astype(jnp.float32), v_ref[0]) * sign_ref[0]


def gather_score_pallas(base: jax.Array, sign: jax.Array, q_rows: jax.Array,
                        v: jax.Array, *, interpret: bool):
    """Scalar-prefetched gather-and-score: ``sign[c] · ⟨q_rows[base[c]], v⟩``.

    The lazy-EM tail's candidate scoring without the XLA gather: each of
    the C candidate rows streams HBM→VMEM exactly once (1× the row bytes
    instead of the gather's read + materialize + matvec re-read ≈ 3×),
    picked by the prefetched id like the megakernel's winner row. Row-wise
    `jnp.dot` keeps the per-row reduction order of the reference matvec —
    bitwise `(q_rows[base] @ v) * sign`.

    Args: base (C,) int32 row ids; sign (C,) f32 ±1; q_rows (R, U); v (U,).
    Returns (C,) f32 scores.
    """
    C = base.shape[0]
    U = q_rows.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, U), lambda c, ids_ref: (ids_ref[c], 0)),
            pl.BlockSpec((1, U), lambda c, ids_ref: (0, 0)),
            pl.BlockSpec((1,), lambda c, ids_ref: (c,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda c, ids_ref: (c,)),
    )
    return pl.pallas_call(_score_kernel, grid_spec=grid_spec,
                          out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
                          interpret=interpret)(base, q_rows, v[None], sign)


def _marginal_score_kernel(tab_ref, off_ref, sign_ref, v_ref, out_ref, *,
                           kmax: int):
    """One candidate per program: rebuild the implicit marginal-cell row
    over the U lanes by mixed-radix iota arithmetic and dot it with v.

    ``tab_ref`` (C, 3·kmax) SMEM holds [domain strides | cards | cell
    strides] for the candidate's clique; ``off_ref`` (C,) its cell offset.
    No row table exists anywhere — the row is (cm == offset) on the fly,
    so the only HBM traffic is v (resident across programs) and the SMEM
    scalars.
    """
    c = pl.program_id(0)
    U = v_ref.shape[1]
    u = jax.lax.broadcasted_iota(jnp.int32, (1, U), 1)
    cm = jnp.zeros((1, U), jnp.int32)
    for j in range(kmax):  # static unroll — kmax is tiny
        cm = cm + ((u // tab_ref[c, j]) % tab_ref[c, kmax + j]) \
            * tab_ref[c, 2 * kmax + j]
    row = (cm == off_ref[c]).astype(jnp.float32)
    out_ref[0] = jnp.dot(row[0], v_ref[0].astype(jnp.float32)) * sign_ref[0]


def marginal_gather_score_pallas(tab: jax.Array, off: jax.Array,
                                 sign: jax.Array, v: jax.Array, *, kmax: int,
                                 interpret: bool):
    """Factored-row gather-and-score: the `gather_score_pallas` contract
    without any ``(m, U)`` table behind it.

    Args: tab (C, 3·kmax) int32 per-candidate clique params; off (C,) int32
    cell offsets; sign (C,) f32 ±1; v (U,). Returns (C,) f32 scores
    ``sign[c] · ⟨q_c, v⟩`` with row-wise `jnp.dot` reduction order (the
    same contract as the dense gather-score kernel).
    """
    C = off.shape[0]
    U = v.shape[0]
    kern = functools.partial(_marginal_score_kernel, kmax=kmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1,), lambda c, tab_ref, off_ref: (c,)),
            pl.BlockSpec((1, U), lambda c, tab_ref, off_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda c, tab_ref, off_ref: (c,)),
    )
    return pl.pallas_call(kern, grid_spec=grid_spec,
                          out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
                          interpret=interpret)(tab, off, sign, v[None])
