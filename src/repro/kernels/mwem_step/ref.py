"""Pure-jnp oracle for the fused MWEM step (measure → MWU → renormalize).

`mwu_apply_ref` is THE multiplicative-weights update expression: the host
loop's `_mwu_step`, both fused scan cores, the sharded driver's model tail
and the Pallas megakernel all reduce to this one function, so the kernel
has a single integration seam and cross-driver bitwise parity cannot drift
(ISSUE 6 satellite: the `_mwu_update` alias and the raw `_mwu_step` partial
were two copies of this math).

Carried-density invariant the megakernel scan relies on: every update ends
with ``log_w -= max(log_w)``, so the carried log-weights have max exactly
0.0 and next step's ``softmax(log_w)`` reproduces the ``p_new`` emitted
here bit-for-bit (IEEE ``x - 0.0 == x``). That is what lets the scan carry
``p`` alongside ``log_w`` and skip the per-step softmax entirely.

Randomness stays outside this seam: the caller draws the Laplace
measurement noise from ``k_meas`` and passes the realized scalar in, so the
kernel body is deterministic and the PR 5 key-chain conformance holds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.faults import fault_site

UPDATE_RULES = ("paper", "signed", "hardt")


def mwu_apply_ref(log_w: jax.Array, p: jax.Array, q_row: jax.Array,
                  h: jax.Array, noise: jax.Array, *, rule: str,
                  eta: float) -> tuple[jax.Array, jax.Array]:
    """One MW update given the selected query row and realized noise.

    Args:
      log_w: (U,) carried log-weights (max-shifted: max == 0).
      p: (U,) carried density, ``softmax(log_w)`` of the input.
      q_row: (U,) the selected query row.
      h: (U,) true histogram.
      noise: scalar Laplace measurement noise (ignored for ``rule="paper"``,
        which takes no measurement).

    Returns ``(log_w', p')`` with ``max(log_w') == 0`` and
    ``p' == softmax(log_w')``.
    """
    if rule == "paper":
        lw = log_w - eta * q_row
    else:
        measured = q_row @ h + noise
        est = q_row @ p
        if rule == "signed":
            lw = log_w + eta * jnp.sign(measured - est) * q_row
        elif rule == "hardt":
            lw = log_w + q_row * (measured - est) / 2.0
        else:
            raise ValueError(f"unknown update rule {rule!r}")
    lw = lw - jnp.max(lw)  # drift control
    return lw, jax.nn.softmax(lw)


def mwem_step_ref(log_w: jax.Array, p: jax.Array, p_sum: jax.Array,
                  q_row: jax.Array, h: jax.Array, noise: jax.Array, *,
                  rule: str, eta: float
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """XLA reference for the megakernel: MWU + renorm + output accumulation.

    Returns ``(log_w', p', p_sum + p')`` — exactly the state the fused scan
    carries per lane.
    """
    fault_site("kernel.mwem_step")
    lw, p_new = mwu_apply_ref(log_w, p, q_row, h, noise, rule=rule, eta=eta)
    return lw, p_new, p_sum + p_new
