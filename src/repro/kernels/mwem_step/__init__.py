from repro.kernels.mwem_step.ops import (aug_gather_score, mwem_step,
                                         mwem_step_batch,
                                         mwem_step_supported, mwu_apply)
from repro.kernels.mwem_step.ref import (UPDATE_RULES, mwem_step_ref,
                                         mwu_apply_ref)

__all__ = [
    "aug_gather_score",
    "mwem_step",
    "mwem_step_batch",
    "mwem_step_supported",
    "mwu_apply",
    "mwem_step_ref",
    "mwu_apply_ref",
    "UPDATE_RULES",
]
