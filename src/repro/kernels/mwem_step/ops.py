"""Public jit'd wrappers for the fused MWEM-step megakernel.

Dispatch contract (the drivers rely on it): every wrapper takes the full
row table plus the winner id — selection, lazy-EM and the overflow
`lax.cond` happen *before* this seam — and every wrapper degrades to
`ref.mwem_step_ref` when `mwem_step_supported` says the shape cannot take
the kernel route (U not lane-aligned, or the whole-U working set would not
fit VMEM). The ref is op-for-op the host `_mwu_step` math, so the fallback
is bitwise, not approximate.

``interpret=None`` resolves to interpret mode off-TPU, same as the other
kernel packages — CPU/GPU CI exercises the real kernel body.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.faults import fault_site
from repro.kernels.mwem_step.mwem_step import (gather_score_pallas,
                                               marginal_gather_score_pallas,
                                               mwem_step_pallas)
from repro.kernels.mwem_step.ref import UPDATE_RULES, mwem_step_ref
from repro.obs.trace import scope as obs_scope

# Whole-U residency budget: each program keeps ~7 (1, U) f32 blocks live
# (3 state in + row + h + 3 out, noise negligible) and Pallas double-buffers
# the pipeline, so peak VMEM ≈ 2·7·4·U bytes. Cap well under the 16 MB/core
# of a v5e so the probe kernel's scratch still fits alongside.
_VMEM_BUDGET_BYTES = 8 * 2**20


def mwem_step_supported(U: int, batch: int = 1) -> bool:
    """Static gate for the kernel route (the drivers' automatic fallback).

    The kernel is whole-U single-block — bitwise parity with the ref comes
    from never tiling the reductions — so U must fill TPU lanes exactly
    (padding would enter max/sum) and one lane's working set must fit VMEM.
    """
    del batch  # grid is (B,): per-program residency is batch-independent
    return U % 128 == 0 and 2 * 7 * 4 * U <= _VMEM_BUDGET_BYTES


def _resolve_interpret(interpret):
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _check_rule(rule: str) -> None:
    if rule not in UPDATE_RULES:
        raise ValueError(f"unknown update rule {rule!r}")


@partial(jax.jit, static_argnames=("rule", "eta", "interpret"))
def mwem_step(log_w: jax.Array, p: jax.Array, p_sum: jax.Array,
              q_rows: jax.Array, sel: jax.Array, h: jax.Array,
              noise: jax.Array, *, rule: str, eta: float,
              interpret: bool | None = None):
    """Single-lane fused step: ``(log_w', p', p_sum')`` from winner ``sel``.

    Args:
      log_w/p/p_sum: (U,) carried state (``p == softmax(log_w)``).
      q_rows: (R, U) row table; only row ``sel`` is streamed on the kernel
        route.
      sel: scalar int winner id into ``q_rows``.
      h: (U,) histogram.
      noise: scalar realized Laplace noise (0.0 for ``rule="paper"``).
    """
    fault_site("kernel.mwem_step")
    _check_rule(rule)
    U = log_w.shape[0]
    if not mwem_step_supported(U):
        return mwem_step_ref(log_w, p, p_sum, q_rows[sel], h, noise,
                             rule=rule, eta=eta)
    interpret = _resolve_interpret(interpret)
    with obs_scope("kernel/mwem_step"):
        out = mwem_step_pallas(
            jnp.reshape(sel, (1,)).astype(jnp.int32),
            log_w[None], p[None], p_sum[None], q_rows, h[None],
            jnp.reshape(jnp.asarray(noise, jnp.float32), (1,)),
            rule=rule, eta=eta, interpret=interpret)
    return tuple(o[0] for o in out)


@partial(jax.jit, static_argnames=("rule", "eta", "interpret"))
def mwem_step_batch(log_w: jax.Array, p: jax.Array, p_sum: jax.Array,
                    q_rows: jax.Array, sel: jax.Array, h: jax.Array,
                    noise: jax.Array, *, rule: str, eta: float,
                    interpret: bool | None = None):
    """Wave-batched fused step over B lanes.

    ``log_w/p/p_sum`` are (B, U); ``sel``/``noise`` are (B,); ``h`` is a
    shared (U,) or per-lane (B, U) histogram. Lane b reproduces
    `mwem_step` for its slice bitwise (grid programs are independent).
    """
    fault_site("kernel.mwem_step")
    _check_rule(rule)
    B, U = log_w.shape
    if not mwem_step_supported(U, B):
        h_ax = 0 if h.ndim == 2 else None
        step = partial(mwem_step_ref, rule=rule, eta=eta)
        return jax.vmap(step, in_axes=(0, 0, 0, 0, h_ax, 0))(
            log_w, p, p_sum, q_rows[sel], h, noise)
    interpret = _resolve_interpret(interpret)
    h2 = h if h.ndim == 2 else h[None]
    with obs_scope("kernel/mwem_step_batch"):
        return mwem_step_pallas(sel.astype(jnp.int32), log_w, p, p_sum,
                                q_rows, h2, noise.astype(jnp.float32),
                                rule=rule, eta=eta, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def aug_gather_score(q_rows: jax.Array, v: jax.Array, aug_idx: jax.Array, *,
                     interpret: bool | None = None):
    """Complement-augmented candidate scores, rows streamed once.

    ``aug_idx`` (C,) encodes query ``j % m`` with sign +1 for ``j < m``
    else −1 (the §3.4 closure); returns ``sign · ⟨q_rows[j % m], v⟩`` —
    bitwise `core.mwem._aug_score`, at 1× the row bytes instead of the XLA
    gather's ~3×. Unsupported shapes fall back to the gather.
    """
    m, U = q_rows.shape
    base = (aug_idx % m).astype(jnp.int32)
    sign = jnp.where(aug_idx < m, 1.0, -1.0).astype(jnp.float32)
    if not mwem_step_supported(U):
        return (q_rows[base] @ v) * sign
    interpret = _resolve_interpret(interpret)
    with obs_scope("kernel/aug_gather_score"):
        return gather_score_pallas(base, sign, q_rows, v, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def marginal_gather_score(W, v: jax.Array, aug_idx: jax.Array, *,
                          interpret: bool | None = None):
    """`aug_gather_score` for factored workloads: candidate rows are
    rebuilt in-kernel from per-clique mixed-radix parameters — offsets +
    implicit one-hot products, never an ``(m, U)`` gather.

    ``W`` is a `core.workload.MarginalWorkload` (a pytree — flows through
    jit as an argument). The XLA side only gathers the (C,) candidate
    clique parameter rows (int32 scalars) before handing them to the
    scalar-prefetch grid. Unsupported shapes fall back to the workload's
    traceable `score_in_graph`.
    """
    m = W.m
    base = (aug_idx % m).astype(jnp.int32)
    sign = jnp.where(aug_idx < m, 1.0, -1.0).astype(jnp.float32)
    if not mwem_step_supported(W.U):
        return W.score_in_graph(v, aug_idx)
    cl = W.q_clique[base]
    tab = jnp.concatenate(
        [W.cl_dstride[cl], W.cl_card[cl], W.cl_stride[cl]], axis=1)
    interpret = _resolve_interpret(interpret)
    with obs_scope("kernel/marginal_gather_score"):
        return marginal_gather_score_pallas(
            tab, W.q_offset[base], sign, v, kmax=W.kmax, interpret=interpret)


@partial(jax.jit, static_argnames=("rule", "eta", "interpret"))
def mwu_apply(log_w: jax.Array, p: jax.Array, p_sum: jax.Array,
              q_row: jax.Array, h: jax.Array, noise: jax.Array, *,
              rule: str, eta: float, interpret: bool | None = None):
    """Materialized-row variant (no prefetch table): the sharded driver's
    model tail, where the winner row arrives via a one-hot psum instead of
    an id into a local table. Same kernel body, ``sel = [0]`` into the
    (1, U) row."""
    fault_site("kernel.mwem_step")
    _check_rule(rule)
    U = log_w.shape[0]
    if not mwem_step_supported(U):
        return mwem_step_ref(log_w, p, p_sum, q_row, h, noise,
                             rule=rule, eta=eta)
    interpret = _resolve_interpret(interpret)
    with obs_scope("kernel/mwu_apply"):
        out = mwem_step_pallas(
            jnp.zeros((1,), jnp.int32),
            log_w[None], p[None], p_sum[None], q_row[None], h[None],
            jnp.reshape(jnp.asarray(noise, jnp.float32), (1,)),
            rule=rule, eta=eta, interpret=interpret)
    return tuple(o[0] for o in out)
