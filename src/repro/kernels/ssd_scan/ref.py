"""Oracles for the Mamba-2 SSD scan.

`ssd_scan_ref` is the literal sequential recurrence (the ground truth):

    h_t = exp(dt_t A) · h_{t−1} + (dt_t x_t) ⊗ B_t,   y_t = h_t C_t

`ssd_chunked_jnp` is the chunked (state-space duality) formulation the
model layer uses on non-TPU backends — quadratic within chunks, linear
state passing across chunks — mathematically identical, validated against
the sequential oracle in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence.

    x: (B, S, H, P); dt: (B, S, H) > 0; A: (H,) < 0; Bm/Cm: (B, S, N).
    Returns y: (B, S, H, P), final state (B, H, P, N). All f32.
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    x, dt, A, Bm, Cm = (t.astype(jnp.float32) for t in (x, dt, A, Bm, Cm))

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P), (B,H), (B,N), (B,N)
        a = jnp.exp(dtt * A[None, :])             # (B,H)
        dtx = dtt[..., None] * xt                 # (B,H,P)
        h = a[..., None, None] * h + dtx[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk: int = 64, h0=None):
    """Chunked SSD (the TPU-friendly formulation; see kernel docstring).

    Same signature/returns as `ssd_scan_ref`, plus optional initial state.

    Memory note: the chunk dimension is a `lax.scan`, emitting y per chunk —
    live state is one (B,H,P,N) carry plus one chunk's quadratic
    intermediates, never the (n_chunks × state) stack.
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    x, dt, A, Bm, Cm = (t.astype(jnp.float32) for t in (x, dt, A, Bm, Cm))
    pad = (-S) % chunk
    if pad:  # dt = 0 → identity transition, zero input
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    # (nc, B, Q, ...) chunked views, chunk dim leading for the scan
    xc = jnp.moveaxis(x.reshape(Bsz, nc, chunk, H, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, chunk, H), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(Bsz, nc, chunk, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(Bsz, nc, chunk, N), 1, 0)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def step(h, inp):
        xq, dtq, bq, cq = inp                       # (B,Q,H,P) (B,Q,H) (B,Q,N)
        l = dtq * A[None, None, :]                  # (B,Q,H) ≤ 0
        cum = jnp.cumsum(l, axis=1)                 # inclusive
        # intra: W[i,j] = (C_i·B_j)·exp(cum_i − cum_j)·dt_j, j ≤ i
        Sij = jnp.einsum("bin,bjn->bij", cq, bq)    # (B,Q,Q)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,Q,H)
        W = Sij[..., None] * decay * tri[None, :, :, None] * dtq[:, None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", W, xq)
        # inter: exp(cum_i)·C_i·h — explicit contraction order (2·B·Q·H·P·N)
        y_inter = jnp.einsum("bin,bhpn->bihp", cq, h) * jnp.exp(cum)[..., None]
        # state update
        cum_last = cum[:, -1, :]                    # (B,H)
        wj = jnp.exp(cum_last[:, None, :] - cum) * dtq          # (B,Q,H)
        U = jnp.einsum("bjhp,bjn->bhpn", xq * wj[..., None], bq)
        h_new = jnp.exp(cum_last)[..., None, None] * h + U
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, H, P)[:, :S]
    return y, hT
