"""Public jit'd wrapper for the SSD scan kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, interpret: bool | None = None):
    """Mamba-2 SSD: y_t = C_t·h_t with h_t = exp(dt_t A)h_{t−1} + dt_t x_t⊗B_t.

    x: (B, S, H, P); dt: (B, S, H); A: (H,); Bm/Cm: (B, S, N) → y (B,S,H,P) f32.
    Padding timesteps carry dt = 0 (identity state transition, zero input).
    """
    B, S, H, P = x.shape
    chunk = min(chunk, max(8, S))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_pallas(x.astype(jnp.float32), dt.astype(jnp.float32),
                        A.astype(jnp.float32), Bm.astype(jnp.float32),
                        Cm.astype(jnp.float32), chunk=chunk, interpret=interpret)
    return y[:, :S]
