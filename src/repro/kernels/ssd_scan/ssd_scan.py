"""Mamba-2 SSD chunked scan Pallas kernel.

State-space duality: within a chunk of Q timesteps the SSD recurrence is a
masked-attention-like quadratic form (MXU work); across chunks only the
(P × N) state is carried — VMEM-resident scratch, never touching HBM.

Per grid step (one chunk of one (batch, head)):
    l       = dt · A                                    (Q,)
    cum     = cumsum(l)                                 (Q,)
    W[i,j]  = (C_i·B_j) · exp(cum_i − cum_j) · dt_j     j ≤ i
    y       = W @ x  +  exp(cum) ⊙ (C @ stateᵀ)
    state   = exp(cum_Q)·state + xᵀ diag(exp(cum_Q − cum)·dt) B

A ≤ 0 keeps every exponential in (0, 1] — no overflow paths.

Grid: (B, H, n_chunks), chunks innermost/sequential (the state carry).
Blocks: x,y (1,Q,1,P); dt (1,Q,1); B,C (1,Q,N) shared across heads (G=1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xq = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dtq = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    A = a_ref[0]                                    # scalar
    Bq = b_ref[0].astype(jnp.float32)               # (Q, N)
    Cq = c_ref[0].astype(jnp.float32)               # (Q, N)

    cum = jnp.cumsum(dtq * A)                       # (Q,) ≤ 0, inclusive
    Sij = Cq @ Bq.T                                 # (Q, Q)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    W = Sij * decay * tri * dtq[None, :]
    y_intra = W @ xq                                # (Q, P)

    state = state_ref[...]                          # (P, N)
    y_inter = jnp.exp(cum)[:, None] * (Cq @ state.T)
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    g_last = jnp.exp(cum[-1])
    wj = jnp.exp(cum[-1] - cum) * dtq               # (Q,)
    state_ref[...] = g_last * state + (xq * wj[:, None]).T @ Bq


def ssd_scan_pallas(x, dt, A, Bm, Cm, *, chunk: int, interpret: bool):
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    grid = (Bsz, H, S // chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
