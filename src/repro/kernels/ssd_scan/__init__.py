from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref, ssd_chunked_jnp

__all__ = ["ssd_scan", "ssd_scan_ref", "ssd_chunked_jnp"]
