"""Public jit'd wrapper for the mips_topk kernel (padding + dispatch)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mips_topk.mips_topk import mips_topk_pallas


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit,
         static_argnames=("k", "block_n", "block_d", "interpret", "absolute"))
def mips_topk(V: jax.Array, q: jax.Array, k: int, *, block_n: int = 512,
              block_d: int = 512, interpret: bool | None = None,
              absolute: bool = False):
    """Top-k inner products of ``q`` against rows of ``V``.

    Pads (n, d) to tile multiples; padded rows are masked inside the kernel
    (scores forced to −inf). ``interpret=None`` → interpret everywhere
    except real TPU backends. ``absolute=True`` ranks by |⟨v_j, q⟩| and
    returns the absolute scores (the IVF centroid-probe ordering) — ties
    break exactly like ``jax.lax.top_k`` on the full score vector.
    """
    n, d = V.shape
    block_n = min(block_n, max(8, n))
    block_d = min(block_d, max(8, d))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Vp = _pad_to(_pad_to(V, 0, block_n), 1, block_d)
    qp = _pad_to(q, 0, block_d)
    return mips_topk_pallas(Vp, qp, k, block_n=block_n, block_d=block_d,
                            interpret=interpret, n_real=n,
                            mode="abs" if absolute else "plain")


@partial(jax.jit, static_argnames=("k", "block_n", "block_d", "interpret"))
def mips_abs_topk(V: jax.Array, q: jax.Array, k: int, *, block_n: int = 512,
                  block_d: int = 512, interpret: bool | None = None):
    """Top-k of ``|V @ q|`` as complement-augmented ids (paper §3.4).

    Returned id ``j < n`` means ``+⟨v_j, q⟩``; ``j ≥ n`` means
    ``−⟨v_{j−n}, q⟩`` (the complement row's score for zero-sum probes).
    One streaming pass over V: each row tile contributes *both* signed
    scores to the running top-k merge (``mode="aug"``), so the 2n-row
    augmented matrix is never materialized and V is read exactly once —
    half the HBM traffic of the old two-pass (q, −q) formulation. For
    k ≤ n each base row contributes at most one of its two signed scores
    to the top (the other is ≤ 0 ≤ the winner), so this equals top-k over
    the full augmented set.
    """
    n, d = V.shape
    block_n = min(block_n, max(8, n))
    block_d = min(block_d, max(8, d))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Vp = _pad_to(_pad_to(V, 0, block_n), 1, block_d)
    qp = _pad_to(q, 0, block_d)
    return mips_topk_pallas(Vp, qp, k, block_n=block_n, block_d=block_d,
                            interpret=interpret, n_real=n, mode="aug")
