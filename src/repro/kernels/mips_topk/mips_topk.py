"""Streaming tiled top-k MIPS Pallas kernel.

The flat (exact) retrieval path of Fast-MWEM: score all n key vectors
against one probe and keep the top-k — without ever materializing the
(n,) score vector in HBM.

TPU mapping: V streams HBM→VMEM in (block_n × block_d) tiles; partial dot
products accumulate across the d-tiles in a VMEM scratch; when a row tile's
score is complete it is merged into a running top-k scratch via
`jax.lax.top_k` over the (k + block_n) concatenation. Arithmetic intensity
is ~0.5 flop/byte — the kernel is HBM-bandwidth-bound by construction, which
is the roofline the IVF/LSH/NSW indices beat by touching fewer rows.

Three ranking modes share the one streaming pass (``mode``):

* ``"plain"`` — rank by ⟨v_j, q⟩, return row ids (the exact flat scan).
* ``"abs"``   — rank by |⟨v_j, q⟩|, return row ids and the absolute
  scores (the IVF centroid-probe ordering of the sharded driver).
* ``"aug"``   — rank the complement-augmented set: each row contributes
  both signed scores (+⟨v_j, q⟩ as id j, −⟨v_j, q⟩ as id j+n) to a single
  top-k merge. One read of V covers both signs — half the HBM traffic of
  the old two-pass (q, −q) formulation.

Grid: (n_tiles, d_tiles), d innermost. All shapes padded by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, q_ref, out_i_ref, out_s_ref, acc_ref, top_s_ref, top_i_ref,
            *, k: int, block_n: int, n_real: int, mode: str):
    ni = pl.program_id(0)
    di = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(di == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (block_n, block_d) @ (block_d,) partial scores, f32 accumulation.
    acc_ref[...] += v_ref[...].astype(jnp.float32) @ q_ref[...].astype(jnp.float32)

    @pl.when(di == nd - 1)
    def _merge():
        @pl.when(ni == 0)
        def _init_top():
            top_s_ref[...] = jnp.full_like(top_s_ref, -jnp.inf)
            top_i_ref[...] = jnp.zeros_like(top_i_ref)

        row_idx = ni * block_n + jax.lax.iota(jnp.int32, block_n)
        valid = row_idx < n_real
        acc = acc_ref[...]
        if mode == "plain":
            scores = jnp.where(valid, acc, -jnp.inf)
            cand_i = row_idx
        elif mode == "abs":
            scores = jnp.where(valid, jnp.abs(acc), -jnp.inf)
            cand_i = row_idx
        elif mode == "aug":
            # Both signs of every row in one merge: id j ↦ +score,
            # id j+n ↦ −score (the complement row, paper §3.4).
            scores = jnp.concatenate([
                jnp.where(valid, acc, -jnp.inf),
                jnp.where(valid, -acc, -jnp.inf),
            ])
            cand_i = jnp.concatenate([row_idx, row_idx + n_real])
        else:
            raise ValueError(f"unknown mips_topk mode {mode!r}")
        merged_s = jnp.concatenate([top_s_ref[...], scores])
        merged_i = jnp.concatenate([top_i_ref[...], cand_i])
        new_s, pos = jax.lax.top_k(merged_s, k)
        top_s_ref[...] = new_s
        top_i_ref[...] = merged_i[pos]

        @pl.when(ni == pl.num_programs(0) - 1)
        def _emit():
            out_s_ref[...] = top_s_ref[...]
            out_i_ref[...] = top_i_ref[...]


def mips_topk_pallas(Vp: jax.Array, qp: jax.Array, k: int, *, block_n: int,
                     block_d: int, interpret: bool, n_real: int,
                     mode: str = "plain"):
    """Padded-shape pallas_call; use ops.mips_topk for the public API."""
    n, d = Vp.shape
    assert n % block_n == 0 and d % block_d == 0, "ops.py must pad"
    grid = (n // block_n, d // block_d)
    kern = functools.partial(_kernel, k=k, block_n=block_n, n_real=n_real,
                             mode=mode)
    out_i, out_s = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_d,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_n,), jnp.float32),
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        interpret=interpret,
    )(Vp, qp)
    return out_i, out_s
