from repro.kernels.mips_topk.ops import mips_abs_topk, mips_topk
from repro.kernels.mips_topk.ref import mips_topk_ref

__all__ = ["mips_abs_topk", "mips_topk", "mips_topk_ref"]
