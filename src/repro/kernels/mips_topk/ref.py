"""Pure-jnp oracle for the streaming top-k MIPS kernel."""

import jax
import jax.numpy as jnp


def mips_topk_ref(V: jax.Array, q: jax.Array, k: int):
    """Exact top-k inner products: returns (idx int32 (k,), scores f32 (k,))."""
    scores = V.astype(jnp.float32) @ q.astype(jnp.float32)
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_i.astype(jnp.int32), top_s
