"""Fused multiplicative-weights update Pallas kernel.

Fuses the MWEM inner-loop update ``log_w += coef·q_row`` with the *online*
softmax statistics (running max + rescaled running sum-of-exponentials, the
same trick flash attention uses), so the (U,)-sized weight vector is read
exactly once from HBM instead of three times (update, max pass, sum pass).

Outputs the updated log-weights plus (max, sumexp) scalars; the caller forms
``p = exp(log_w − m)/s`` lazily, fused by XLA into whichever consumer needs
p. For MWEM, U = |X| can be 2^20+, so this is the bandwidth hot-spot of the
MWU half of each iteration.

Grid: (u_tiles,), sequential; scratch keeps (m, s) running scalars in VMEM
(shaped (1,1) for TPU SMEM friendliness).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(lw_ref, c_ref, coef_ref, out_lw_ref, out_m_ref, out_s_ref, stat_ref,
            *, block_u: int, u_real: int):
    ui = pl.program_id(0)

    @pl.when(ui == 0)
    def _init():
        stat_ref[0, 0] = -jnp.inf   # running max
        stat_ref[0, 1] = 0.0        # running sumexp (w.r.t. running max)

    idx = ui * block_u + jax.lax.iota(jnp.int32, block_u)
    valid = idx < u_real
    lw = lw_ref[...].astype(jnp.float32) + coef_ref[0] * c_ref[...].astype(jnp.float32)
    out_lw_ref[...] = lw

    lw_masked = jnp.where(valid, lw, -jnp.inf)
    tile_max = jnp.max(lw_masked)
    m_old = stat_ref[0, 0]
    m_new = jnp.maximum(m_old, tile_max)
    tile_sum = jnp.sum(jnp.where(valid, jnp.exp(lw_masked - m_new), 0.0))
    stat_ref[0, 1] = stat_ref[0, 1] * jnp.exp(m_old - m_new) + tile_sum
    stat_ref[0, 0] = m_new

    @pl.when(ui == pl.num_programs(0) - 1)
    def _emit():
        out_m_ref[0] = stat_ref[0, 0]
        out_s_ref[0] = stat_ref[0, 1]


def mwu_update_pallas(lw: jax.Array, c: jax.Array, coef: jax.Array, *,
                      block_u: int, interpret: bool, u_real: int):
    u = lw.shape[0]
    assert u % block_u == 0
    grid = (u // block_u,)
    kern = functools.partial(_kernel, block_u=block_u, u_real=u_real)
    out_lw, out_m, out_s = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_u,), lambda i: (i,)),
            pl.BlockSpec((block_u,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_u,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((u,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 2), jnp.float32)],
        interpret=interpret,
    )(lw, c, coef)
    return out_lw, out_m, out_s
