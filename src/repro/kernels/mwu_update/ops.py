"""Public jit'd wrapper for the fused MWU update kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mwu_update.mwu_update import mwu_update_pallas


@partial(jax.jit, static_argnames=("block_u", "interpret"))
def mwu_update(log_w: jax.Array, c_row: jax.Array, coef, *, block_u: int = 1024,
               interpret: bool | None = None):
    """Fused ``log_w += coef·c_row`` + softmax(p) (see kernel docstring).

    Returns (log_w', p) matching `ref.mwu_update_ref`.
    """
    u = log_w.shape[0]
    block_u = min(block_u, max(8, u))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pad = (-u) % block_u
    lw = jnp.pad(log_w.astype(jnp.float32), (0, pad))
    c = jnp.pad(c_row.astype(jnp.float32), (0, pad))
    coef_arr = jnp.asarray(coef, jnp.float32).reshape(1)
    out_lw, m, s = mwu_update_pallas(lw, c, coef_arr, block_u=block_u,
                                     interpret=interpret, u_real=u)
    out_lw = out_lw[:u]
    p = jnp.exp(out_lw - m[0]) / s[0]
    return out_lw, p
