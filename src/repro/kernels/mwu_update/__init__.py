from repro.kernels.mwu_update.ops import mwu_update
from repro.kernels.mwu_update.ref import mwu_update_ref

__all__ = ["mwu_update", "mwu_update_ref"]
