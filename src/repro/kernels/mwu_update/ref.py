"""Pure-jnp oracle for the fused MWU update."""

import jax
import jax.numpy as jnp


def mwu_update_ref(log_w: jax.Array, c_row: jax.Array, coef: jax.Array):
    """log_w' = log_w + coef·c_row; p = softmax(log_w').

    Returns (log_w', p).
    """
    lw = log_w.astype(jnp.float32) + jnp.float32(coef) * c_row.astype(jnp.float32)
    m = jnp.max(lw)
    e = jnp.exp(lw - m)
    return lw, e / jnp.sum(e)
