"""Pallas-fused IVF probe: scalar-prefetched cell streaming (DESIGN.md §3).

The Θ(√m) selection step of Fast-MWEM is an IVF probe: score the nlist
centroids, pick the top-nprobe cells, score only those cells' rows, keep
the top-k. The XLA lowering materializes the gathered (nprobe·cap, dim)
candidate matrix in HBM (gather out, matvec back in — the rows cross the
HBM bus three times). These kernels never materialize it:

* rows live in HBM pre-grouped by cell (``cell_rows`` (nlist, cap, dim),
  built once per index);
* the probed cell ids are a *scalar-prefetch* input
  (`pltpu.PrefetchScalarGridSpec`), so the Pallas pipeline's index_map
  reads them before the body runs and DMAs exactly the probed cells'
  (cap, block_d) tiles HBM→VMEM, double-buffered across grid steps;
* partial dots accumulate in a VMEM scratch across the d-tiles and merge
  into a running top-k scratch — only the (k,) result leaves the chip.

Bytes touched: nlist·dim (centroids, scored by the `mips_topk` streaming
kernel) + nprobe·cap·dim (probed rows, once) — vs the XLA path's
~3× nprobe·cap·dim gather traffic (`analysis.roofline.ivf_probe_roofline`).

The batched kernel amortizes the stream across a serve wave of B probes:
the union of all lanes' probed cells is deduplicated — the unique cells
stream first (each read from HBM once however many lanes probed it) and
the fully-masked duplicate tail repeats the last unique id, revisiting the
block already resident in VMEM rather than re-streaming distinct cells.
Every streamed (cap, block_d) tile feeds one (cap × block_d) @
(block_d × B) MXU matmul — the wave turns gather-bound probing into
MXU-bound matmuls (scoring runs for all B lanes per tile; a per-slot
membership mask blanks lanes that did not probe the cell after the
matmul — the dedup shares reads, not FLOPs).

Grids: single (nprobe, d_tiles), batched (n_slots, d_tiles), d innermost.
All shapes padded by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stream_kernel(probe_ref, rows_ref, ids_ref, q_ref, out_i_ref, out_s_ref,
                   acc_ref, top_s_ref, top_i_ref, *, k: int, absolute: bool):
    del probe_ref  # consumed by the index_maps, not the body
    ci = pl.program_id(0)
    di = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(di == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (cap, block_d) @ (block_d,) partial dots for this cell, f32 accum.
    acc_ref[...] += rows_ref[0].astype(jnp.float32) @ q_ref[...].astype(jnp.float32)

    @pl.when(di == nd - 1)
    def _merge():
        @pl.when(ci == 0)
        def _init_top():
            top_s_ref[...] = jnp.full_like(top_s_ref, -jnp.inf)
            top_i_ref[...] = jnp.full_like(top_i_ref, -1)

        ids = ids_ref[0]                       # (cap,) row ids, -1 = padding
        acc = acc_ref[...]
        scores = jnp.abs(acc) if absolute else acc
        scores = jnp.where(ids >= 0, scores, -jnp.inf)
        # Stable merge: the running buffer (earlier cells) sits first in the
        # concat, so incremental top-k equals one `lax.top_k` over the flat
        # candidate vector in probe order — ties break identically to ref.py.
        merged_s = jnp.concatenate([top_s_ref[...], scores])
        merged_i = jnp.concatenate([top_i_ref[...], ids])
        new_s, pos = jax.lax.top_k(merged_s, k)
        top_s_ref[...] = new_s
        top_i_ref[...] = merged_i[pos]

        @pl.when(ci == pl.num_programs(0) - 1)
        def _emit():
            out_s_ref[...] = top_s_ref[...]
            out_i_ref[...] = top_i_ref[...]


def ivf_probe_stream_pallas(probe: jax.Array, rows_p: jax.Array,
                            ids_p: jax.Array, qp: jax.Array, k: int, *,
                            block_d: int, interpret: bool, absolute: bool):
    """Padded-shape pallas_call; use ops.ivf_probe_topk for the public API.

    ``probe`` (nprobe,) int32 cell ids is the scalar-prefetch operand: the
    index_maps read ``probe[ci]`` to pick which HBM cell block the pipeline
    DMAs next, so un-probed cells are never touched.
    """
    nlist, cap, dp = rows_p.shape
    nprobe = probe.shape[0]
    assert dp % block_d == 0 and qp.shape[0] == dp, "ops.py must pad"
    grid = (nprobe, dp // block_d)
    kern = functools.partial(_stream_kernel, k=k, absolute=absolute)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap, block_d),
                         lambda i, j, probe_ref: (probe_ref[i], 0, j)),
            pl.BlockSpec((1, cap), lambda i, j, probe_ref: (probe_ref[i], 0)),
            pl.BlockSpec((block_d,), lambda i, j, probe_ref: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i, j, probe_ref: (0,)),
            pl.BlockSpec((k,), lambda i, j, probe_ref: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((cap,), jnp.float32),
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
    )
    out_i, out_s = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(probe, rows_p, ids_p, qp)
    return out_i, out_s


def _stream_batch_kernel(slots_ref, rows_ref, ids_ref, qb_ref, member_ref,
                         out_i_ref, out_s_ref, acc_ref, top_s_ref, top_i_ref,
                         *, k: int, absolute: bool):
    del slots_ref
    si = pl.program_id(0)
    di = pl.program_id(1)
    nd = pl.num_programs(1)
    B = top_s_ref.shape[0]
    cap = ids_ref.shape[1]

    @pl.when(di == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # One MXU matmul scores this cell tile against the whole wave:
    # (cap, block_d) @ (block_d, B) → (cap, B).
    acc_ref[...] += jnp.dot(rows_ref[0].astype(jnp.float32),
                            qb_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _merge():
        @pl.when(si == 0)
        def _init_top():
            top_s_ref[...] = jnp.full_like(top_s_ref, -jnp.inf)
            top_i_ref[...] = jnp.full_like(top_i_ref, -1)

        ids = ids_ref[0]                       # (cap,)
        member = member_ref[0]                 # (B,) 1.0 iff lane probed cell
        acc = acc_ref[...]                     # (cap, B)
        scores = jnp.abs(acc) if absolute else acc
        scores_t = scores.T                    # (B, cap)
        mask = (ids[None, :] >= 0) & (member[:, None] > 0)
        scores_t = jnp.where(mask, scores_t, -jnp.inf)
        ids_b = jnp.broadcast_to(ids[None, :], (B, cap))
        merged_s = jnp.concatenate([top_s_ref[...], scores_t], axis=1)
        merged_i = jnp.concatenate([top_i_ref[...], ids_b], axis=1)
        new_s, pos = jax.lax.top_k(merged_s, k)
        top_s_ref[...] = new_s
        top_i_ref[...] = jnp.take_along_axis(merged_i, pos, axis=1)

        @pl.when(si == pl.num_programs(0) - 1)
        def _emit():
            out_s_ref[...] = top_s_ref[...]
            out_i_ref[...] = top_i_ref[...]


def ivf_probe_stream_batch_pallas(slots: jax.Array, rows_p: jax.Array,
                                  ids_p: jax.Array, qbp: jax.Array,
                                  member: jax.Array, k: int, *, block_d: int,
                                  interpret: bool, absolute: bool):
    """Batched padded-shape pallas_call (ops.ivf_probe_topk_batch public).

    ``slots`` (n_slots,) int32 deduplicated cell ids (scalar-prefetched);
    ``qbp`` (dp, B) probe vectors as columns; ``member`` (n_slots, B) 0/1
    lane-membership mask. A cell shared by lanes streams from HBM once.
    """
    nlist, cap, dp = rows_p.shape
    n_slots = slots.shape[0]
    B = qbp.shape[1]
    assert dp % block_d == 0, "ops.py must pad"
    grid = (n_slots, dp // block_d)
    kern = functools.partial(_stream_batch_kernel, k=k, absolute=absolute)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap, block_d),
                         lambda i, j, slots_ref: (slots_ref[i], 0, j)),
            pl.BlockSpec((1, cap), lambda i, j, slots_ref: (slots_ref[i], 0)),
            pl.BlockSpec((block_d, B), lambda i, j, slots_ref: (j, 0)),
            pl.BlockSpec((1, B), lambda i, j, slots_ref: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda i, j, slots_ref: (0, 0)),
            pl.BlockSpec((B, k), lambda i, j, slots_ref: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((cap, B), jnp.float32),
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
        ],
    )
    out_i, out_s = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(slots, rows_p, ids_p, qbp, member)
    return out_i, out_s
