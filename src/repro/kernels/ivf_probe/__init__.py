from repro.kernels.ivf_probe.ops import ivf_probe_topk, ivf_probe_topk_batch
from repro.kernels.ivf_probe.ref import (batch_probe_slots,
                                         ivf_probe_topk_batch_ref,
                                         ivf_probe_topk_ref,
                                         marginal_probe_topk_ref)

__all__ = [
    "ivf_probe_topk",
    "ivf_probe_topk_batch",
    "ivf_probe_topk_ref",
    "ivf_probe_topk_batch_ref",
    "batch_probe_slots",
    "marginal_probe_topk_ref",
]
