"""Public jit'd wrappers for the fused IVF probe (padding + dispatch).

Layout contract: callers hold the index rows twice — the flat ``V`` the
XLA reference gathers from, and ``cell_rows`` (nlist, cap, dim), the same
rows pre-grouped by cell so a probed cell is one contiguous HBM block the
kernel's scalar-prefetched index_map can DMA directly (`mips.IVFIndex`
builds it lazily, only when the Pallas route is live).

Stage split: the centroid scoring + top-nprobe runs through the streaming
`mips_topk` kernel (VMEM-resident, mode="abs" for the sharded driver's
|·| ordering); its (nprobe,) cell ids feed the stream kernel's scalar
prefetch with no host round-trip. The batched wrapper plans its probes
with one XLA (B × dim) @ (dim × nlist) matmul instead — at wave width the
centroid stage is MXU-bound already, and the dedup/membership planning is
pure jnp either way.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ivf_probe.ivf_probe import (ivf_probe_stream_batch_pallas,
                                               ivf_probe_stream_pallas)
from repro.kernels.ivf_probe.ref import batch_probe_slots
from repro.kernels.mips_topk.ops import _pad_to, mips_topk
from repro.obs.trace import scope as obs_scope


def _pad_cell_blocks(cell_rows, cells, block_d: int, cap_mult: int = 8):
    """Pad cap to a sublane multiple (pad slots id −1) and dim to block_d."""
    rows_p = _pad_to(_pad_to(cell_rows, 1, cap_mult), 2, block_d)
    pad_cap = rows_p.shape[1] - cells.shape[1]
    ids_p = cells
    if pad_cap:
        ids_p = jnp.pad(cells, ((0, 0), (0, pad_cap)), constant_values=-1)
    return rows_p, ids_p


@partial(jax.jit, static_argnames=("k", "nprobe", "block_d", "interpret",
                                   "absolute"))
def ivf_probe_topk(cents: jax.Array, cell_rows: jax.Array, cells: jax.Array,
                   q: jax.Array, k: int, nprobe: int, *, block_d: int = 512,
                   interpret: bool | None = None, absolute: bool = False):
    """Fused IVF probe: top-k inner products over the top-``nprobe`` cells.

    Args:
      cents: (nlist, dim) cell centroids.
      cell_rows: (nlist, cap, dim) rows grouped by cell (pad slots zero).
      cells: (nlist, cap) int32 row-id table, −1 padding.
      q: (dim,) probe vector.
      absolute: rank centroids and candidates by |⟨·, q⟩| and return the
        absolute scores (the sharded driver's ordering); False matches
        `mips.IVFIndex`'s signed ordering.

    Returns ``(idx (k,) int32, scores (k,) f32, n_valid () int32)`` —
    bitwise `ref.ivf_probe_topk_ref` (same candidate order, stable merge),
    with ``idx = −1`` beyond the valid candidates.
    """
    nlist, cap, dim = cell_rows.shape
    block_d = min(block_d, max(8, dim))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    probe, _ = mips_topk(cents, q, nprobe, block_d=block_d,
                         interpret=interpret, absolute=absolute)
    rows_p, ids_p = _pad_cell_blocks(cell_rows, cells, block_d)
    qp = _pad_to(q, 0, block_d)
    with obs_scope("kernel/ivf_probe"):
        out_i, out_s = ivf_probe_stream_pallas(
            probe, rows_p, ids_p, qp, k, block_d=block_d, interpret=interpret,
            absolute=absolute)
    n_valid = jnp.sum(cells[probe] >= 0).astype(jnp.int32)
    return out_i, out_s, n_valid


@partial(jax.jit, static_argnames=("k", "nprobe", "block_d", "interpret",
                                   "absolute"))
def ivf_probe_topk_batch(cents: jax.Array, cell_rows: jax.Array,
                         cells: jax.Array, Vb: jax.Array, k: int, nprobe: int,
                         *, block_d: int = 512, interpret: bool | None = None,
                         absolute: bool = False):
    """Wave-batched fused IVF probe over B probe vectors ``Vb`` (B, dim).

    Each cell of the lanes' deduplicated union streams HBM→VMEM once
    (duplicate tail slots revisit the resident block, lane-membership
    masked); each streamed tile is scored against the whole wave by one
    MXU matmul. Returns ``(idx (B, k), scores (B, k), n_valid (B,))`` —
    bitwise `ref.ivf_probe_topk_batch_ref` (ties break in ascending-cell
    slot order, see ref.py).
    """
    nlist, cap, dim = cell_rows.shape
    B = Vb.shape[0]
    block_d = min(block_d, max(8, dim))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    slots, member, probe = batch_probe_slots(cents, cells, Vb, nprobe,
                                             absolute)
    rows_p, ids_p = _pad_cell_blocks(cell_rows, cells, block_d)
    qbp = _pad_to(Vb.T, 0, block_d)                       # (dp, B)
    with obs_scope("kernel/ivf_probe_batch"):
        out_i, out_s = ivf_probe_stream_batch_pallas(
            slots, rows_p, ids_p, qbp, member, k, block_d=block_d,
            interpret=interpret, absolute=absolute)
    n_valid = jnp.sum(cells[probe] >= 0, axis=(1, 2)).astype(jnp.int32)
    return out_i, out_s, n_valid
