"""Pure-jnp oracles for the fused IVF probe kernels.

`ivf_probe_topk_ref` is exactly the XLA probe `mips.IVFIndex` has always
run (centroid matvec → top_k → cell gather → candidate matvec → top_k),
with candidates laid out cell-probe-major / slot-minor — the same flat
order the streaming kernel merges in, so index/score agreement is exact
including ties (`jax.lax.top_k` is stable, and a stable incremental top-k
merge equals the stable global top-k).

`ivf_probe_topk_batch_ref` mirrors the batched kernel's candidate order
instead: the deduplicated cell union in *ascending cell id* order shared
by all lanes. On exact score ties the batched path can therefore pick a
different (equal-scoring) candidate than nprobe-ordered per-lane probes —
the only way the two orderings are observably different.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ivf_probe_topk_ref(cents: jax.Array, cells: jax.Array, V: jax.Array,
                       q: jax.Array, k: int, nprobe: int,
                       absolute: bool = False):
    """Returns (idx int32 (k,), scores f32 (k,), n_valid int32 ()).

    ``idx`` entries are row ids from the cell table (−1 where fewer than k
    valid candidates were probed); ``n_valid`` counts the valid (non-pad)
    row slots in the probed cells — the scored-rows term of ``n_scored``.
    """
    cscores = cents.astype(jnp.float32) @ q.astype(jnp.float32)
    order = jnp.abs(cscores) if absolute else cscores
    _, probe = jax.lax.top_k(order, nprobe)
    cand = cells[probe].reshape(-1)                       # (nprobe·cap,)
    valid = cand >= 0
    scores = V[jnp.clip(cand, 0)].astype(jnp.float32) @ q.astype(jnp.float32)
    if absolute:
        scores = jnp.abs(scores)
    scores = jnp.where(valid, scores, -jnp.inf)
    top_s, pos = jax.lax.top_k(scores, k)
    idx = jnp.where(jnp.isfinite(top_s), cand[pos], -1)
    return idx.astype(jnp.int32), top_s, jnp.sum(valid).astype(jnp.int32)


def batch_probe_slots(cents: jax.Array, cells: jax.Array, Vb: jax.Array,
                      nprobe: int, absolute: bool = False):
    """Shared probe planning for the batched kernel and its reference.

    Returns ``(slots, member, probe)``: the (B·nprobe,) deduplicated cell
    union (unique ids first, ascending; the duplicate tail masked out of
    every lane and pinned to the *last* unique id, so the tail's grid
    steps revisit the block already resident in VMEM instead of
    re-streaming distinct cells), the (B·nprobe, B) float 0/1 membership
    mask, and the per-lane (B, nprobe) probed cells.
    """
    cscores = Vb.astype(jnp.float32) @ cents.astype(jnp.float32).T  # (B, nlist)
    order = jnp.abs(cscores) if absolute else cscores
    _, probe = jax.lax.top_k(order, nprobe)               # (B, nprobe)
    flat = jnp.sort(probe.reshape(-1))
    uniq = jnp.concatenate([jnp.ones((1,), bool), flat[1:] != flat[:-1]])
    # unique cells first (ascending), duplicates squeezed to the tail
    slots = flat[jnp.argsort(~uniq, stable=True)]
    slot_valid = jnp.sort(uniq)[::-1]
    # fully-masked tail slots all repeat the max (= last unique) cell id
    slots = jnp.where(slot_valid, slots, flat[-1])
    member = ((slots[:, None, None] == probe[None, :, :]).any(-1)
              & slot_valid[:, None]).astype(jnp.float32)  # (S, B)
    return slots.astype(jnp.int32), member, probe


def marginal_probe_topk_ref(tabs: jax.Array, cl_cells: jax.Array,
                            starts: jax.Array, m: int, k: int, nprobe: int):
    """Clique-structured probe for factored marginal workloads — the
    `ivf_probe` dataflow with the workload's own cliques as cells.

    The geometric IVF structure (centroids, row gathers) disappears: the
    per-clique marginal tables of the probe vector (``tabs`` =
    `MarginalWorkload.marginal_tables(v)`, (n_cliques, max_cells)) already
    hold every query's exact score, so the "centroid" statistic is the
    per-clique max |cell| — an exact upper bound, making the probe's top-k
    exact whenever the probed cliques cover k candidates. No (m, U) gather
    exists anywhere on this path: scoring is offsets + the segment sums
    that built ``tabs``.

    Args:
      tabs: (n_cliques, max_cells) f32 per-clique marginals of ``v``.
      cl_cells: (n_cliques,) int32 valid cell counts (tail cells are pad).
      starts: (n_cliques,) int32 first query id of each clique.
      m: total query count (augmented-id encoding).
      k / nprobe: top-k size and probed clique count.

    Returns ``(aug_idx (k,) int32, |scores| (k,) f32, n_scored int32)`` —
    augmented ids under the §3.4 sign convention, and the candidate count
    the probe actually scored (the n_scored trace term).
    """
    nc, mc = tabs.shape
    valid = jnp.arange(mc)[None, :] < cl_cells[:, None]
    a = jnp.where(valid, jnp.abs(tabs), -jnp.inf)
    cstat = jnp.max(a, axis=1)                       # exact per-clique bound
    _, probe = jax.lax.top_k(cstat, nprobe)
    cand_s = tabs[probe]                             # (nprobe, mc) signed
    cand_valid = valid[probe]
    qid = starts[probe][:, None] + jnp.arange(mc)[None, :]
    flat_s = cand_s.reshape(-1)
    flat_a = jnp.where(cand_valid.reshape(-1), jnp.abs(flat_s), -jnp.inf)
    top_a, pos = jax.lax.top_k(flat_a, k)
    qid_top = qid.reshape(-1)[pos]
    aug = jnp.where(flat_s[pos] >= 0, qid_top, qid_top + m)
    return (aug.astype(jnp.int32), top_a,
            jnp.sum(cand_valid).astype(jnp.int32))


def ivf_probe_topk_batch_ref(cents: jax.Array, cells: jax.Array,
                             V: jax.Array, Vb: jax.Array, k: int, nprobe: int,
                             absolute: bool = False):
    """Returns (idx (B, k), scores (B, k), n_valid (B,)) — candidates per
    lane in the batched kernel's slot order (ascending deduplicated cells,
    lane-masked), so parity with `ivf_probe_topk_batch` is exact."""
    slots, member, probe = batch_probe_slots(cents, cells, Vb, nprobe,
                                             absolute)
    cand = cells[slots]                                   # (S, cap)
    scores = jnp.einsum("scd,bd->bsc", V[jnp.clip(cand, 0)].astype(jnp.float32),
                        Vb.astype(jnp.float32))           # (B, S, cap)
    if absolute:
        scores = jnp.abs(scores)
    mask = (cand[None, :, :] >= 0) & (member.T[:, :, None] > 0)
    scores = jnp.where(mask, scores, -jnp.inf)
    B = Vb.shape[0]
    flat_s = scores.reshape(B, -1)
    flat_i = jnp.broadcast_to(cand.reshape(-1)[None, :], flat_s.shape)
    top_s, pos = jax.lax.top_k(flat_s, k)
    idx = jnp.where(jnp.isfinite(top_s),
                    jnp.take_along_axis(flat_i, pos, axis=1), -1)
    n_valid = jnp.sum(cells[probe] >= 0, axis=(1, 2)).astype(jnp.int32)
    return idx.astype(jnp.int32), top_s, n_valid
