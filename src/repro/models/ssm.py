"""Mamba-2 (SSD) block — attention-free sequence mixing.

Layer = in_proj → causal depthwise conv (x|B|C channels) → SiLU → SSD scan
(chunked state-space duality; `repro.kernels.ssd_scan` is the TPU kernel,
`ssd_chunked_jnp` the XLA path) → gated RMSNorm → out_proj.

Decode carries (conv ring state, SSM state (B,H,P,N)) — O(1) per token,
which is why mamba2 runs the `long_500k` shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd_scan.ref import ssd_chunked_jnp
from repro.models.common import ParamBuilder, rmsnorm, shard


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    return d_inner, H, cfg.ssm_state, cfg.ssm_headdim


def init_ssm(pb: ParamBuilder, cfg: ModelConfig, name: str = "ssm"):
    D = cfg.d_model
    d_inner, H, N, P = _dims(cfg)
    conv_ch = d_inner + 2 * N
    with pb.scope(name):
        pb("in_proj", (D, 2 * d_inner + 2 * N + H), ("embed", "rnn"))
        pb("conv_w", (cfg.ssm_conv, conv_ch), ("conv", "rnn"), dtype=jnp.float32)
        pb("conv_b", (conv_ch,), ("rnn",), init="zeros", dtype=jnp.float32)
        pb("dt_bias", (H,), ("rnn",), init="zeros", dtype=jnp.float32)
        pb("A_log", (H,), ("rnn",), init="zeros", dtype=jnp.float32)
        pb("D_skip", (H,), ("rnn",), init="ones", dtype=jnp.float32)
        pb("norm_scale", (d_inner,), ("rnn",), init="zeros", dtype=jnp.float32)
        pb("out_proj", (d_inner, D), ("rnn", "embed"))


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, Cch); w: (K, Cch)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def _split_proj(p, x, cfg):
    d_inner, H, N, P = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xbc, dt_raw


def ssm_forward(p, x, cfg: ModelConfig) -> jax.Array:
    B, S, D = x.shape
    d_inner, H, N, P = _dims(cfg)
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc.astype(jnp.float32), p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xs = shard(xs.reshape(B, S, H, P), "batch", None, "rnn", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked_jnp(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    y = y + p["D_skip"][None, None, :, None] * xs
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_scale"])
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])


# ------------------------------------------------------------- decoding ----
def init_ssm_cache(cfg: ModelConfig, batch: int, abstract=False):
    d_inner, H, N, P = _dims(cfg)
    conv_ch = d_inner + 2 * N
    shapes = {
        "conv": ((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
        "state": ((batch, H, P, N), jnp.float32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def ssm_decode(p, x, cache, cfg: ModelConfig):
    """x: (B, 1, D) → (y (B,1,D), new cache)."""
    B = x.shape[0]
    d_inner, H, N, P = _dims(cfg)
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    xbc = xbc[:, 0].astype(jnp.float32)                       # (B, Cch)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B, K, Cch)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(xbc_t, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, H, P)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                              # (B,H)
    dtx = dt[..., None] * xs                                  # (B,H,P)
    state = a[..., None, None] * cache["state"] + dtx[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", state, Cm) + p["D_skip"][None, :, None] * xs
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"conv": hist[:, 1:], "state": state}
