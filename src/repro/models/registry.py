"""Model factory."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.lm import LM


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
