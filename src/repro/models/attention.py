"""GQA attention: training/prefill forward + cached single-token decode.

Mask kinds: "attn" (causal), "attn_bidir" (encoder), "window_attn"
(sliding window), "chunk_attn" (llama4 iRoPE chunked-local). Decode uses a
ring buffer of size `window` for the local kinds — O(window) memory and
compute per token, which is what makes `long_500k` sub-quadratic.

The forward path uses the pure-jnp reference math (XLA fuses it well and it
is what the dry-run rooflines measure); on TPU backends the
`repro.kernels.flash_attention` Pallas kernel swaps in for prefill/train.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ref import attention_ref, make_mask
from repro.models.common import ParamBuilder, apply_mrope, apply_rope, shard

_MASK_OF_KIND = {
    "attn": "causal",
    "attn_bidir": "full",
    "window_attn": "window",
    "chunk_attn": "chunk",
    "xattn_dec": "causal",      # decoder self-attention half of the block
}

# beyond this kv length the forward path switches to the blockwise
# (online-softmax) attention so the (Sq × Skv) logit matrix never
# materializes — the XLA analogue of the flash kernel.
BLOCKWISE_THRESHOLD = 8192
BLOCKWISE_CHUNK = 1024


def blockwise_attention(q, k, v, *, mode: str, window: int = 0,
                        logit_softcap: float = 0.0,
                        chunk: int = BLOCKWISE_CHUNK) -> jax.Array:
    """Flash-style attention in pure jnp: scan over kv chunks with running
    (max, denom, acc) — O(Sq·chunk) live memory instead of O(Sq·Skv).

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D). Same semantics as
    `attention_ref` (GQA, mask modes, f32 softmax).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Skv + pad) // chunk
    kc = k.reshape(B, Hkv, n_chunks, chunk, D)
    vc = v.reshape(B, Hkv, n_chunks, chunk, D)
    qf = (q.astype(jnp.float32) * D ** -0.5).reshape(B, Hkv, g, Sq, D)
    # spread the per-chunk (B,Hkv,g,Sq,chunk) logit tensors over the model
    # axis (kv-head groups) — the dominant HBM term of long prefills.
    from repro.models.common import shard as _shard
    qf = _shard(qf, "batch", "kv_heads_act", None, None, None)
    kc = _shard(kc, "batch", "kv_heads_act", None, None, None)
    vc = _shard(vc, "batch", "kv_heads_act", None, None, None)
    qpos = jnp.arange(Sq)

    def step(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp
        kj = kj.astype(jnp.float32)
        s = jnp.einsum("bngsd,bncd->bngsc", qf, kj)
        if logit_softcap > 0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        kpos = j * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < Skv
        if mode in ("causal", "window", "chunk"):
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if mode == "window":
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        if mode == "chunk":
            mask = mask & ((kpos[None, :] // window) == (qpos[:, None] // window))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        msafe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - msafe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - msafe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bngsc,bncd->bngsd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    # carry must start with the same sharding the body produces, or the
    # partitioner reshards (all-gathers) the multi-GB accumulator every
    # chunk step (measured: EXPERIMENTS.md §Perf N5)
    m0 = _shard(jnp.full((B, Hkv, g, Sq), -jnp.inf, jnp.float32),
                "batch", "kv_heads_act", None, None)
    l0 = _shard(jnp.zeros((B, Hkv, g, Sq), jnp.float32),
                "batch", "kv_heads_act", None, None)
    a0 = _shard(jnp.zeros((B, Hkv, g, Sq, D), jnp.float32),
                "batch", "kv_heads_act", None, None, None)
    ks = jnp.moveaxis(kc, 2, 0)
    vs = jnp.moveaxis(vc, 2, 0)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (ks, vs, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def init_attention(pb: ParamBuilder, cfg: ModelConfig, name: str = "attn",
                   kv_dim: Optional[int] = None):
    D, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    Dh = cfg.resolved_head_dim
    kv_dim = kv_dim or D
    with pb.scope(name):
        pb("wq", (D, H, Dh), ("embed", "q_heads", "head_dim"))
        pb("wk", (kv_dim, Hkv, Dh), ("embed", "kv_heads", "head_dim"))
        pb("wv", (kv_dim, Hkv, Dh), ("embed", "kv_heads", "head_dim"))
        pb("wo", (H, Dh, D), ("q_heads", "head_dim", "embed"))


def _rope_qk(q, k, cfg: ModelConfig, kind: str, positions):
    """positions: (B, S) int32, or (3, B, S) for mrope."""
    use_rope = cfg.rope_mode != "none"
    if kind == "attn" and cfg.nope_on_global:
        use_rope = False                      # llama4 iRoPE: NoPE global layers
    if not use_rope:
        return q, k
    if cfg.rope_mode == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attn_forward(p, x, cfg: ModelConfig, kind: str, positions,
                 xkv: Optional[jax.Array] = None, return_kv: bool = False):
    """x: (B, S, D) → (B, S, D). xkv: cross-attention source (B, Skv, D).

    ``return_kv=True`` additionally returns the post-RoPE (k, v) tensors —
    the prefill cache feed.
    """
    mode = _MASK_OF_KIND[kind] if xkv is None else "full"
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    src = x if xkv is None else xkv
    k = jnp.einsum("bsd,dhk->bhsk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", src, p["wv"])
    q = shard(q, "batch", "heads_act", None, None)
    k = shard(k, "batch", "kv_heads", None, None)
    if xkv is None:
        q, k = _rope_qk(q, k, cfg, kind, positions)
    if k.shape[2] > BLOCKWISE_THRESHOLD:
        out = blockwise_attention(q, k, v, mode=mode, window=cfg.window,
                                  logit_softcap=cfg.logit_softcap)
    else:
        out = attention_ref(q, k, v, mode=mode, window=cfg.window,
                            logit_softcap=cfg.logit_softcap)
    out = shard(out, "batch", "heads_act", None, None)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    y = shard(y, "batch", None, None)
    if return_kv:
        return y, (k, v)
    return y


# ------------------------------------------------------------- decoding ----
def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    abstract: bool = False, dtype=jnp.bfloat16):
    """Ring buffer for local kinds; full-length buffer for global attention."""
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    size = max_len if kind in ("attn", "attn_bidir", "xattn_dec") \
        else min(cfg.window, max_len)
    shape = (batch, Hkv, size, Dh)
    if abstract:
        k = v = jax.ShapeDtypeStruct(shape, dtype)
    else:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
    return {"k": k, "v": v}


def attn_decode(p, x, cache, pos, cfg: ModelConfig, kind: str,
                positions=None):
    """One-token decode. x: (B, 1, D); pos: scalar int32 global position.

    Returns (y (B,1,D), new_cache).
    """
    mode = _MASK_OF_KIND[kind]
    B = x.shape[0]
    S = cache["k"].shape[2]
    is_ring = kind in ("window_attn", "chunk_attn")
    W = cfg.window if is_ring else 0

    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    if positions is None:
        positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
        if cfg.rope_mode == "mrope":
            positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    q, k_new = _rope_qk(q, k_new, cfg, kind, positions)

    slot = jnp.mod(pos, S) if is_ring else pos
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                           (0, 0, slot, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                           (0, 0, slot, 0))
    k_cache = shard(k_cache, "batch", "kv_heads", "kv_seq", None)
    v_cache = shard(v_cache, "batch", "kv_heads", "kv_seq", None)

    # global position each slot holds
    slots = jnp.arange(S)
    if is_ring:
        gpos = pos - jnp.mod(pos - slots, S)
    else:
        gpos = slots
    if mode == "causal" or mode == "full":
        valid = (gpos <= pos) & (gpos >= 0)
    elif mode == "window":
        valid = (gpos <= pos) & (gpos > pos - W) & (gpos >= 0)
    else:  # chunk
        valid = (gpos <= pos) & ((gpos // W) == (pos // W)) & (gpos >= 0)

    g = cfg.n_heads // cfg.n_kv_heads
    Dh = cfg.resolved_head_dim
    qh = q[:, :, 0].reshape(B, cfg.n_kv_heads, g, Dh).astype(jnp.float32)
    logits = jnp.einsum("bngk,bnsk->bngs", qh * Dh ** -0.5,
                        k_cache.astype(jnp.float32))
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngs,bnsk->bngk", w, v_cache.astype(jnp.float32))
    out = out.reshape(B, cfg.n_heads, 1, Dh).astype(x.dtype)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache}


def init_cross_cache(cfg: ModelConfig, p, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (whisper decode)."""
    k = jnp.einsum("bsd,dhk->bhsk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", enc_out, p["wv"])
    return {"k": k, "v": v}


def cross_decode(p, x, cross_cache, cfg: ModelConfig):
    """Cross-attention for one decode token against the cached encoder K/V."""
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    out = attention_ref(q, cross_cache["k"], cross_cache["v"], mode="full",
                        logit_softcap=cfg.logit_softcap)
    return jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
