"""LM assembly: stages of scanned layer-units → train / prefill / decode.

An architecture is a list of *stages*; each stage is a layer-unit pattern
(e.g. ``("rglru", "rglru", "attn")``) scanned over ``n_units`` with stacked
parameters — HLO size is independent of depth, and heterogeneous layouts
(RecurrentGemma 2:1, Llama-4 3:1 chunked:global) are exact.

The same parameter tree serves three entry points:
  * ``loss(params, batch)``      — training objective (next-token CE)
  * ``prefill(params, batch)``   — forward + cache extraction
  * ``decode_step(params, cache, tokens, pos)`` — one-token serving step

Caches mirror the param tree structure (stage → block → stacked-over-units)
so both move through ``jax.lax.scan`` together.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import mlp as mlp_mod
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamBuilder, apply_norm, init_norm, shard, sinusoidal_pos,
)

ATTN_KINDS = ("attn", "attn_bidir", "window_attn", "chunk_attn", "xattn_dec")


def _has_mlp(kind: str) -> bool:
    return kind != "ssm"


class LM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.stages, "ModelConfig.stages must be set"
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------- init ----
    def _init_block(self, pb: ParamBuilder, kind: str):
        cfg = self.cfg
        init_norm(pb, "norm_1", cfg.d_model, cfg.norm_type)
        if kind in ATTN_KINDS:
            att.init_attention(pb, cfg, "attn")
            if kind == "xattn_dec":
                init_norm(pb, "norm_x", cfg.d_model, cfg.norm_type)
                att.init_attention(pb, cfg, "xattn")
        elif kind == "ssm":
            ssm_mod.init_ssm(pb, cfg, "ssm")
        elif kind == "rglru":
            rg.init_rglru(pb, cfg, "rglru")
        else:
            raise ValueError(f"unknown block kind {kind!r}")
        if _has_mlp(kind):
            init_norm(pb, "norm_2", cfg.d_model, cfg.norm_type)
            mlp_mod.init_mlp(pb, cfg, "mlp")

    def init(self, key: Optional[jax.Array] = None, abstract: bool = False):
        """Returns (params, logical_specs)."""
        cfg = self.cfg
        pb = ParamBuilder(key, abstract=abstract, dtype=self.dtype)
        # d^-1/2 init keeps tied-head logits O(1) at depth
        pb("embedding", (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
           scale=cfg.d_model ** -0.5)
        if not cfg.tie_embeddings:
            pb("lm_head", (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))
        if cfg.is_encdec:
            with pb.scope("encoder"):
                with pb.stacked(cfg.encoder_layers):
                    with pb.scope("unit"):
                        with pb.scope("block_0"):
                            self._init_block(pb, "attn_bidir")
                init_norm(pb, "final_norm", cfg.d_model, cfg.norm_type)
        for si, (pattern, n_units) in enumerate(cfg.stages):
            with pb.scope(f"stage_{si}"):
                with pb.stacked(n_units):
                    for bi, kind in enumerate(pattern):
                        with pb.scope(f"block_{bi}"):
                            self._init_block(pb, kind)
        init_norm(pb, "final_norm", cfg.d_model, cfg.norm_type)
        return pb.params, pb.specs

    # ---------------------------------------------------------- forward ----
    def _block_fwd(self, p, h, kind, positions, h_enc=None, cache_len=0):
        """One block forward. Returns (h, cache|None) — cache when
        ``cache_len > 0`` (prefill)."""
        cfg = self.cfg
        cache = None
        hn = apply_norm(h, p["norm_1"], cfg.norm_type, cfg.norm_eps)
        if kind in ATTN_KINDS:
            if cache_len > 0:
                y, (k, v) = att.attn_forward(p["attn"], hn, cfg, kind,
                                             positions, return_kv=True)
                cache = self._kv_to_cache(k, v, kind, cache_len)
            else:
                y = att.attn_forward(p["attn"], hn, cfg, kind, positions)
            y = checkpoint_name(y, "tp_out")
            h = h + y
            if kind == "xattn_dec":
                hx = apply_norm(h, p["norm_x"], cfg.norm_type, cfg.norm_eps)
                h = h + att.attn_forward(p["xattn"], hx, cfg, kind, positions,
                                         xkv=h_enc)
        elif kind == "ssm":
            if cache_len > 0:
                y, cache = self._ssm_prefill(p["ssm"], hn)
            else:
                y = ssm_mod.ssm_forward(p["ssm"], hn, cfg)
            h = h + y
        elif kind == "rglru":
            if cache_len > 0:
                y, cache = self._rglru_prefill(p["rglru"], hn)
            else:
                y = rg.rglru_forward(p["rglru"], hn, cfg)
            h = h + y
        if _has_mlp(kind):
            hn2 = apply_norm(h, p["norm_2"], cfg.norm_type, cfg.norm_eps)
            y2 = mlp_mod.mlp_forward(p["mlp"], hn2, cfg)
            h = h + checkpoint_name(y2, "tp_out")
        return shard(h, "batch", "seq", None), cache

    def _kv_to_cache(self, k, v, kind, cache_len):
        """Convert prefill (B,Hkv,S,Dh) K/V into the decode cache layout."""
        cfg = self.cfg
        B, Hkv, S, Dh = k.shape
        if kind in ("window_attn", "chunk_attn"):
            W = min(cfg.window, cache_len)
            # ring layout: slot = pos % W for the last W positions
            last = jnp.arange(S - W, S) if S >= W else jnp.arange(S)
            kw, vw = k[:, :, -W:], v[:, :, -W:]
            slots = jnp.mod(jnp.arange(max(S - W, 0), S), W) if S >= W else \
                jnp.arange(S)
            kc = jnp.zeros((B, Hkv, W, Dh), k.dtype).at[:, :, slots].set(kw)
            vc = jnp.zeros((B, Hkv, W, Dh), v.dtype).at[:, :, slots].set(vw)
            return {"k": kc, "v": vc}
        size = cache_len
        pad = size - S
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return {"k": kc, "v": vc}

    def _ssm_prefill(self, p, hn):
        cfg = self.cfg
        B, S, D = hn.shape
        d_inner, H, N, P = ssm_mod._dims(cfg)
        z, xbc, dt_raw = ssm_mod._split_proj(p, hn, cfg)
        xbc_f = xbc.astype(jnp.float32)
        conv_in = jax.nn.silu(ssm_mod._causal_conv(xbc_f, p["conv_w"], p["conv_b"]))
        xs, Bm, Cm = jnp.split(conv_in, [d_inner, d_inner + N], axis=-1)
        xs = xs.reshape(B, S, H, P)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        from repro.kernels.ssd_scan.ref import ssd_chunked_jnp

        y, hT = ssd_chunked_jnp(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
        y = y + p["D_skip"][None, None, :, None] * xs
        y = y.reshape(B, S, d_inner)
        from repro.models.common import rmsnorm

        y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_scale"])
        out = jnp.einsum("bse,ed->bsd", y.astype(hn.dtype), p["out_proj"])
        K = cfg.ssm_conv - 1
        conv_hist = xbc_f[:, -K:] if S >= K else jnp.pad(
            xbc_f, ((0, 0), (K - S, 0), (0, 0)))
        return out, {"conv": conv_hist, "state": hT}

    def _rglru_prefill(self, p, hn):
        cfg = self.cfg
        B, S, D = hn.shape
        u_pre = jnp.einsum("bsd,dr->bsr", hn, p["w_x"]).astype(jnp.float32)
        u = rg._causal_conv(u_pre, p["conv_w"], p["conv_b"])
        log_a, b_term = rg._gates(p, u)
        hseq, _ = rg.rglru_scan(log_a, b_term)
        gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", hn, p["w_gate_branch"])
                           .astype(jnp.float32))
        y = (hseq * gate).astype(hn.dtype)
        out = jnp.einsum("bsr,rd->bsd", y, p["out_proj"])
        K = cfg.rglru_conv - 1
        conv_hist = u_pre[:, -K:] if S >= K else jnp.pad(
            u_pre, ((0, 0), (K - S, 0), (0, 0)))
        return out, {"conv": conv_hist, "h": hseq[:, -1].astype(jnp.float32)}

    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.input_embeds:
            h = batch["embeds"].astype(self.dtype)
        else:
            h = params["embedding"][batch["tokens"]]
        h = shard(h, "batch", "seq", None)
        B, S = h.shape[:2]
        if cfg.rope_mode == "mrope":
            positions = batch.get("positions")
            if positions is None:
                pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
                positions = jnp.stack([pos, pos, pos])
            elif positions.shape[0] == B and positions.shape[1] == 3:
                positions = jnp.moveaxis(positions, 1, 0)  # (B,3,S) → (3,B,S)
        elif cfg.rope_mode == "none":
            h = (h.astype(jnp.float32)
                 + sinusoidal_pos(S, cfg.d_model)[None]).astype(self.dtype)
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return h, positions

    def _encode(self, params, batch, remat: bool = False):
        cfg = self.cfg
        h = batch["enc_embeds"].astype(self.dtype)
        h = (h.astype(jnp.float32)
             + sinusoidal_pos(h.shape[1], cfg.d_model)[None]).astype(self.dtype)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        enc = params["encoder"]

        def unit(hc, up):
            out, _ = self._block_fwd(up["block_0"], hc, "attn_bidir", positions)
            return out, None

        if remat:
            unit = jax.checkpoint(unit)
        h, _ = jax.lax.scan(unit, h, enc["unit"])
        return apply_norm(h, enc["final_norm"], cfg.norm_type, cfg.norm_eps)

    @staticmethod
    def _remat_policy(remat):
        if remat in (True, "full"):
            return None  # save nothing
        if remat == "save_tp":
            # keep the outputs of TP-collective-producing sublayers: their
            # recomputation would replay the psum collectives in the bwd
            return jax.checkpoint_policies.save_only_these_names("tp_out")
        return None

    def forward(self, params, batch, remat=False):
        """Full forward → logits (B, S, V) in f32."""
        cfg = self.cfg
        h, positions = self._embed(params, batch)
        h_enc = self._encode(params, batch, remat) if cfg.is_encdec else None

        for si, (pattern, n_units) in enumerate(cfg.stages):
            stage_p = params[f"stage_{si}"]

            def unit(hc, up, _pattern=pattern):
                for bi, kind in enumerate(_pattern):
                    hc, _ = self._block_fwd(up[f"block_{bi}"], hc, kind,
                                            positions, h_enc=h_enc)
                return hc, None

            if remat:
                unit = jax.checkpoint(unit, policy=self._remat_policy(remat))
            h, _ = jax.lax.scan(unit, h, stage_p)

        h = apply_norm(h, params["final_norm"], cfg.norm_type, cfg.norm_eps)
        logits = self._logits(params, h)
        return shard(logits, "batch", "seq", "vocab")

    def _logits(self, params, h):
        cfg = self.cfg
        head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("...d,dv->...v", h, head.astype(h.dtype))
        logits = logits.astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask, -1e9, logits)
        return logits

    def loss(self, params, batch, remat: bool = False):
        """Next-token cross entropy (mean over positions)."""
        logits = self.forward(params, batch, remat)
        labels = batch.get("labels")
        if labels is None:
            labels = batch["tokens"][:, 1:]
            logits = logits[:, :-1]
        else:
            labels = labels[:, :logits.shape[1]]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: partitions cleanly
        # over the vocab-sharded logits (local partial + psum), where a
        # cross-shard gather would all-gather the full logits.
        vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
        gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                       axis=-1)
        return jnp.mean(logz - gold)

    # ------------------------------------------------------------ decode ---
    def init_cache(self, batch: int, max_len: int, abstract: bool = False):
        """Cache pytree mirroring the stage/block structure (stacked units)."""
        cfg = self.cfg

        def stacked(tree, n):
            def expand(x):
                if isinstance(x, jax.ShapeDtypeStruct):
                    return jax.ShapeDtypeStruct((n,) + x.shape, x.dtype)
                return jnp.broadcast_to(x[None], (n,) + x.shape)
            return jax.tree.map(expand, tree)

        def block_cache(kind):
            if kind in ("attn", "attn_bidir", "window_attn", "chunk_attn",
                        "xattn_dec"):
                c = att.init_attn_cache(cfg, kind, batch, max_len,
                                        abstract=abstract, dtype=self.dtype)
                if kind == "xattn_dec":
                    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
                    xshape = (batch, Hkv, cfg.enc_len, Dh)
                    if abstract:
                        c["xk"] = jax.ShapeDtypeStruct(xshape, self.dtype)
                        c["xv"] = jax.ShapeDtypeStruct(xshape, self.dtype)
                    else:
                        c["xk"] = jnp.zeros(xshape, self.dtype)
                        c["xv"] = jnp.zeros(xshape, self.dtype)
                return c
            if kind == "ssm":
                return ssm_mod.init_ssm_cache(cfg, batch, abstract=abstract)
            if kind == "rglru":
                return rg.init_rglru_cache(cfg, batch, abstract=abstract)
            raise ValueError(kind)

        cache = {}
        for si, (pattern, n_units) in enumerate(cfg.stages):
            cache[f"stage_{si}"] = {
                f"block_{bi}": stacked(block_cache(kind), n_units)
                for bi, kind in enumerate(pattern)
            }
        return cache

    def _block_decode(self, p, c, h, kind, pos):
        cfg = self.cfg
        hn = apply_norm(h, p["norm_1"], cfg.norm_type, cfg.norm_eps)
        if kind in ATTN_KINDS:
            y, kv = att.attn_decode(p["attn"], hn, {"k": c["k"], "v": c["v"]},
                                    pos, cfg,
                                    "attn" if kind == "xattn_dec" else kind)
            c = dict(c)
            c.update(kv)
            h = h + y
            if kind == "xattn_dec":
                hx = apply_norm(h, p["norm_x"], cfg.norm_type, cfg.norm_eps)
                h = h + att.cross_decode(p["xattn"], hx,
                                         {"k": c["xk"], "v": c["xv"]}, cfg)
        elif kind == "ssm":
            y, c = ssm_mod.ssm_decode(p["ssm"], hn, c, cfg)
            h = h + y
        elif kind == "rglru":
            y, c = rg.rglru_decode(p["rglru"], hn, c, cfg)
            h = h + y
        if _has_mlp(kind):
            hn2 = apply_norm(h, p["norm_2"], cfg.norm_type, cfg.norm_eps)
            h = h + mlp_mod.mlp_forward(p["mlp"], hn2, cfg)
        return h, c

    def decode_step(self, params, cache, tokens, pos):
        """One serving step. tokens: (B, 1) int32 (or embeds (B,1,D));
        pos: scalar int32 — the global position being written.
        Returns (logits (B, V) f32, new_cache)."""
        cfg = self.cfg
        if cfg.input_embeds:
            h = tokens.astype(self.dtype)
        else:
            h = params["embedding"][tokens]
        if cfg.rope_mode == "none":
            S = 1
            h = (h.astype(jnp.float32)
                 + sinusoidal_pos(S, cfg.d_model, offset=pos)[None]
                 ).astype(self.dtype)
        new_cache = {}
        for si, (pattern, n_units) in enumerate(cfg.stages):
            stage_p = params[f"stage_{si}"]
            stage_c = cache[f"stage_{si}"]

            def unit(hc, pc, _pattern=pattern):
                up, uc = pc
                new_uc = {}
                for bi, kind in enumerate(_pattern):
                    hc, cb = self._block_decode(up[f"block_{bi}"],
                                                uc[f"block_{bi}"], hc, kind, pos)
                    new_uc[f"block_{bi}"] = cb
                return hc, new_uc

            h, new_stage_c = jax.lax.scan(unit, h, (stage_p, stage_c))
            new_cache[f"stage_{si}"] = new_stage_c
        h = apply_norm(h, params["final_norm"], cfg.norm_type, cfg.norm_eps)
        logits = self._logits(params, h)
        return logits[:, 0], new_cache

    # ----------------------------------------------------------- prefill ---
    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Forward + cache extraction. Returns (last-position logits, cache)."""
        cfg = self.cfg
        h, positions = self._embed(params, batch)
        S = h.shape[1]
        max_len = max_len or S
        h_enc = self._encode(params, batch) if cfg.is_encdec else None

        cache = {}
        for si, (pattern, n_units) in enumerate(cfg.stages):
            stage_p = params[f"stage_{si}"]

            def unit(hc, up, _pattern=pattern):
                caches = {}
                for bi, kind in enumerate(_pattern):
                    hc, cb = self._block_fwd(up[f"block_{bi}"], hc, kind,
                                             positions, h_enc=h_enc,
                                             cache_len=max_len)
                    if kind == "xattn_dec":
                        cb["xk"] = jnp.einsum("bsd,dhk->bhsk", h_enc,
                                              up[f"block_{bi}"]["xattn"]["wk"])
                        cb["xv"] = jnp.einsum("bsd,dhk->bhsk", h_enc,
                                              up[f"block_{bi}"]["xattn"]["wv"])
                    caches[f"block_{bi}"] = cb
                return hc, caches

            h, stage_cache = jax.lax.scan(unit, h, stage_p)
            cache[f"stage_{si}"] = stage_cache

        h = apply_norm(h, params["final_norm"], cfg.norm_type, cfg.norm_eps)
        logits = self._logits(params, h[:, -1])
        return logits, cache
