"""Shared model machinery: params, norms, RoPE, logical sharding.

Params are plain nested dicts of arrays built through `ParamBuilder`, which
simultaneously records a parallel tree of *logical* PartitionSpecs (tuples
of logical axis names). `abstract=True` builds ShapeDtypeStructs instead of
arrays — the dry-run path, which never allocates.

Activation sharding goes through `shard(x, *logical_axes)`, resolved against
the ambient `ShardingRules`/mesh installed by `sharding_ctx` — a no-op when
no mesh is active (unit tests, CPU smoke runs).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShardingRules

_CTX = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh, rules: ShardingRules):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, rules)
    try:
        yield
    finally:
        _CTX.state = prev


def current_rules() -> Optional[ShardingRules]:
    state = getattr(_CTX, "state", None)
    return state[1] if state else None


def current_mesh_and_rules():
    return getattr(_CTX, "state", None)


def shard(x: jax.Array, *logicals) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op w/o mesh)."""
    state = getattr(_CTX, "state", None)
    if state is None:
        return x
    mesh, rules = state
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.spec(*logicals)))


class ParamBuilder:
    """Builds (params, logical_specs) trees with scoped names."""

    def __init__(self, key: Optional[jax.Array], abstract: bool = False,
                 dtype=jnp.bfloat16):
        self.key = key
        self.abstract = abstract
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}
        self._path: list = []
        self._stack: list = []

    @contextlib.contextmanager
    def scope(self, name: str):
        self._path.append(str(name))
        try:
            yield self
        finally:
            self._path.pop()

    @contextlib.contextmanager
    def stacked(self, n: int):
        """Prepend a (n,) 'layers' dim to every param created inside —
        the scan-over-layers stacking."""
        self._stack.append(n)
        try:
            yield self
        finally:
            self._stack.pop()

    def _insert(self, tree: dict, name: str, value):
        node = tree
        for p in self._path:
            node = node.setdefault(p, {})
        node[name] = value

    def __call__(self, name: str, shape, logical, *, scale: Optional[float] = None,
                 dtype=None, init: str = "normal"):
        dtype = dtype or self.dtype
        assert len(shape) == len(logical), (name, shape, logical)
        if scale is None and init == "normal":
            fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
            scale = fan_in ** -0.5
        shape = tuple(self._stack) + tuple(shape)
        logical = ("layers",) * len(self._stack) + tuple(logical)
        self._insert(self.specs, name, tuple(logical))
        if self.abstract:
            value = jax.ShapeDtypeStruct(shape, dtype)
        else:
            self.key, sub = jax.random.split(self.key)
            if init == "zeros":
                value = jnp.zeros(shape, dtype)
            elif init == "ones":
                value = jnp.ones(shape, dtype)
            else:
                value = (jax.random.normal(sub, shape, jnp.float32) * scale).astype(dtype)
        self._insert(self.params, name, value)
        return value


# ------------------------------------------------------------------ norms --
def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32) \
        + beta.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x, p, norm_type: str, eps: float):
    if norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


def init_norm(pb: ParamBuilder, name: str, d: int, norm_type: str):
    with pb.scope(name):
        pb("scale", (d,), ("embed",), init="zeros" if norm_type == "rmsnorm" else "ones",
           dtype=jnp.float32)
        if norm_type == "layernorm":
            pb("bias", (d,), ("embed",), init="zeros", dtype=jnp.float32)


# ------------------------------------------------------------------- rope --
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) int."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                              # (D/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) (t, h, w) ids.

    The D/2 rotary frequency slots are split into `sections` (per modality
    stream); each section rotates by its own position stream.
    """
    D = x.shape[-1]
    half = D // 2
    sections = tuple(int(s * half / sum(sections)) for s in sections)
    sections = sections[:-1] + (half - sum(sections[:-1]),)
    freqs = rope_freqs(D, theta)                              # (half,)
    # build per-slot positions by section
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        pos = positions[i]                                    # (B, S)
        ang = pos[:, None, :, None].astype(jnp.float32) * freqs[start:start + sec]
        parts.append(ang)
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                     # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(seq) + offset)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos / (10_000.0 ** (dim / d))
    out = jnp.zeros((seq, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
