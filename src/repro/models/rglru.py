"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block: x → [linear → causal conv → RG-LRU] ⊙ [linear → GeLU] → out proj.
RG-LRU recurrence (f32):

    r_t = σ(W_r x_t),  i_t = σ(W_i x_t)
    log a_t = −c · softplus(Λ) · r_t            (c = 8)
    h_t = a_t · h_{t−1} + √(1 − a_t²) · (i_t ⊙ x_t)

Training/prefill uses `jax.lax.associative_scan` (O(log S) depth — the
TPU-native mapping of a linear recurrence); decode is the one-step update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder, shard

_C = 8.0


def init_rglru(pb: ParamBuilder, cfg: ModelConfig, name: str = "rglru"):
    D, R = cfg.d_model, cfg.rglru_width
    with pb.scope(name):
        pb("w_x", (D, R), ("embed", "rnn"))
        pb("w_gate_branch", (D, R), ("embed", "rnn"))
        pb("conv_w", (cfg.rglru_conv, R), ("conv", "rnn"), dtype=jnp.float32)
        pb("conv_b", (R,), ("rnn",), init="zeros", dtype=jnp.float32)
        pb("w_r", (R, R), ("rnn", None))
        pb("w_i", (R, R), ("rnn", None))
        pb("lam", (R,), ("rnn",), init="ones", dtype=jnp.float32)
        pb("out_proj", (R, D), ("rnn", "embed"))


def _gates(p, u):
    """u: (..., R) f32 → (log_a, beta·(i⊙u))."""
    r = jax.nn.sigmoid(u @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(u @ p["w_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return log_a, beta * (i * u)


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K)) + b


def _combine(left, right):
    la1, b1 = left
    la2, b2 = right
    return la1 + la2, jnp.exp(la2) * b1 + b2


def rglru_scan(log_a, b_term, h0=None, chunk: int = 256):
    """Linear recurrence h_t = a_t h_{t−1} + b_t, chunked:

    outer `lax.scan` over chunks (O(B·R) carry), inner
    `associative_scan` within the chunk (O(Q log Q) transients) — bounded
    memory at 32k+ sequence lengths, unlike a flat associative scan whose
    AD residuals grow with S·log S.
    """
    B, S, R = log_a.shape
    pad = (-S) % chunk
    if pad:  # log_a = 0, b = 0 → identity
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b_term = jnp.pad(b_term, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    la = jnp.moveaxis(log_a.reshape(B, nc, chunk, R), 1, 0)
    bt = jnp.moveaxis(b_term.reshape(B, nc, chunk, R), 1, 0)

    def step(h, inp):
        la_c, b_c = inp                            # (B,Q,R)
        la0 = jnp.concatenate([jnp.zeros((B, 1, R), la_c.dtype), la_c], 1)
        b0 = jnp.concatenate([h[:, None, :], b_c], 1)
        _, hs = jax.lax.associative_scan(_combine, (la0, b0), axis=1)
        return hs[:, -1], hs[:, 1:]

    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, (la, bt))
    h_seq = jnp.moveaxis(ys, 0, 1).reshape(B, S + pad, R)[:, :S]
    return h_seq, hT


def rglru_forward(p, x, cfg: ModelConfig) -> jax.Array:
    B, S, D = x.shape
    u = jnp.einsum("bsd,dr->bsr", x, p["w_x"]).astype(jnp.float32)
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    u = shard(u, "batch", None, "rnn")
    log_a, b_term = _gates(p, u)
    h, _ = rglru_scan(log_a, b_term)
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_branch"])
                       .astype(jnp.float32))
    y = (h * gate).astype(x.dtype)
    return jnp.einsum("bsr,rd->bsd", y, p["out_proj"])


def init_rglru_cache(cfg: ModelConfig, batch: int, abstract=False):
    R = cfg.rglru_width
    shapes = {
        "conv": ((batch, cfg.rglru_conv - 1, R), jnp.float32),
        "h": ((batch, R), jnp.float32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def rglru_decode(p, x, cache, cfg: ModelConfig):
    u = jnp.einsum("bsd,dr->bsr", x, p["w_x"])[:, 0].astype(jnp.float32)  # (B,R)
    hist = jnp.concatenate([cache["conv"], u[:, None]], axis=1)
    u = jnp.einsum("bkr,kr->br", hist, p["conv_w"]) + p["conv_b"]
    log_a, b_term = _gates(p, u)
    h = jnp.exp(log_a) * cache["h"] + b_term
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_branch"])
                       [:, 0].astype(jnp.float32))
    y = (h * gate).astype(x.dtype)[:, None]
    out = jnp.einsum("bsr,rd->bsd", y, p["out_proj"])
    return out, {"conv": hist[:, 1:], "h": h}
