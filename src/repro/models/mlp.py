"""MLP variants + top-k MoE with sort-based capacity dispatch.

MoE (fixed shapes): token copies are sorted by expert id, placed into an
(E, C, d) capacity buffer by scatter, run through per-expert GEMMs, and
combined back with router weights. Overflowing tokens beyond capacity C
are dropped (standard Switch-style), C = capacity_factor · T · top_k / E.

Two execution paths:
  * dense/global (`moe_mlp_dense`) — single-device semantics; what unit
    tests and the non-mesh path use. Under pjit the global scatter cannot
    be partitioned (token-sharded updates into an expert-sharded buffer)
    and degenerates into per-layer all-reduces of the whole (E, C, d)
    buffer — measured at ~45 TB/device/step on qwen3-moe (EXPERIMENTS.md
    §Perf).
  * expert-parallel shard_map (`moe_mlp_ep`) — activations are replicated
    over "model" and sharded over the batch axes, so each device already
    holds its tokens and an E/TP slice of experts: route locally against
    all-gathered router logits, dispatch *locally*, run the local expert
    GEMMs, and combine with one psum over "model" (each token's experts
    live on exactly one model shard). Cross-device volume drops from
    O(E·C·d) to O(T_loc·d) per layer.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder, current_mesh_and_rules, shard


def init_mlp(pb: ParamBuilder, cfg: ModelConfig, name: str = "mlp"):
    D, F = cfg.d_model, cfg.d_ff
    gated = cfg.mlp_type in ("swiglu", "geglu")
    with pb.scope(name):
        if cfg.mlp_type == "moe":
            E = cfg.n_experts
            Fe = cfg.d_ff
            pb("router", (D, E), ("embed", "experts"), dtype=jnp.float32)
            pb("w_gate", (E, D, Fe), ("experts", "embed", "expert_mlp"))
            pb("w_up", (E, D, Fe), ("experts", "embed", "expert_mlp"))
            pb("w_down", (E, Fe, D), ("experts", "expert_mlp", "embed"))
            if cfg.moe_shared_expert:
                pb("ws_gate", (D, Fe), ("embed", "mlp"))
                pb("ws_up", (D, Fe), ("embed", "mlp"))
                pb("ws_down", (Fe, D), ("mlp", "embed"))
        else:
            if gated:
                pb("w_gate", (D, F), ("embed", "mlp"))
            pb("w_up", (D, F), ("embed", "mlp"))
            pb("w_down", (F, D), ("mlp", "embed"))


def _act(h, kind: str):
    if kind in ("swiglu",):
        return jax.nn.silu(h)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(h)
    if kind == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(kind)


def _dense_mlp(p, x, kind: str):
    if kind in ("swiglu", "geglu"):
        h = _act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]), kind) \
            * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:
        h = _act(jnp.einsum("bsd,df->bsf", x, p["w_up"]), kind)
    h = shard(h, "batch", None, "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def _shared_expert(p, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["ws_gate"])) \
        * jnp.einsum("bsd,df->bsf", x, p["ws_up"])
    return jnp.einsum("bsf,fd->bsd", h, p["ws_down"])


def _dispatch_compute(xt, gate_vals, expert_ids, w_gate, w_up, w_down,
                      C: int, e_offset=0):
    """Sort-based capacity dispatch + grouped GEMM + weighted combine.

    xt: (T, D); expert_ids/gate_vals: (T, K) *local* expert indices in
    [0, E_loc) (entries outside the range are dropped via the capacity
    mask); weights: (E_loc, D, F)/(E_loc, F, D). Returns (T, D).
    """
    T, D = xt.shape
    E_loc = w_gate.shape[0]
    K = expert_ids.shape[1]
    flat_e = expert_ids.reshape(T * K) - e_offset
    in_range = (flat_e >= 0) & (flat_e < E_loc)
    flat_e = jnp.clip(flat_e, 0, E_loc - 1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = jnp.where(in_range, gate_vals.reshape(T * K), 0.0)

    order = jnp.argsort(jnp.where(in_range, flat_e, E_loc))
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    s_in = in_range[order]
    counts = jnp.bincount(jnp.where(in_range, flat_e, E_loc), length=E_loc + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[jnp.where(s_in, se, E_loc)]
    keep = s_in & (pos < C)
    pos_c = jnp.clip(pos, 0, C - 1)

    # .add, not .set: dropped/out-of-range rows clip onto occupied slots and
    # must contribute nothing rather than clobber them with zeros.
    buf = jnp.zeros((E_loc, C, D), xt.dtype)
    buf = buf.at[se, pos_c].add(jnp.where(keep[:, None], xt[st], 0.0))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", buf, w_up)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)
    contrib = out_buf[se, pos_c] * jnp.where(keep, sg, 0.0)[:, None]
    return jnp.zeros((T, D), out_buf.dtype).at[st].add(contrib)


def _route(xt, router, K):
    logits = (xt.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return gate_vals, expert_ids


def moe_mlp_dense(p, x, cfg: ModelConfig):
    """Global-semantics MoE (single device / no mesh)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    C = max(1, math.ceil(cfg.moe_capacity_factor * T * K / E))
    xt = x.reshape(T, D)
    gate_vals, expert_ids = _route(xt, p["router"], K)
    y = _dispatch_compute(xt, gate_vals, expert_ids,
                          p["w_gate"], p["w_up"], p["w_down"], C)
    y = y.reshape(B, S, D)
    if cfg.moe_shared_expert:
        y = y + _shared_expert(p, x)
    return y.astype(x.dtype)


def moe_mlp_ep(p, x, cfg: ModelConfig, mesh, rules):
    """Expert-parallel MoE via shard_map (see module docstring)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    model_ax = rules.experts
    batch_ax = rules.batch
    n_model = mesh.shape[model_ax]
    E_loc = E // n_model
    T_glob = B * S

    def local(x_l, router, wg, wu, wd):
        Bl, Sl, Dl = x_l.shape
        Tl = Bl * Sl
        xt = x_l.reshape(Tl, Dl)
        # router is expert-sharded: gather the full score row per token
        logits_loc = (xt.astype(jnp.float32) @ router)
        logits = jax.lax.all_gather(logits_loc, model_ax, axis=1, tiled=True)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                            1e-9)
        # per-(data-shard × expert-shard) capacity keeps memory flat
        C = max(1, math.ceil(cfg.moe_capacity_factor * Tl * K / E))
        e_offset = jax.lax.axis_index(model_ax) * E_loc
        y = _dispatch_compute(xt, gate_vals, expert_ids, wg, wu, wd, C,
                              e_offset=e_offset)
        # each token's experts live on exactly one model shard → sum
        y = jax.lax.psum(y, model_ax)
        return y.reshape(Bl, Sl, Dl)

    y = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_ax, None, None), P(None, model_ax),
                  P(model_ax, None, None), P(model_ax, None, None),
                  P(model_ax, None, None)),
        out_specs=P(batch_ax, None, None),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.moe_shared_expert:
        y = y + _shared_expert(p, x)
    return y.astype(x.dtype)


def mlp_forward(p, x, cfg: ModelConfig):
    if cfg.mlp_type == "moe":
        state = current_mesh_and_rules()
        if state is not None and state[1].experts is not None \
                and cfg.n_experts % state[0].shape[state[1].experts] == 0:
            return moe_mlp_ep(p, x, cfg, state[0], state[1])
        return moe_mlp_dense(p, x, cfg)
    return _dense_mlp(p, x, cfg.mlp_type)


moe_mlp = moe_mlp_dense  # back-compat alias
