"""The assigned architecture zoo: composable JAX model definitions."""

from repro.models.registry import build_model
from repro.models.lm import LM

__all__ = ["build_model", "LM"]
