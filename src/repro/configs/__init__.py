"""Architecture registry: the 10 assigned configs + the paper's own workload.

``get_config(name)`` returns the exact assigned configuration;
``get_smoke_config(name)`` returns a reduced same-family config for CPU
smoke tests (small widths/depths, tiny vocab — structure preserved).
"""

from __future__ import annotations

import importlib

from repro.configs.base import (
    ModelConfig, ShapeConfig, ShardingRules, TrainConfig,
    SHAPES, TP_RULES, FSDP_TP_RULES, LONG_DECODE_RULES, uniform_stages,
)

ARCH_MODULES = {
    "mamba2-130m": "repro.configs.mamba2_130m",
    "minitron-8b": "repro.configs.minitron_8b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "llama3-8b": "repro.configs.llama3_8b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}

ARCH_NAMES = tuple(ARCH_MODULES)


def _module(name: str):
    if name not in ARCH_MODULES:
        raise ValueError(f"unknown arch {name!r}; options: {ARCH_NAMES}")
    return importlib.import_module(ARCH_MODULES[name])


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke()


__all__ = [
    "ModelConfig", "ShapeConfig", "ShardingRules", "TrainConfig",
    "SHAPES", "TP_RULES", "FSDP_TP_RULES", "LONG_DECODE_RULES",
    "uniform_stages", "ARCH_NAMES", "get_config", "get_smoke_config",
]
