"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936.
"""

from repro.configs.base import ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    head_dim=128,
    stages=uniform_stages("attn", 48),
    rope_theta=1_000_000.0,
    mlp_type="moe",
    n_experts=128,
    moe_top_k=8,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=256, head_dim=16, stages=uniform_stages("attn", 2),
        n_experts=8, moe_top_k=2,
    )
