"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. Backbone only:
the vision frontend is a stub — `input_specs()` provides precomputed patch
embeddings (B, S, d_model) and M-RoPE position ids (3, B, S).
"""

from repro.configs.base import ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    head_dim=128,
    stages=uniform_stages("attn", 80),
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    input_embeds=True,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, stages=uniform_stages("attn", 2),
    )
