"""Config dataclasses: model architecture, shapes, sharding rules, training.

Every assigned architecture is one `ModelConfig`; the four assigned input
shapes are `ShapeConfig`s; `ShardingRules` maps the model's *logical* array
axes onto mesh axes (DP/TP/FSDP/EP/SP are all expressed here).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------- model ----
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # block layout: list of (pattern, n_units); pattern entries are block
    # kinds: "attn" | "window_attn" | "chunk_attn" | "ssm" | "rglru"
    stages: Tuple[Tuple[Tuple[str, ...], int], ...] = ()

    # attention
    window: int = 0                 # window/chunk size for local attention
    rope_theta: float = 10_000.0
    rope_mode: str = "rope"         # rope | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    nope_on_global: bool = False    # llama4 iRoPE: no RoPE on global-attn layers
    logit_softcap: float = 0.0

    # mlp
    mlp_type: str = "swiglu"        # swiglu | geglu | squared_relu | gelu | moe
    n_experts: int = 0
    moe_top_k: int = 0
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25

    # ssm (mamba-2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # hybrid (RG-LRU)
    rglru_width: int = 0
    rglru_conv: int = 4

    # enc-dec (whisper)
    is_encdec: bool = False
    encoder_layers: int = 0
    enc_len: int = 1500

    # io
    input_embeds: bool = False      # vlm: inputs are precomputed embeddings
    tie_embeddings: bool = True
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # embedding-table padding so the vocab axis divides the TP degree; the
    # dry-run sets 512 (= 16 TP × 32), unit tests keep 1. Pad logits are
    # masked to −1e9 so loss/argmax semantics are unchanged.
    vocab_pad_multiple: int = 1

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    # long-context eligibility (drives the long_500k skip logic): pure
    # full-attention stacks are skipped; SSM/hybrid/local-attention layouts
    # (incl. llama4's 3:1 chunked:global iRoPE) run it.
    @property
    def subquadratic(self) -> bool:
        kinds = {k for pat, _ in self.stages for k in pat}
        local = {"ssm", "rglru", "window_attn", "chunk_attn"}
        return bool(kinds & local)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def uniform_stages(kind: str, n_layers: int) -> tuple:
    return (((kind,), n_layers),)


# --------------------------------------------------------------- shapes ----
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ------------------------------------------------------------- sharding ----
@dataclass(frozen=True)
class ShardingRules:
    """Logical-axis → mesh-axis mapping.

    Logical axes used by the model zoo:
      batch, seq, embed, mlp, q_heads, kv_heads, head_dim, vocab,
      experts, expert_mlp, layers, state, conv, rnn, enc_seq
    """
    batch: object = "data"          # ("pod","data") on the multi-pod mesh
    seq: object = None              # "data" for long-context decode (SP)
    embed: object = None            # "data" under FSDP
    mlp: object = "model"
    q_heads: object = "model"
    kv_heads: object = "model"
    # activation-level head sharding: applied to attention *intermediates*
    # even when the parameter head count doesn't divide the mesh axis (XLA
    # pads uneven intermediate shardings) — spreads the O(S²) logit tensors
    # across the model axis instead of replicating them.
    heads_act: object = "model"
    kv_heads_act: object = "model"
    head_dim: object = None
    vocab: object = "model"
    experts: object = "model"
    expert_mlp: object = None
    layers: object = None
    state: object = None
    conv: object = None
    rnn: object = "model"
    enc_seq: object = None
    kv_seq: object = None           # "data" to shard decode KV cache over seq

    def axis(self, logical: Optional[str]):
        if logical is None:
            return None
        return getattr(self, logical)

    def spec(self, *logicals) -> P:
        return P(*(self.axis(l) for l in logicals))


# default rule sets
TP_RULES = ShardingRules()
FSDP_TP_RULES = ShardingRules(embed="data", expert_mlp=None)
LONG_DECODE_RULES = ShardingRules(batch=None, kv_seq="data")


# ------------------------------------------------------------- training ----
@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adam"        # adam | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    state_dtype: str = "float32"   # moment dtype (bf16 for the huge archs)
    microbatches: int = 1          # gradient accumulation
    remat: str = "save_tp"         # none | full | save_tp
    grad_compression: bool = False # int8 error-feedback on the pod axis
    max_grad_norm: float = 1.0
    seed: int = 0
