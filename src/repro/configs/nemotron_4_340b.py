"""nemotron-4-340b [dense] — GQA, squared-ReLU [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
"""

from repro.configs.base import ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    head_dim=192,
    stages=uniform_stages("attn", 96),
    mlp_type="squared_relu",
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab_size=256, head_dim=24, stages=uniform_stages("attn", 2),
    )
