"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356].

32L (32 encoder + 32 decoder — the actual whisper-large-v3 layout; see
DESIGN.md §5) d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866; GELU MLP,
LayerNorm, sinusoidal positions. The conv frontend is a stub: inputs are
precomputed frame embeddings (B, enc_len, d_model). The assigned shapes
apply to the decoder stream; encoder length is the whisper-standard 1500.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    head_dim=64,
    stages=((("xattn_dec",), 32),),
    is_encdec=True,
    encoder_layers=32,
    enc_len=1500,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_mode="none",
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16,
        stages=((("xattn_dec",), 2),),
        encoder_layers=2, enc_len=32,
    )
