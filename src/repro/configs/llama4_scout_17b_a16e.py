"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, iRoPE
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048.
Attention layout (iRoPE): 3 chunked-local layers (RoPE, 8192 chunk) :
1 global layer (NoPE) — which makes the arch sub-quadratic end-to-end and
eligible for long_500k. MoE: 16 routed experts, top-1, + shared expert.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    stages=((("chunk_attn", "chunk_attn", "chunk_attn", "attn"), 12),),
    window=8192,
    nope_on_global=True,
    rope_theta=500_000.0,
    mlp_type="moe",
    n_experts=16,
    moe_top_k=1,
    moe_shared_expert=True,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, head_dim=16, window=16,
        stages=((("chunk_attn", "chunk_attn", "chunk_attn", "attn"), 1),),
        n_experts=4, moe_top_k=1,
    )
