"""minitron-8b [dense] — pruned nemotron [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000; squared-ReLU MLP.
"""

from repro.configs.base import ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16_384,
    vocab_size=256_000,
    head_dim=128,
    stages=uniform_stages("attn", 32),
    mlp_type="squared_relu",
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, stages=uniform_stages("attn", 2),
    )
