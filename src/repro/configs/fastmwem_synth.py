"""The paper's own workload as a selectable config (the `fastmwem-dist`
dry-run cell): m queries over a domain of size U, per-shard IVF structure,
LazyEM parameters. See repro.core.distributed for the mesh-parallel
iteration it parameterizes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class MWEMWorkloadConfig:
    name: str = "fastmwem-synth"
    m: int = 2 ** 24            # queries (complement-augmented count)
    U: int = 2 ** 14            # histogram domain |X|
    eps: float = 1.0
    delta: float = 1e-3
    T: int = 1000
    mode: str = "lazy"          # lazy | exhaustive
    nprobe: int = 10

    def derived(self, n_data_shards: int) -> dict:
        m_loc = self.m // n_data_shards
        k_loc = int(math.isqrt(m_loc))
        nlist = 2 * k_loc
        return {
            "m_loc": m_loc,
            "k_loc": k_loc,
            "nlist": nlist,
            "cap": max(8, math.ceil(2.0 * m_loc / nlist)),
            "tail_cap": 4 * k_loc,
        }


CONFIG = MWEMWorkloadConfig()


def smoke() -> MWEMWorkloadConfig:
    return MWEMWorkloadConfig(m=4096, U=256, T=20)
