"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attn-free (d_ff=0), vocab=50280, ssm_state=128.
d_inner = 2·768 = 1536, headdim 64 → 24 SSD heads, 1 B/C group.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,            # unused (attention-free)
    n_kv_heads=12,
    d_ff=0,
    vocab_size=50_280,
    stages=((("ssm",), 24),),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=64,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, vocab_size=256,
        stages=((("ssm",), 2),),
        ssm_state=16, ssm_headdim=16, ssm_chunk=8,
    )
