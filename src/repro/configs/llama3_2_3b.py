"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256; SwiGLU; tied
embeddings (the 3.2 small models tie).
"""

from repro.configs.base import ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128_256,
    head_dim=128,
    stages=uniform_stages("attn", 28),
    mlp_type="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, head_dim=12, stages=uniform_stages("attn", 2),
    )
