"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; SwiGLU,
RoPE θ=500000.
"""

from repro.configs.base import ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    head_dim=128,
    stages=uniform_stages("attn", 32),
    mlp_type="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, stages=uniform_stages("attn", 2),
    )
