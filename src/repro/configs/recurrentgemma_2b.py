"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 2:1
[arXiv:2402.19427].

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000; GeGLU MLP;
layout (R, R, A)×8 + (R, R) = 26 blocks; local attention window 2048.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    stages=(
        (("rglru", "rglru", "window_attn"), 8),
        (("rglru", "rglru"), 1),
    ),
    window=2048,
    mlp_type="geglu",
    rglru_width=2560,
    rglru_conv=4,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=256, head_dim=16, window=16,
        stages=((("rglru", "rglru", "window_attn"), 1), (("rglru", "rglru"), 1)),
        rglru_width=64,
    )
