"""Privacy accounting (paper §B, Thm B.1) and budget calibration.

The ledger tracks every mechanism invocation and the extra failure mass the
index contributes (Thm 3.3 adds ``γ = 1/m`` to δ when the k-MIPS structure
may fail). Composition is reported three ways:

* basic:      (Σ ε_i, Σ δ_i)
* paper B.1:  ε̃ = ε√(2k ln 1/δ′) + 2kε²        (as printed in the paper)
* tight B.1:  ε̃ = ε√(2k ln 1/δ′) + kε(e^ε − 1)  (Dwork-Rothblum-Vadhan)

and the calibration helpers invert the paper's per-iteration formulas
(Alg. 1: ε₀ = ε/√(T ln 1/δ); Alg. 3: ε₀ = ε/√(8T log 1/δ)).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.faults import fault_site


def advanced_composition(
    eps0: float, delta0: float, k: int, delta_prime: float, tight: bool = False
) -> tuple[float, float]:
    """Compose k adaptive (ε₀, δ₀)-DP mechanisms (Thm B.1)."""
    if k == 0:
        return 0.0, 0.0
    head = eps0 * math.sqrt(2.0 * k * math.log(1.0 / delta_prime))
    tail = k * eps0 * (math.expm1(eps0)) if tight else 2.0 * k * eps0 * eps0
    return head + tail, k * delta0 + delta_prime


def calibrate_eps0(eps: float, delta: float, T: int, scheme: str = "mwem") -> float:
    """Per-iteration budget from a global (ε, δ) target.

    ``scheme="mwem"`` follows Alg. 1/2: ε₀ = ε / √(T ln(1/δ)).
    ``scheme="lp"`` follows Alg. 3:     ε₀ = ε / √(8 T log(1/δ)).
    """
    if scheme == "mwem":
        return eps / math.sqrt(T * math.log(1.0 / delta))
    if scheme == "lp":
        return eps / math.sqrt(8.0 * T * math.log(1.0 / delta))
    raise ValueError(f"unknown scheme {scheme!r}")


@dataclass
class PrivacyLedger:
    """Append-only record of privacy events for one end-to-end run.

    Two-phase budget commit (DESIGN.md §10): a serving tier that charges
    budget *at dispatch* cannot survive a crash — an exception between the
    charge and the answer either leaks ε (charged, nothing released) or
    invites a double charge on retry. `reserve` holds a release's exact
    cost bundle against the ledger without touching the composed state;
    `commit` applies it through the very same `record_events` path a direct
    charge would take (bitwise-equal ledger state in both composition
    modes), and `abort` refunds it. Outstanding reservations are visible to
    admission via `reserved_bundle` so queued-but-unexecuted requests still
    count against the budget — but they survive any crash of the code that
    queued them, because they live here, not in a transient queue.
    """

    target_delta_prime: float = 1e-9
    events: list = field(default_factory=list)
    index_failure_mass: float = 0.0  # γ: P[k-MIPS structure answers wrongly]
    approx_slack: float = 0.0        # Σ 2c from runtime-preserving approx top-k (Thm F.2)
    # observers called with (self) after every mutating record — the obs
    # layer hangs per-tenant ε/δ-spent gauges here. Excluded from repr/eq
    # so ledgers still compare by their privacy state alone.
    hooks: list = field(default_factory=list, repr=False, compare=False)
    # rid -> (events, gamma, slack) bundles reserved but not yet committed.
    # Excluded from eq: a recovered ledger has resolved every reservation,
    # and equality means "same composed privacy state".
    reservations: dict = field(default_factory=dict, repr=False, compare=False)
    _next_rid: int = field(default=0, repr=False, compare=False)

    def add_hook(self, fn) -> None:
        """Register ``fn(ledger)`` to fire after every mutating record."""
        self.hooks.append(fn)

    def _notify(self) -> None:
        for fn in self.hooks:
            fn(self)

    # ------------------------------------------------- two-phase commit
    @property
    def next_rid(self) -> int:
        """The id the next `reserve` will hand out. Journal recovery needs
        it: rids key WAL records, so a recovered ledger must never re-issue
        an id the pre-crash process already journaled."""
        return self._next_rid

    def advance_rid(self, next_rid: int) -> None:
        """Fast-forward the reservation-id counter to at least ``next_rid``
        (never backward). Called by `journal.recover`/`ReleaseService.adopt`
        so post-recovery reservations cannot collide with a pre-crash rid
        still referenced by the WAL — a reused rid would let a later
        ``committed``/``aborted`` record resolve the *wrong* reservation on
        the next replay."""
        self._next_rid = max(self._next_rid, int(next_rid))

    def reserve(self, events, gamma: float = 0.0, slack: float = 0.0) -> int:
        """Phase one: hold a cost bundle against this ledger.

        Nothing is spent — `composed()` is unchanged and hooks do NOT fire
        (the budget gauges report committed spend only). Returns a
        reservation id for `commit`/`abort`.
        """
        rid = self._next_rid
        self._next_rid += 1
        self.reservations[rid] = (
            [(e0, d0, label) for e0, d0, label in events],
            float(gamma), float(slack))
        return rid

    def commit(self, rid: int) -> None:
        """Phase two: apply a reserved bundle to the ledger.

        Routes through `record_events`, so reserve→commit leaves the ledger
        bitwise equal to a direct `record_events` of the same bundle (and
        hooks fire here, exactly once)."""
        fault_site("ledger.commit")
        try:
            bundle = self.reservations.pop(rid)
        except KeyError:
            raise KeyError(f"unknown or already-resolved reservation {rid}")
        self.record_events(*bundle)

    def abort(self, rid: int) -> None:
        """Drop a reservation — the refund path (expired deadline, failed
        wave, shed load). A no-op on the composed state; hooks don't fire."""
        try:
            del self.reservations[rid]
        except KeyError:
            raise KeyError(f"unknown or already-resolved reservation {rid}")

    def reserved_bundle(self) -> tuple[list, float, float]:
        """Aggregate ``(events, γ, Σ2c)`` over all outstanding reservations
        — the admission controller's ``reserved=`` input, so queued
        requests count against the budget until committed or aborted."""
        events: list = []
        gamma = slack = 0.0
        for ev, g, s in self.reservations.values():
            events.extend(ev)
            gamma += g
            slack += s
        return events, gamma, slack

    def record(self, eps0: float, delta0: float = 0.0, label: str = "") -> None:
        self.events.append((eps0, delta0, label))
        self._notify()

    def record_index_failure(self, gamma: float) -> None:
        """Thm 3.3: an imperfect index adds γ to the δ of the whole run."""
        self.index_failure_mass += gamma
        self._notify()

    def record_approx_slack(self, c: float) -> None:
        """Thm F.2: a c-approximate top-k costs +2c in ε for that invocation."""
        self.approx_slack += 2.0 * c
        self._notify()

    def record_events(self, events, gamma: float = 0.0, slack: float = 0.0) -> None:
        """Append a pre-computed cost bundle (the admitted counterpart of
        `preview`): raw events, index failure mass γ, and *already-doubled*
        approx slack Σ2c."""
        self.events.extend((e0, d0, label) for e0, d0, label in events)
        self.index_failure_mass += gamma
        self.approx_slack += slack
        self._notify()

    def bundle(self) -> tuple[list, float, float]:
        """Snapshot of the ledger's raw cost state ``(events, γ, Σ2c)`` —
        the triple `record_events`/`preview` consume, so a bundle taken
        here can be replayed into a scratch ledger (marginal-cost
        accounting) or held as a reservation (admission control)."""
        return list(self.events), self.index_failure_mass, self.approx_slack

    def composed(self, tight: bool = False) -> tuple[float, float]:
        """Total (ε, δ) over all events, plus index failure mass and slack.

        Events are grouped by their ε₀ (homogeneous composition within each
        group, basic composition across groups — a safe upper bound).
        """
        return self.preview(tight=tight)

    def preview(
        self,
        events=(),
        gamma: float = 0.0,
        slack: float = 0.0,
        tight: bool = False,
    ) -> tuple[float, float]:
        """Composed (ε, δ) if ``events`` (plus ``gamma`` failure mass and
        ``slack`` approx-ε) were appended — without mutating the ledger.

        This is the admission-control primitive: a release's cost is a list
        of (ε₀, δ₀, label) events (see `repro.core.mwem.release_cost`), and
        the service asks "what would this ledger compose to with them?"
        before spending anything.
        """
        groups: dict[tuple[float, float], int] = {}
        for e0, d0, _ in list(self.events) + list(events):
            groups[(e0, d0)] = groups.get((e0, d0), 0) + 1
        eps_total, delta_total = 0.0, 0.0
        for (e0, d0), k in groups.items():
            e, d = advanced_composition(e0, d0, k, self.target_delta_prime, tight)
            eps_total += e
            delta_total += d
        return (eps_total + self.approx_slack + slack,
                delta_total + self.index_failure_mass + gamma)

    def remaining(
        self, eps_target: float, delta_target: float, tight: bool = False
    ) -> tuple[float, float]:
        """Unspent (ε, δ) against a global budget: target − composed().

        Negative components mean the ledger has already overshot the budget
        (possible because advanced composition is superadditive across
        heterogeneous event groups).
        """
        eps, delta = self.composed(tight=tight)
        return eps_target - eps, delta_target - delta

    def would_exceed(
        self,
        eps_target: float,
        delta_target: float,
        events=(),
        gamma: float = 0.0,
        slack: float = 0.0,
        tight: bool = False,
    ) -> bool:
        """True iff appending ``events``/``gamma``/``slack`` would push the
        composed totals past (eps_target, delta_target)."""
        eps, delta = self.preview(events, gamma, slack, tight=tight)
        return eps > eps_target or delta > delta_target

    def basic(self) -> tuple[float, float]:
        eps = sum(e for e, _, _ in self.events) + self.approx_slack
        delta = sum(d for _, d, _ in self.events) + self.index_failure_mass
        return eps, delta
