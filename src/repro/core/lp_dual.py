"""Constraint-private LPs via dense MWU on the dual (paper §4.2, Thm 4.4).

Packing/covering LPs ``max c^T x s.t. Ax ≤ b`` where neighboring databases
differ by one *constraint row*. The dual player maintains a 1/s-dense
distribution ``y`` over constraints (Bregman-projected after each MWU step,
Lemma A.3 bounds the sensitivity); the primal oracle picks the vertex
``v_j = (OPT/c_j)·e_j`` of ``K_OPT`` minimizing expected violation, i.e.
maximizes ``⟨y, N_j⟩`` with the *preprocessed* vectors

    N_j = −(OPT/c_j) · A[:, j]  ∈ R^m,  j ∈ [d].

LazyEM over a k-MIPS index on {N_j} gives O(m√d) per-iteration time instead
of O(md) — the large-width regime of Thm 4.4.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.accountant import PrivacyLedger
from repro.core.bregman import bregman_project_dense
from repro.core.gumbel import gumbel
from repro.core.lazy_em import default_tail_cap, lazy_em_from_topk


@dataclass(frozen=True)
class DualLPConfig:
    eps: float = 1.0
    delta: float = 1e-3
    alpha: float = 0.5
    s: int = 16                  # density parameter: ≤ s−1 constraints may violate
    T: int = 200
    mode: str = "fast"           # "exact" | "fast"
    k: Optional[int] = None
    tail_cap: Optional[int] = None
    margin_slack: float = 0.0
    eta: Optional[float] = None


@dataclass
class DualLPResult:
    x_bar: jax.Array
    violations: jax.Array
    n_violated: int              # constraints with A x̄ > b + α
    selected: list = field(default_factory=list)
    n_scored: list = field(default_factory=list)
    overflow_count: int = 0
    iter_seconds: list = field(default_factory=list)
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)


@partial(jax.jit, static_argnames=("scale",))
def _exact_select_dual(key, N, y, scale: float):
    scores = (N @ y) * scale     # N is (d, m): score_j = ⟨y, N_j⟩
    g = gumbel(key, scores.shape)
    return jnp.argmax(scores + g)


def solve_constraint_private_lp(
    A: jax.Array,
    b: jax.Array,
    c: jax.Array,
    opt: float,
    cfg: DualLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> DualLPResult:
    """Dense-MWU dual solver. ``index`` must be built on rows of N (d, m)."""
    m, d = A.shape
    N = -(opt / c)[:, None] * A.T          # (d, m): N_j as rows
    c_min = float(jnp.min(c))
    b_max = float(jnp.max(b))
    rho = max(opt / c_min - b_max, 1e-6)   # §G width
    T = cfg.T
    eta = cfg.eta if cfg.eta is not None else min(0.5, math.sqrt(math.log(m) / T))
    eps_prime = cfg.eps / math.sqrt(2.0 * T * math.log(1.0 / cfg.delta))
    sensitivity = 3.0 * opt / (c_min * cfg.s)  # §G: y moves ≤ 2/s, one row add
    scale = float(eps_prime / (2.0 * sensitivity))
    k = cfg.k or max(1, math.ceil(math.sqrt(d)))
    tail_cap = cfg.tail_cap or default_tail_cap(d)

    res = DualLPResult(x_bar=None, violations=None, n_violated=-1,
                       ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        if index is None:
            raise ValueError("fast mode requires a k-MIPS index over N_j rows")
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / d))
        c_idx = float(getattr(index, "approx_margin", 0.0))

        @jax.jit
        def fast_select(key, topk_idx, topk_scores, y):
            return lazy_em_from_topk(
                key, topk_idx, topk_scores * scale, d,
                score_fn=lambda idx: (N[idx] @ y) * scale,
                tail_cap=tail_cap,
                margin_slack=cfg.margin_slack * scale if cfg.margin_slack else 0.0,
            )

    @partial(jax.jit, static_argnames=())
    def dual_update(logY, x_vertex):
        # Constraint player upweights violated constraints: loss (b − A x*)/ρ.
        loss = (b - A @ x_vertex) / rho
        logY_new = logY - float(eta) * loss
        logY_new = logY_new - jnp.max(logY_new)
        y = bregman_project_dense(jnp.exp(logY_new), float(cfg.s))
        return logY_new, y

    logY = jnp.zeros((m,), jnp.float32)
    y = jnp.full((m,), 1.0 / m, jnp.float32)
    x_sum = jnp.zeros((d,), jnp.float32)

    for _ in range(T):
        key, k_sel = jax.random.split(key)
        t0 = time.perf_counter()
        if cfg.mode == "exact":
            j = int(_exact_select_dual(k_sel, N, y, scale))
            res.n_scored.append(d)
        else:
            idx, raw = index.query(y, k)
            out = fast_select(k_sel, idx, raw, y)
            if bool(out.overflow):
                j = int(_exact_select_dual(k_sel, N, y, scale))
                res.overflow_count += 1
                res.n_scored.append(d)
            else:
                j = int(out.index)
                res.n_scored.append(int(out.n_scored))
        res.ledger.record(eps_prime, 0.0, "dual_oracle")
        if cfg.mode == "fast" and c_idx > 0.0 and cfg.margin_slack == 0.0:
            res.ledger.record_approx_slack(c_idx)
        x_vertex = jnp.zeros((d,), jnp.float32).at[j].set(opt / float(c[j]))
        x_sum = x_sum + x_vertex
        logY, y = dual_update(logY, x_vertex)
        jax.block_until_ready(y)
        res.iter_seconds.append(time.perf_counter() - t0)
        res.selected.append(j)

    x_bar = x_sum / T
    res.x_bar = x_bar
    res.violations = A @ x_bar - b
    res.n_violated = int(jnp.sum(res.violations > cfg.alpha))
    return res
