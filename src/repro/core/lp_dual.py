"""Constraint-private LPs via dense MWU on the dual (paper §4.2, Thm 4.4).

Packing/covering LPs ``max c^T x s.t. Ax ≤ b`` where neighboring databases
differ by one *constraint row*. The dual player maintains a 1/s-dense
distribution ``y`` over constraints (Bregman-projected after each MWU step,
Lemma A.3 bounds the sensitivity); the primal oracle picks the vertex
``v_j = (OPT/c_j)·e_j`` of ``K_OPT`` minimizing expected violation, i.e.
maximizes ``⟨y, N_j⟩`` with the *preprocessed* vectors

    N_j = −(OPT/c_j) · A[:, j]  ∈ R^m,  j ∈ [d].

LazyEM over a k-MIPS index on {N_j} gives O(m√d) per-iteration time instead
of O(md) — the large-width regime of Thm 4.4.

Like the scalar solver (and the MWEM engine it mirrors), two drivers execute
the same iteration: `solve_constraint_private_lp_fused` runs the whole
T-iteration loop as one jitted `lax.scan` — in-graph index probe, LazyEM,
`lax.cond` overflow fallback (fresh `fallback_key` stream), the vertex
pick, and the Bregman projection all on device — while ``driver="host"``
keeps the reference Python loop. Both consume the identical `lp_split_chain`
key chain, so they are bitwise interchangeable (tests/test_lp_fused.py).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accountant import PrivacyLedger
from repro.core.bregman import bregman_project_dense
from repro.core.gumbel import gumbel
from repro.core.lazy_em import (default_tail_cap, fallback_key,
                                lazy_em_from_topk)
from repro.core.lp_scalar import (ScalarLPConfig, _check_lp_fast_index,
                                  _lp_fused_driver, _record_lp_iteration,
                                  _resolve_lp_driver, lp_split_chain,
                                  scalar_lp_release_cost)
from repro.obs.clock import perf_counter
from repro.obs.telemetry import MechanismTelemetry, record_run
from repro.obs.trace import annotate as obs_annotate


@dataclass(frozen=True)
class DualLPConfig:
    eps: float = 1.0
    delta: float = 1e-3
    alpha: float = 0.5
    s: int = 16                  # density parameter: ≤ s−1 constraints may violate
    T: int = 200
    mode: str = "fast"           # "exact" | "fast"
    driver: str = "auto"         # "auto" | "fused" | "host"
    k: Optional[int] = None
    tail_cap: Optional[int] = None
    margin_slack: float = 0.0
    eta: Optional[float] = None


@dataclass
class DualLPResult:
    x_bar: jax.Array
    violations: jax.Array
    n_violated: int              # constraints with A x̄ > b + α
    selected: list = field(default_factory=list)
    n_scored: list = field(default_factory=list)
    overflow_count: int = 0
    iter_seconds: list = field(default_factory=list)
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)
    telemetry: Optional[MechanismTelemetry] = None  # repro.obs aggregation


class _DualCalibration(NamedTuple):
    T: int
    eta: float
    rho: float
    eps_prime: float
    scale: float
    k: int
    tail_cap: int


def _dual_eps_prime(cfg: DualLPConfig) -> float:
    """Per-iteration budget ε′ = ε/√(2T ln 1/δ) — cfg-only, so the cost
    bundle (`dual_lp_release_cost`) and the drivers (`_dual_calibrate`)
    cannot drift apart."""
    return cfg.eps / math.sqrt(2.0 * cfg.T * math.log(1.0 / cfg.delta))


def _dual_calibrate(A, b, c, opt: float, cfg: DualLPConfig) -> _DualCalibration:
    """Per-iteration budget and scales — one point of truth shared by both
    drivers and by `dual_lp_release_cost` (the admission contract)."""
    m, d = A.shape
    c_min = float(jnp.min(c))
    b_max = float(jnp.max(b))
    rho = max(opt / c_min - b_max, 1e-6)   # §G width
    T = cfg.T
    eta = cfg.eta if cfg.eta is not None else min(0.5, math.sqrt(math.log(m) / T))
    eps_prime = _dual_eps_prime(cfg)
    sensitivity = 3.0 * opt / (c_min * cfg.s)  # §G: y moves ≤ 2/s, one row add
    return _DualCalibration(
        T=T,
        eta=float(eta),
        rho=float(rho),
        eps_prime=eps_prime,
        scale=float(eps_prime / (2.0 * sensitivity)),
        k=cfg.k or max(1, math.ceil(math.sqrt(d))),
        tail_cap=cfg.tail_cap or default_tail_cap(d),
    )


def dual_lp_release_cost(A, cfg: DualLPConfig, index=None
                         ) -> tuple[list, float, float]:
    """The exact privacy-cost bundle one `solve_constraint_private_lp*` run
    records — ``(events, gamma, slack)``; ``PrivacyLedger.preview`` of it
    equals the post-run ``composed()`` in both composition modes.

    Only budget-relevant calibration is needed: ε′ depends on cfg alone and
    the failure mass defaults to 1/d, so ``A`` supplies shapes only.
    """
    d = jnp.asarray(A).shape[1]
    eps_prime = _dual_eps_prime(cfg)
    c_idx = _check_lp_fast_index(cfg, index, fused=False, what="N_j rows")
    tmp = PrivacyLedger()
    if cfg.mode == "fast":
        tmp.record_index_failure(getattr(index, "failure_mass", 1.0 / d))
    for _ in range(cfg.T):
        _record_lp_iteration(tmp, cfg.mode, eps_prime, "dual_oracle",
                             c_idx, cfg.margin_slack)
    return tmp.bundle()


def lp_release_cost(cfg, A, index=None) -> tuple[list, float, float]:
    """Cost bundle for either LP solver, dispatched on the config type —
    the single admission-control entry point (`ReleaseService.submit_lp`,
    `AdmissionController.check_lp`)."""
    if isinstance(cfg, ScalarLPConfig):
        return scalar_lp_release_cost(A, cfg, index=index)
    if isinstance(cfg, DualLPConfig):
        return dual_lp_release_cost(A, cfg, index=index)
    raise TypeError(f"unknown LP config type {type(cfg).__name__}")


def _exact_select_dual_raw(key, N, y, scale):
    """Exhaustive EM oracle over the d vertices: score_j = ⟨y, N_j⟩."""
    scores = (N @ y) * scale     # N is (d, m)
    g = gumbel(key, scores.shape)
    return jnp.argmax(scores + g).astype(jnp.int32)


_exact_select_dual = jax.jit(_exact_select_dual_raw, static_argnames=("scale",))


def _vertex_raw(j, c, opt: float, d: int):
    """The K_OPT vertex v_j = (OPT/c_j)·e_j, built in-graph so host and
    fused drivers round identically."""
    return jnp.zeros((d,), jnp.float32).at[j].set(opt / c[j])


_vertex = jax.jit(_vertex_raw, static_argnames=("opt", "d"))


def _dual_step(logY, x_vertex, A, b, eta: float, rho: float, s: int):
    """One MWU step of the constraint player: upweight violated constraints
    (loss (b − A x*)/ρ), then Bregman-project onto the 1/s-dense simplex."""
    loss = (b - A @ x_vertex) / rho
    logY_new = logY - eta * loss
    logY_new = logY_new - jnp.max(logY_new)
    y = bregman_project_dense(jnp.exp(logY_new), float(s))
    return logY_new, y


_dual_update = jax.jit(_dual_step, static_argnames=("eta", "rho", "s"))


# ---------------------------------------------------------------------------
# Fused on-device driver (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _dual_core(A: jax.Array, b: jax.Array, c: jax.Array, N: jax.Array,
               key: jax.Array, *, query_fn, T: int, mode: str, eta: float,
               rho: float, s: int, opt: float, scale: float, k: int,
               tail_cap: int, margin_slack: float):
    """The whole §4.2 dual loop as one `lax.scan` — selection, the overflow
    fallback, the vertex pick, and the Bregman projection stay on device;
    the projection's piecewise-linear solve (`bregman_project_dense`) is
    sort+cumsum+argmax, so it traces straight into the scan body."""
    m, d = A.shape
    sel_keys = lp_split_chain(key, T)

    def body(carry, k_sel):
        logY, y, x_sum = carry
        if mode == "exact":
            j = _exact_select_dual_raw(k_sel, N, y, scale)
            n_scored = jnp.int32(d)
            tail_count = jnp.int32(0)
            overflow = jnp.bool_(False)
        else:
            idx, raw = query_fn(y, k)
            out = lazy_em_from_topk(
                k_sel, idx, raw * scale, d,
                score_fn=lambda i: (N[i] @ y) * scale,
                tail_cap=tail_cap,
                margin_slack=margin_slack * scale if margin_slack else 0.0,
            )
            j = jax.lax.cond(
                out.overflow,
                lambda _: _exact_select_dual_raw(fallback_key(k_sel), N, y,
                                                 scale),
                lambda _: out.index.astype(jnp.int32),
                operand=None,
            )
            n_scored = jnp.where(out.overflow, jnp.int32(d), out.n_scored)
            tail_count = out.tail_count
            overflow = out.overflow
        x_vertex = _vertex_raw(j, c, opt, d)
        logY, y = _dual_step(logY, x_vertex, A, b, eta, rho, s)
        return (logY, y, x_sum + x_vertex), (j, n_scored, tail_count, overflow)

    init = (jnp.zeros((m,), jnp.float32),
            jnp.full((m,), 1.0 / m, jnp.float32),
            jnp.zeros((d,), jnp.float32))
    (_, _, x_sum), traces = jax.lax.scan(body, init, sel_keys)
    return x_sum / T, traces


def _dual_statics(cfg: DualLPConfig, cal: _DualCalibration, opt: float) -> dict:
    return dict(T=cal.T, mode=cfg.mode, eta=cal.eta, rho=cal.rho,
                s=int(cfg.s), opt=float(opt), scale=cal.scale, k=cal.k,
                tail_cap=cal.tail_cap, margin_slack=cfg.margin_slack)


def solve_constraint_private_lp_fused(
    A: jax.Array,
    b: jax.Array,
    c: jax.Array,
    opt: float,
    cfg: DualLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> DualLPResult:
    """Run the dense-MWU dual solver as a single fused scan dispatch."""
    from repro.core.mwem import _compiled_driver

    A = jnp.asarray(A, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    m, d = A.shape
    cal = _dual_calibrate(A, b, c, opt, cfg)
    c_idx = _check_lp_fast_index(cfg, index, fused=True, what="N_j rows")

    res = DualLPResult(x_bar=None, violations=None, n_violated=-1,
                       ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / d))

    N = -(opt / c)[:, None] * A.T          # (d, m): N_j as rows
    entry = _lp_fused_driver(index if cfg.mode == "fast" else None,
                             _dual_core, _dual_statics(cfg, cal, opt), "dual")
    args = (A, b, c, N, key)
    driver = _compiled_driver(entry, *args)
    t0 = perf_counter()
    with obs_annotate("lp_dual/fused"):
        x_bar, traces = driver(*args)
        jax.block_until_ready(x_bar)
    total = perf_counter() - t0

    sel_t, n_scored_t, _tail_t, over_t = jax.device_get(traces)
    res.selected = [int(s) for s in sel_t]
    res.n_scored = [int(s) for s in n_scored_t]
    res.overflow_count = int(np.sum(over_t))
    res.iter_seconds = [total / cal.T] * cal.T
    # the dual oracle scores the d vertices {N_j}, so d is this
    # mechanism's candidate-set size ("m" in telemetry terms)
    res.telemetry = record_run(
        workload="lp_dual", driver="fused", mode=cfg.mode, m=d,
        n_scored=n_scored_t, overflow_count=res.overflow_count,
        total_seconds=total, amortized=True)
    for _ in range(cal.T):
        _record_lp_iteration(res.ledger, cfg.mode, cal.eps_prime,
                             "dual_oracle", c_idx, cfg.margin_slack)
    res.x_bar = x_bar
    res.violations = A @ x_bar - b
    res.n_violated = int(jnp.sum(res.violations > cfg.alpha))
    return res


# ---------------------------------------------------------------------------
# Host-loop driver (reference / non-traceable indices)
# ---------------------------------------------------------------------------

def _solve_constraint_private_lp_host(
    A: jax.Array,
    b: jax.Array,
    c: jax.Array,
    opt: float,
    cfg: DualLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> DualLPResult:
    """One jit dispatch per step; `bool(out.overflow)` syncs to the host."""
    m, d = A.shape
    N = -(opt / c)[:, None] * A.T          # (d, m): N_j as rows
    cal = _dual_calibrate(A, b, c, opt, cfg)
    c_idx = _check_lp_fast_index(cfg, index, fused=False, what="N_j rows")

    res = DualLPResult(x_bar=None, violations=None, n_violated=-1,
                       ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / d))

        @jax.jit
        def fast_select(key, topk_idx, topk_scores, y):
            return lazy_em_from_topk(
                key, topk_idx, topk_scores * cal.scale, d,
                score_fn=lambda idx: (N[idx] @ y) * cal.scale,
                tail_cap=cal.tail_cap,
                margin_slack=(cfg.margin_slack * cal.scale
                              if cfg.margin_slack else 0.0),
            )

    logY = jnp.zeros((m,), jnp.float32)
    y = jnp.full((m,), 1.0 / m, jnp.float32)
    x_sum = jnp.zeros((d,), jnp.float32)

    for _ in range(cal.T):
        key, k_sel = jax.random.split(key)
        t0 = perf_counter()
        if cfg.mode == "exact":
            j = int(_exact_select_dual(k_sel, N, y, cal.scale))
            res.n_scored.append(d)
        else:
            idx, raw = index.query(y, cal.k)
            out = fast_select(k_sel, idx, raw, y)
            if bool(out.overflow):
                # fresh-stream redo, bitwise-matching the fused lax.cond
                j = int(_exact_select_dual(fallback_key(k_sel), N, y,
                                           cal.scale))
                res.overflow_count += 1
                res.n_scored.append(d)
            else:
                j = int(out.index)
                res.n_scored.append(int(out.n_scored))
        _record_lp_iteration(res.ledger, cfg.mode, cal.eps_prime,
                             "dual_oracle", c_idx, cfg.margin_slack)
        x_vertex = _vertex(jnp.int32(j), c, float(opt), d)
        x_sum = x_sum + x_vertex
        logY, y = _dual_update(logY, x_vertex, A, b, cal.eta, cal.rho,
                               int(cfg.s))
        jax.block_until_ready(y)
        res.iter_seconds.append(perf_counter() - t0)
        res.selected.append(j)

    x_bar = x_sum / cal.T
    res.x_bar = x_bar
    res.violations = A @ x_bar - b
    res.n_violated = int(jnp.sum(res.violations > cfg.alpha))
    res.telemetry = record_run(
        workload="lp_dual", driver="host", mode=cfg.mode, m=d,
        n_scored=res.n_scored, overflow_count=res.overflow_count,
        total_seconds=sum(res.iter_seconds), amortized=False)
    return res


def solve_constraint_private_lp(
    A: jax.Array,
    b: jax.Array,
    c: jax.Array,
    opt: float,
    cfg: DualLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> DualLPResult:
    """Dense-MWU dual solver. ``index`` must be built on rows of N (d, m)
    (`mips.lp_dual_rows`); routes between the fused scan and the host loop
    via ``cfg.driver``."""
    if _resolve_lp_driver(cfg, index) == "fused":
        return solve_constraint_private_lp_fused(A, b, c, opt, cfg, key,
                                                 index=index, ledger=ledger)
    return _solve_constraint_private_lp_host(A, b, c, opt, cfg, key,
                                             index=index, ledger=ledger)
