"""The exponential mechanism (paper Def. 2.2 / Thm 2.3).

The EM over candidates with utility scores ``u_i`` and sensitivity ``Δ``
samples ``i ∝ exp(ε·u_i / (2Δ))``. We implement it through the Gumbel-Max
trick (Lemma C.2), which is the numerically-stable classic and the form the
lazy mechanism accelerates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def em_scores(utilities: jax.Array, eps: float, sensitivity: float) -> jax.Array:
    """Scale raw utilities into EM log-space scores ``ε·u/(2Δ)``."""
    return utilities * (eps / (2.0 * sensitivity))


def exact_em(key: jax.Array, utilities: jax.Array, eps: float, sensitivity: float) -> jax.Array:
    """ε-DP exponential mechanism: returns an index ``i ∝ exp(ε·u_i/(2Δ))``.

    Θ(|R|) time — the baseline the paper's LazyEM beats.
    """
    x = em_scores(utilities, eps, sensitivity)
    g = jax.random.gumbel(key, x.shape, x.dtype)
    return jnp.argmax(x + g)


def em_utility_bound(n_candidates: int, eps: float, sensitivity: float, t: float) -> float:
    """Thm 2.3: P[s(î) < s_max − 2Δ(ln|R| + t)/ε] ≤ e^{−t}."""
    import math

    return 2.0 * sensitivity * (math.log(n_candidates) + t) / eps
