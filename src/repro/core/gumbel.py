"""Gumbel distribution machinery (paper §C, Lemma C.2/C.3).

Numerically-stable helpers used by the exact and lazy exponential mechanisms.
All functions are jit-compatible and operate in float32 without catastrophic
cancellation:

* ``tail_prob(B)`` computes ``P[G > B] = 1 - exp(-exp(-B))`` as
  ``-expm1(-exp(-B))`` — exact even for large ``B`` where the naive form
  rounds to 0.
* ``truncated_gumbel`` samples ``G | G > B`` through the log-space
  transform ``W = -log1p(-q*(1-u)); G = -log(W)`` with ``q = tail_prob(B)``,
  avoiding the unstable ``-log(-log(U))`` with ``U`` microscopically below 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gumbel(key: jax.Array, shape=(), dtype=jnp.float32) -> jax.Array:
    """Standard Gumbel(0, 1) samples."""
    return jax.random.gumbel(key, shape, dtype)


def tail_prob(B: jax.Array) -> jax.Array:
    """P[Gumbel(0,1) > B] = 1 - exp(-exp(-B)), computed stably."""
    return -jnp.expm1(-jnp.exp(-B))


def truncated_gumbel(key: jax.Array, shape, B: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Sample ``G ~ Gumbel(0,1)`` conditioned on ``G > B`` (Lemma C.3).

    Equivalent to ``-log(-log(U))`` with ``U ~ Uniform(exp(-exp(-B)), 1)``
    but stable for large ``B``: with ``q = P[G > B]`` and ``u ~ U[0,1)``,

        W = -log(U) = -log1p(-q * (1 - u)),   G = -log(W).
    """
    u = jax.random.uniform(key, shape, dtype)
    q = tail_prob(jnp.asarray(B, dtype))
    w = -jnp.log1p(-q * (1.0 - u))
    return -jnp.log(w)


def gumbel_max(key: jax.Array, scores: jax.Array) -> jax.Array:
    """Gumbel-Max trick (Lemma C.2): argmax(scores + G) ~ softmax(scores)."""
    g = gumbel(key, scores.shape, scores.dtype)
    return jnp.argmax(scores + g)
