"""Fast scalar-private LP solver (paper §4.1, Algorithm 3).

Feasibility LPs ``Ax ≤ b`` over the simplex ``x ∈ Δ([d])`` in the
scalar-private, low-sensitivity setting: neighboring databases only move
``b`` by ``‖b−b'‖_∞ ≤ Δ_∞`` (A and c public). Each iteration selects the
most-violated constraint privately; the EM score is the inner product

    Q_t(i) = A_i·x − b_i = ⟨A_i ∘ b_i, x ∘ −1⟩

so LazyEM over a k-MIPS index on the concatenated rows ``{A_i ∘ b_i}``
gives O(d√m) expected per-iteration time (Thm 4.1) vs Θ(dm) exhaustive.

Two drivers execute the same iteration (DESIGN.md §6), mirroring the MWEM
engine's architecture exactly:

* **fused** (`solve_scalar_lp_fused`): the whole T-iteration loop is one
  jitted `lax.scan` — the in-graph index probe (`query_in_graph`), LazyEM,
  the `lax.cond` overflow fallback to the exhaustive Gumbel-max, and the
  multiplicative-weights update all stay on device. The per-iteration key
  chain is pre-split through `lp_split_chain`, which walks the host loop's
  exact ``key → (key, k_sel)`` chain, so the two drivers make bitwise the
  same selections (up to XLA float reassociation on exact ties).
* **host** (`driver="host"`): the original Python loop, one dispatch per
  step — the reference for the conformance tier (tests/test_lp_fused.py)
  and the only driver for non-traceable indices (NSW).

`solve_scalar_lp` routes between them (`ScalarLPConfig.driver`);
`solve_lp_batch` vmaps the fused scan over seed lanes (and per-lane ``b``
instances in exact mode) — the dispatch the serving tier's LP waves ride.

Overflow fallback keys: the lazy draw consumes splits of ``k_sel``, so the
exhaustive redo draws from `lazy_em.fallback_key(k_sel)` — a fresh stream,
decorrelated from the failed lazy draw (both drivers, bitwise-aligned).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accountant import PrivacyLedger, calibrate_eps0
from repro.core.gumbel import gumbel
from repro.core.lazy_em import (default_tail_cap, fallback_key,
                                lazy_em_from_topk)
from repro.obs.clock import perf_counter
from repro.obs.telemetry import MechanismTelemetry, record_run
from repro.obs.trace import annotate as obs_annotate


@dataclass(frozen=True)
class ScalarLPConfig:
    eps: float = 1.0
    delta: float = 1e-3
    alpha: float = 0.5
    delta_inf: float = 0.1        # Δ∞ sensitivity of b
    T: Optional[int] = None       # default 9ρ² log d / α²
    mode: str = "fast"            # "exact" | "fast"
    driver: str = "auto"          # "auto" | "fused" | "host"
    k: Optional[int] = None
    tail_cap: Optional[int] = None
    margin_slack: float = 0.0
    eta: Optional[float] = None


@dataclass
class ScalarLPResult:
    x_bar: jax.Array
    violations: jax.Array          # A x̄ − b
    violated_frac: float           # fraction with A x̄ > b + α
    selected: list = field(default_factory=list)
    n_scored: list = field(default_factory=list)
    overflow_count: int = 0
    iter_seconds: list = field(default_factory=list)
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)
    telemetry: Optional[MechanismTelemetry] = None  # repro.obs aggregation


@dataclass
class ScalarLPBatchResult:
    """Stacked outputs of `solve_lp_batch` (leading axis = batch lanes)."""

    x_bar: jax.Array              # (B, d)
    violated_fracs: np.ndarray    # (B,)
    selected: np.ndarray          # (B, T)
    n_scored: np.ndarray          # (B, T)
    overflow_counts: np.ndarray   # (B,)
    total_seconds: float = 0.0
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)  # per run
    ledgers: Optional[list] = None  # per-lane ledgers when the caller passed them
    telemetry: Optional[MechanismTelemetry] = None  # whole-batch aggregation


class _LPCalibration(NamedTuple):
    T: int
    eta: float
    rho: float
    eps0: float
    scale: float      # EM log-space factor ε₀/(2Δ∞)
    k: int
    tail_cap: int


def _scalar_calibrate(A: jax.Array, cfg: ScalarLPConfig) -> _LPCalibration:
    """Per-iteration budget, EM scale and buffer sizes — one point of truth
    shared by both drivers and by `lp_release_cost`, so the cost bundle an
    admission controller previews is exactly what execution records."""
    m, d = A.shape
    rho = float(jnp.max(jnp.abs(A)))
    T = cfg.T or max(1, math.ceil(9.0 * rho * rho * math.log(d) / (cfg.alpha ** 2)))
    eta = cfg.eta if cfg.eta is not None else math.sqrt(math.log(d) / T)
    eps0 = calibrate_eps0(cfg.eps, cfg.delta, T, scheme="lp")
    return _LPCalibration(
        T=T,
        eta=float(eta),
        rho=rho,
        eps0=eps0,
        scale=float(eps0 / (2.0 * cfg.delta_inf)),
        k=cfg.k or max(1, math.ceil(math.sqrt(m))),
        tail_cap=cfg.tail_cap or default_tail_cap(m),
    )


def _check_lp_fast_index(cfg, index, fused: bool, what: str) -> float:
    """Validate the (mode, index, driver) combination; returns the index's
    approximation margin c ≥ 0 (0 in exact mode)."""
    if cfg.mode not in ("exact", "fast"):
        raise ValueError(f"unknown mode {cfg.mode!r}")
    if cfg.mode != "fast":
        return 0.0
    if index is None:
        raise ValueError(f"fast mode requires a k-MIPS index over {what}")
    if fused and not getattr(index, "supports_in_graph", False):
        raise ValueError(
            f"{type(index).__name__} cannot be traced into the fused scan "
            "(supports_in_graph=False); use driver='host'")
    return float(getattr(index, "approx_margin", 0.0))


def _record_lp_iteration(ledger: PrivacyLedger, mode: str, eps0: float,
                         label: str, c_idx: float, margin_slack: float) -> None:
    """Ledger entries for one LP iteration — shared by both drivers and by
    the cost-bundle builders, so fused and host runs compose to identical
    privacy totals and `lp_release_cost` previews exactly them."""
    ledger.record(eps0, 0.0, label)
    if mode == "fast" and c_idx > 0.0 and margin_slack == 0.0:
        ledger.record_approx_slack(c_idx)  # Thm F.2 runtime mode


def scalar_lp_release_cost(A, cfg: ScalarLPConfig, index=None
                           ) -> tuple[list, float, float]:
    """The exact privacy-cost bundle one `solve_scalar_lp*` run records.

    Returns ``(events, gamma, slack)`` built through the same
    `_scalar_calibrate`/`_record_lp_iteration` path the drivers use, so
    ``PrivacyLedger.preview(*scalar_lp_release_cost(...))`` equals the
    post-run ``composed()`` — the LP counterpart of `mwem.release_cost`,
    and the bundle `ReleaseService.submit_lp` admission-gates on.
    """
    A = jnp.asarray(A, jnp.float32)
    m = A.shape[0]
    cal = _scalar_calibrate(A, cfg)
    c_idx = _check_lp_fast_index(cfg, index, fused=False, what="[A_i, b_i]")
    tmp = PrivacyLedger()
    if cfg.mode == "fast":
        tmp.record_index_failure(getattr(index, "failure_mass", 1.0 / m))
    for _ in range(cal.T):
        _record_lp_iteration(tmp, cfg.mode, cal.eps0, "lp_em",
                             c_idx, cfg.margin_slack)
    return tmp.bundle()


def lp_split_chain(key: jax.Array, T: int) -> jax.Array:
    """Pre-split the per-iteration selection keys by walking the LP host
    loops' exact chain (``key → key, k_sel``) as one key-only scan.

    This is THE key chain for both LP solvers: the host loops consume it
    step by step, the fused drivers pre-split it through this helper — one
    point of truth, so cross-driver bitwise selection parity cannot drift
    (the LP analog of `mwem.split_chain`). Returns (T,)-stacked keys.
    """

    def body(carry_key, _):
        carry_key, k_sel = jax.random.split(carry_key)
        return carry_key, k_sel

    _, sel_keys = jax.lax.scan(body, key, None, length=T)
    return sel_keys


def _scalar_scores(A, b, x, scale):
    return (A @ x - b) * scale


def _exact_select_lp_raw(key, A, b, x, scale):
    """Exhaustive EM oracle over the m constraints (Alg. 3 selection)."""
    scores = _scalar_scores(A, b, x, scale)
    g = gumbel(key, scores.shape)
    return jnp.argmax(scores + g).astype(jnp.int32)


_exact_select_lp = jax.jit(_exact_select_lp_raw, static_argnames=("scale",))


def _lp_step(logX, A_row, eta: float, rho: float):
    """One MWU step of the primal player x ∈ Δ([d])."""
    logX = logX - (eta / rho) * A_row
    logX = logX - jnp.max(logX)
    return logX, jax.nn.softmax(logX)


_lp_update = jax.jit(_lp_step, static_argnames=("eta", "rho"))


# ---------------------------------------------------------------------------
# Fused on-device driver (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _scalar_core(A: jax.Array, b: jax.Array, key: jax.Array, *, query_fn,
                 T: int, mode: str, eta: float, rho: float, scale: float,
                 k: int, tail_cap: int, margin_slack: float):
    """The whole Alg. 3 loop as one `lax.scan` — zero host round-trips.

    The probe vector ``[x, −1]`` and the concatenated score rows
    ``Ab = [A | b]`` are built in-graph, so the scan body scores tail
    candidates with one (t, d+1) gather-matvec (the §4.1 identity
    ``Q_t(i) = ⟨[A_i, b_i], [x, −1]⟩``) and never re-touches A and b
    separately. Under `solve_lp_batch`'s vmap, per-lane ``b`` instances
    therefore get their own in-graph Ab for free.
    """
    m, d = A.shape
    Ab = jnp.concatenate([A, b[:, None]], axis=1)
    sel_keys = lp_split_chain(key, T)

    def body(carry, k_sel):
        logX, x, x_sum = carry
        if mode == "exact":
            sel = _exact_select_lp_raw(k_sel, A, b, x, scale)
            n_scored = jnp.int32(m)
            tail_count = jnp.int32(0)
            overflow = jnp.bool_(False)
        else:
            xq = jnp.concatenate([x, -jnp.ones((1,), x.dtype)])
            idx, raw = query_fn(xq, k)
            out = lazy_em_from_topk(
                k_sel, idx, raw * scale, m,
                score_fn=lambda i: (Ab[i] @ xq) * scale,
                tail_cap=tail_cap,
                margin_slack=margin_slack * scale if margin_slack else 0.0,
            )
            # In-graph fallback: on tail-buffer overflow redo the step with
            # the exhaustive Gumbel-max from a *fresh* key stream
            # (`fallback_key`) — the lazy draw already consumed splits of
            # k_sel, and the host driver folds in the same tag.
            sel = jax.lax.cond(
                out.overflow,
                lambda _: _exact_select_lp_raw(fallback_key(k_sel), A, b, x,
                                               scale),
                lambda _: out.index.astype(jnp.int32),
                operand=None,
            )
            n_scored = jnp.where(out.overflow, jnp.int32(m), out.n_scored)
            tail_count = out.tail_count
            overflow = out.overflow
        logX, x = _lp_step(logX, A[sel], eta, rho)
        return (logX, x, x_sum + x), (sel, n_scored, tail_count, overflow)

    init = (jnp.zeros((d,), jnp.float32),
            jnp.full((d,), 1.0 / d, jnp.float32),
            jnp.zeros((d,), jnp.float32))
    (_, _, x_sum), traces = jax.lax.scan(body, init, sel_keys)
    return x_sum / T, traces


_LP_EXACT_DRIVER_CACHE: dict = {}


def _lp_fused_driver(index, core, statics: dict, tag: str,
                     batch_axes=None):
    """Build (or fetch) the jitted fused LP driver for an (index, config)
    pair — the LP counterpart of `mwem._fused_driver`. Compiled drivers are
    cached on the index instance (module-level for ``mode="exact"``);
    ``batch_axes`` is a vmap ``in_axes`` tuple for the batched driver."""
    cache = (_LP_EXACT_DRIVER_CACHE if index is None
             else index.__dict__.setdefault("_lp_fused_driver_cache", {}))
    ck = (tag, tuple(sorted(statics.items())), batch_axes,
          getattr(index, "_use_pallas", None))
    entry = cache.get(ck)
    if entry is None:
        query_fn = index.query_in_graph if index is not None else None
        fn = partial(core, query_fn=query_fn, **statics)
        if batch_axes is not None:
            fn = jax.vmap(fn, in_axes=batch_axes)
        entry = (jax.jit(fn), {})
        cache[ck] = entry
    return entry


def _scalar_statics(cfg: ScalarLPConfig, cal: _LPCalibration) -> dict:
    return dict(T=cal.T, mode=cfg.mode, eta=cal.eta, rho=cal.rho,
                scale=cal.scale, k=cal.k, tail_cap=cal.tail_cap,
                margin_slack=cfg.margin_slack)


def solve_scalar_lp_fused(
    A: jax.Array,
    b: jax.Array,
    cfg: ScalarLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> ScalarLPResult:
    """Run Alg. 3 as a single fused scan dispatch.

    Exactly one device→host transfer moves the stacked per-iteration traces
    (`selected`, `n_scored`, tail counts, overflow flags) back.
    ``iter_seconds`` holds the amortized *execution* wall-clock per
    iteration (total / T): trace+compile happen outside the timed region
    via a cached AOT executable.
    """
    from repro.core.mwem import _compiled_driver

    A = jnp.asarray(A, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    m, _ = A.shape
    cal = _scalar_calibrate(A, cfg)
    c_idx = _check_lp_fast_index(cfg, index, fused=True, what="[A_i, b_i]")

    res = ScalarLPResult(x_bar=None, violations=None, violated_frac=float("nan"),
                         ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))

    entry = _lp_fused_driver(index if cfg.mode == "fast" else None,
                             _scalar_core, _scalar_statics(cfg, cal), "scalar")
    args = (A, b, key)
    driver = _compiled_driver(entry, *args)
    t0 = perf_counter()
    with obs_annotate("lp_scalar/fused"):
        x_bar, traces = driver(*args)
        jax.block_until_ready(x_bar)
    total = perf_counter() - t0

    sel_t, n_scored_t, _tail_t, over_t = jax.device_get(traces)
    res.selected = [int(s) for s in sel_t]
    res.n_scored = [int(s) for s in n_scored_t]
    res.overflow_count = int(np.sum(over_t))
    res.iter_seconds = [total / cal.T] * cal.T
    res.telemetry = record_run(
        workload="lp_scalar", driver="fused", mode=cfg.mode, m=m,
        n_scored=n_scored_t, overflow_count=res.overflow_count,
        total_seconds=total, amortized=True)
    for _ in range(cal.T):
        _record_lp_iteration(res.ledger, cfg.mode, cal.eps0, "lp_em",
                             c_idx, cfg.margin_slack)
    res.x_bar = x_bar
    res.violations = A @ x_bar - b
    res.violated_frac = float(jnp.mean(res.violations > cfg.alpha))
    return res


@dataclass
class LPPendingBatch:
    """Handle for an in-flight `launch_lp_batch` dispatch — the LP
    counterpart of `mwem.MWEMPendingBatch`. Device buffers are futures
    until `finish_lp_batch` blocks on them."""

    x_bar: jax.Array
    traces: tuple
    t0: float
    A: jax.Array
    b: jax.Array
    batched_b: bool
    cfg: ScalarLPConfig
    cal: _LPCalibration
    c_idx: float
    index: object
    lanes: int


def launch_lp_batch(
    A: jax.Array,
    b: jax.Array,
    cfg: ScalarLPConfig,
    keys: jax.Array,
    index=None,
) -> LPPendingBatch:
    """Dispatch one batched LP wave asynchronously — the launch half of
    `solve_lp_batch`. ``solve_lp_batch(...)`` is exactly
    ``finish_lp_batch(launch_lp_batch(...))``."""
    from repro.core.mwem import _compiled_driver

    if cfg.driver == "host":
        raise ValueError("solve_lp_batch always uses the fused driver; "
                         "loop solve_scalar_lp(..., driver='host') for host runs")
    A = jnp.asarray(A, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    keys = jnp.asarray(keys)
    B = keys.shape[0]
    batched_b = b.ndim == 2
    if batched_b and cfg.mode == "fast":
        raise ValueError(
            "per-lane b instances require mode='exact': the k-MIPS index "
            "rows [A_i, b_i] embed a single b")
    cal = _scalar_calibrate(A, cfg)
    c_idx = _check_lp_fast_index(cfg, index, fused=True, what="[A_i, b_i]")

    entry = _lp_fused_driver(index if cfg.mode == "fast" else None,
                             _scalar_core, _scalar_statics(cfg, cal), "scalar",
                             batch_axes=(None, 0 if batched_b else None, 0))
    args = (A, b, keys)
    driver = _compiled_driver(entry, *args)
    t0 = perf_counter()
    with obs_annotate("lp_scalar/batch"):
        x_bar, traces = driver(*args)
    return LPPendingBatch(x_bar=x_bar, traces=traces, t0=t0, A=A, b=b,
                          batched_b=batched_b, cfg=cfg, cal=cal, c_idx=c_idx,
                          index=index, lanes=B)


def finish_lp_batch(pending: LPPendingBatch,
                    ledgers: Optional[list] = None) -> ScalarLPBatchResult:
    """Block on a launched LP wave and assemble its `ScalarLPBatchResult` —
    the finish half of `solve_lp_batch`."""
    A, b, cfg, cal = pending.A, pending.b, pending.cfg, pending.cal
    index, B, batched_b = pending.index, pending.lanes, pending.batched_b
    m, _ = A.shape
    if ledgers is not None and len(ledgers) != B:
        raise ValueError(f"ledgers must have one entry per lane "
                         f"({len(ledgers)} != {B})")
    with obs_annotate("lp_scalar/batch/finish"):
        x_bar, traces = pending.x_bar, pending.traces
        jax.block_until_ready(x_bar)
    total = perf_counter() - pending.t0

    viol = x_bar @ A.T - (b if batched_b else b[None, :])   # (B, m)
    violated_fracs = np.asarray(jnp.mean(viol > cfg.alpha, axis=1))

    ledger = PrivacyLedger()
    if cfg.mode == "fast":
        ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))
    for _ in range(cal.T):
        _record_lp_iteration(ledger, cfg.mode, cal.eps0, "lp_em",
                             pending.c_idx, cfg.margin_slack)
    if ledgers is not None:
        for lane in ledgers:
            if lane is not None:
                lane.record_events(ledger.events, ledger.index_failure_mass,
                                   ledger.approx_slack)

    traces = jax.device_get(traces)
    telemetry = record_run(
        workload="lp_scalar", driver="fused", mode=cfg.mode, m=m,
        n_scored=np.asarray(traces[1]),
        overflow_count=int(np.asarray(traces[3]).sum()),
        total_seconds=total, amortized=True, lanes=B)
    return ScalarLPBatchResult(
        x_bar=x_bar,
        violated_fracs=violated_fracs,
        selected=np.asarray(traces[0]),
        n_scored=np.asarray(traces[1]),
        overflow_counts=np.asarray(traces[3]).sum(axis=1),
        total_seconds=total,
        ledger=ledger,
        ledgers=list(ledgers) if ledgers is not None else None,
        telemetry=telemetry,
    )


def aot_compile_lp_batch(A, b, cfg: ScalarLPConfig, lanes: int,
                         index=None) -> bool:
    """Populate the batched LP driver's AOT executable cache for a
    ``lanes``-wide wave without dispatching — the LP counterpart of
    `mwem.aot_compile_batch`. Returns True when a new executable was
    compiled for this lane count."""
    from repro.core.mwem import _compiled_driver

    if cfg.driver == "host":
        raise ValueError("solve_lp_batch always uses the fused driver; "
                         "loop solve_scalar_lp(..., driver='host') for host runs")
    A = jnp.asarray(A, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    cal = _scalar_calibrate(A, cfg)
    _check_lp_fast_index(cfg, index, fused=True, what="[A_i, b_i]")
    entry = _lp_fused_driver(index if cfg.mode == "fast" else None,
                             _scalar_core, _scalar_statics(cfg, cal), "scalar",
                             batch_axes=(None, None, 0))
    keys = jnp.stack([jax.random.PRNGKey(0)] * lanes)
    n_before = len(entry[1])
    _compiled_driver(entry, A, b, keys)
    return len(entry[1]) > n_before


def solve_lp_batch(
    A: jax.Array,
    b: jax.Array,
    cfg: ScalarLPConfig,
    keys: jax.Array,
    index=None,
    ledgers: Optional[list] = None,
) -> ScalarLPBatchResult:
    """Vmapped fused scan over a batch of lanes — the LP serving dispatch.

    Args:
      b: shared ``(m,)`` constraint bounds, or ``(B, m)`` per-lane
        instances (exact mode only: the fast probe's k-MIPS rows
        ``[A_i, b_i]`` embed one ``b``, so per-lane instances would probe a
        stale index).
      keys: (B,)-stacked PRNG keys; each lane reproduces exactly what
        `solve_scalar_lp_fused` produces for that key.
      ledgers: optional list of B `PrivacyLedger`s, one per lane — each
        receives that lane's full event bundle (`scalar_lp_release_cost`),
        the same per-tenant charging contract as `run_mwem_batch`.
        ``None`` entries skip a lane (padding slots).

    The result ledger is *per run*; serving B lanes spends B× the budget,
    accounted by the per-lane ``ledgers`` (DESIGN.md §2 contract). Batching
    is fused-only (``driver="host"`` raises). Note the overflow-fallback
    `lax.cond` lowers to a select under vmap, so every batched iteration
    pays the exhaustive branch — same caveat as `run_mwem_batch`.
    """
    if cfg.driver == "host":
        raise ValueError("solve_lp_batch always uses the fused driver; "
                         "loop solve_scalar_lp(..., driver='host') for host runs")
    B = jnp.asarray(keys).shape[0]
    if ledgers is not None and len(ledgers) != B:
        raise ValueError(f"ledgers must have one entry per lane "
                         f"({len(ledgers)} != {B})")
    return finish_lp_batch(launch_lp_batch(A, b, cfg, keys, index=index),
                           ledgers=ledgers)


# ---------------------------------------------------------------------------
# Host-loop driver (reference / non-traceable indices)
# ---------------------------------------------------------------------------

def _solve_scalar_lp_host(
    A: jax.Array,
    b: jax.Array,
    cfg: ScalarLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> ScalarLPResult:
    """One jit dispatch per step; `bool(out.overflow)` syncs to the host."""
    m, d = A.shape
    cal = _scalar_calibrate(A, cfg)
    c_idx = _check_lp_fast_index(cfg, index, fused=False, what="[A_i, b_i]")

    res = ScalarLPResult(x_bar=None, violations=None, violated_frac=float("nan"),
                         ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))

        Ab = jnp.concatenate([A, b[:, None]], axis=1)  # for tail score gathers

        @jax.jit
        def fast_select(key, topk_idx, topk_scores, xq):
            return lazy_em_from_topk(
                key, topk_idx, topk_scores * cal.scale, m,
                score_fn=lambda idx: (Ab[idx] @ xq) * cal.scale,
                tail_cap=cal.tail_cap,
                margin_slack=(cfg.margin_slack * cal.scale
                              if cfg.margin_slack else 0.0),
            )

    logX = jnp.zeros((d,), jnp.float32)
    x = jnp.full((d,), 1.0 / d, jnp.float32)
    x_sum = jnp.zeros((d,), jnp.float32)

    for _ in range(cal.T):
        key, k_sel = jax.random.split(key)
        t0 = perf_counter()
        if cfg.mode == "exact":
            sel = int(_exact_select_lp(k_sel, A, b, x, cal.scale))
            res.n_scored.append(m)
        else:
            xq = jnp.concatenate([x, -jnp.ones((1,), x.dtype)])
            idx, raw = index.query(xq, cal.k)
            out = fast_select(k_sel, idx, raw, xq)
            if bool(out.overflow):
                # fresh-stream redo, bitwise-matching the fused lax.cond
                sel = int(_exact_select_lp(fallback_key(k_sel), A, b, x,
                                           cal.scale))
                res.overflow_count += 1
                res.n_scored.append(m)
            else:
                sel = int(out.index)
                res.n_scored.append(int(out.n_scored))
        _record_lp_iteration(res.ledger, cfg.mode, cal.eps0, "lp_em",
                             c_idx, cfg.margin_slack)
        logX, x = _lp_update(logX, A[sel], cal.eta, cal.rho)
        x_sum = x_sum + x
        jax.block_until_ready(x)
        res.iter_seconds.append(perf_counter() - t0)
        res.selected.append(sel)

    x_bar = x_sum / cal.T
    res.x_bar = x_bar
    res.violations = A @ x_bar - b
    res.violated_frac = float(jnp.mean(res.violations > cfg.alpha))
    res.telemetry = record_run(
        workload="lp_scalar", driver="host", mode=cfg.mode, m=m,
        n_scored=res.n_scored, overflow_count=res.overflow_count,
        total_seconds=sum(res.iter_seconds), amortized=False)
    return res


def _resolve_lp_driver(cfg, index) -> str:
    """Shared auto-routing for both LP solvers, mirroring `run_mwem`:
    fuse whenever the selection is traceable, fall back to the host loop
    for host-only indices (NSW)."""
    if cfg.driver not in ("auto", "fused", "host"):
        raise ValueError(f"unknown driver {cfg.driver!r}")
    if cfg.driver != "auto":
        return cfg.driver
    if cfg.mode == "exact":
        return "fused"
    if index is not None and getattr(index, "supports_in_graph", False):
        return "fused"
    return "host"


def solve_scalar_lp(
    A: jax.Array,
    b: jax.Array,
    cfg: ScalarLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> ScalarLPResult:
    """Algorithm 3. ``index`` must be built on rows ``[A_i, b_i] ∈ R^{d+1}``
    (`mips.lp_scalar_rows`); routes between the fused scan and the host
    loop via ``cfg.driver``."""
    if _resolve_lp_driver(cfg, index) == "fused":
        return solve_scalar_lp_fused(A, b, cfg, key, index=index, ledger=ledger)
    return _solve_scalar_lp_host(A, b, cfg, key, index=index, ledger=ledger)
