"""Fast scalar-private LP solver (paper §4.1, Algorithm 3).

Feasibility LPs ``Ax ≤ b`` over the simplex ``x ∈ Δ([d])`` in the
scalar-private, low-sensitivity setting: neighboring databases only move
``b`` by ``‖b−b'‖_∞ ≤ Δ_∞`` (A and c public). Each iteration selects the
most-violated constraint privately; the EM score is the inner product

    Q_t(i) = A_i·x − b_i = ⟨A_i ∘ b_i, x ∘ −1⟩

so LazyEM over a k-MIPS index on the concatenated rows ``{A_i ∘ b_i}``
gives O(d√m) expected per-iteration time (Thm 4.1) vs Θ(dm) exhaustive.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.accountant import PrivacyLedger, calibrate_eps0
from repro.core.gumbel import gumbel
from repro.core.lazy_em import default_tail_cap, lazy_em_from_topk


@dataclass(frozen=True)
class ScalarLPConfig:
    eps: float = 1.0
    delta: float = 1e-3
    alpha: float = 0.5
    delta_inf: float = 0.1        # Δ∞ sensitivity of b
    T: Optional[int] = None       # default 9ρ² log d / α²
    mode: str = "fast"            # "exact" | "fast"
    k: Optional[int] = None
    tail_cap: Optional[int] = None
    margin_slack: float = 0.0
    eta: Optional[float] = None


@dataclass
class ScalarLPResult:
    x_bar: jax.Array
    violations: jax.Array          # A x̄ − b
    violated_frac: float           # fraction with A x̄ > b + α
    selected: list = field(default_factory=list)
    n_scored: list = field(default_factory=list)
    overflow_count: int = 0
    iter_seconds: list = field(default_factory=list)
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)


@partial(jax.jit, static_argnames=("scale",))
def _exact_select_lp(key, A, b, x, scale: float):
    scores = (A @ x - b) * scale
    g = gumbel(key, scores.shape)
    return jnp.argmax(scores + g)


@partial(jax.jit, static_argnames=("eta", "rho"))
def _lp_update(logX, A_row, eta: float, rho: float):
    logX = logX - (eta / rho) * A_row
    logX = logX - jnp.max(logX)
    return logX, jax.nn.softmax(logX)


def solve_scalar_lp(
    A: jax.Array,
    b: jax.Array,
    cfg: ScalarLPConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> ScalarLPResult:
    """Algorithm 3. ``index`` must be built on rows ``[A_i, b_i] ∈ R^{d+1}``."""
    m, d = A.shape
    rho = float(jnp.max(jnp.abs(A)))
    T = cfg.T or max(1, math.ceil(9.0 * rho * rho * math.log(d) / (cfg.alpha ** 2)))
    eta = cfg.eta if cfg.eta is not None else math.sqrt(math.log(d) / T)
    eps0 = calibrate_eps0(cfg.eps, cfg.delta, T, scheme="lp")
    scale = float(eps0 / (2.0 * cfg.delta_inf))
    k = cfg.k or max(1, math.ceil(math.sqrt(m)))
    tail_cap = cfg.tail_cap or default_tail_cap(m)

    res = ScalarLPResult(x_bar=None, violations=None, violated_frac=float("nan"),
                         ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        if index is None:
            raise ValueError("fast mode requires a k-MIPS index over [A_i, b_i]")
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))
        c_idx = float(getattr(index, "approx_margin", 0.0))

        Ab = jnp.concatenate([A, b[:, None]], axis=1)  # for tail score gathers

        @jax.jit
        def fast_select(key, topk_idx, topk_scores, xq):
            return lazy_em_from_topk(
                key, topk_idx, topk_scores * scale, m,
                score_fn=lambda idx: (Ab[idx] @ xq) * scale,
                tail_cap=tail_cap,
                margin_slack=cfg.margin_slack * scale if cfg.margin_slack else 0.0,
            )

    logX = jnp.zeros((d,), jnp.float32)
    x = jnp.full((d,), 1.0 / d, jnp.float32)
    x_sum = jnp.zeros((d,), jnp.float32)

    for _ in range(T):
        key, k_sel = jax.random.split(key)
        t0 = time.perf_counter()
        if cfg.mode == "exact":
            sel = int(_exact_select_lp(k_sel, A, b, x, scale))
            res.n_scored.append(m)
        else:
            xq = jnp.concatenate([x, -jnp.ones((1,), x.dtype)])
            idx, raw = index.query(xq, k)
            out = fast_select(k_sel, idx, raw, xq)
            if bool(out.overflow):
                sel = int(_exact_select_lp(k_sel, A, b, x, scale))
                res.overflow_count += 1
                res.n_scored.append(m)
            else:
                sel = int(out.index)
                res.n_scored.append(int(out.n_scored))
        res.ledger.record(eps0, 0.0, "lp_em")
        if cfg.mode == "fast" and c_idx > 0.0 and cfg.margin_slack == 0.0:
            res.ledger.record_approx_slack(c_idx)
        logX, x = _lp_update(logX, A[sel], float(eta), rho)
        x_sum = x_sum + x
        jax.block_until_ready(x)
        res.iter_seconds.append(time.perf_counter() - t0)
        res.selected.append(sel)

    x_bar = x_sum / T
    res.x_bar = x_bar
    res.violations = A @ x_bar - b
    res.violated_frac = float(jnp.mean(res.violations > cfg.alpha))
    return res
