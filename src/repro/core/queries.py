"""Linear-query workloads and LP instance generators (paper §5).

Everything is generated from explicit PRNG keys so data pipelines are
deterministic and shardable (any host can regenerate any piece).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_histogram(key: jax.Array, n: int, U: int, mean=None, std=None) -> jax.Array:
    """§5.1 dataset: n points from N(U/3, U/15) binned into [0, U)."""
    mean = U / 3.0 if mean is None else mean
    std = U / 15.0 if std is None else std
    pts = mean + std * jax.random.normal(key, (n,))
    idx = jnp.clip(jnp.round(pts).astype(jnp.int32), 0, U - 1)
    h = jnp.zeros((U,), jnp.float32).at[idx].add(1.0)
    return h / n


def random_binary_queries(key: jax.Array, m: int, U: int, mean=None, std=None) -> jax.Array:
    """§5.1 queries: binary vectors marking U/4 draws from N(U/2, U/5)."""
    mean = U / 2.0 if mean is None else mean
    std = U / 5.0 if std is None else std
    n_pts = max(U // 4, 1)
    pts = mean + std * jax.random.normal(key, (m, n_pts))
    idx = jnp.clip(jnp.round(pts).astype(jnp.int32), 0, U - 1)
    q = jnp.zeros((m, U), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(m)[:, None], idx.shape)
    return q.at[rows, idx].set(1.0)


def interval_queries(key: jax.Array, m: int, U: int, min_w: int = 1) -> jax.Array:
    """Random interval (range) queries — classic workload for histograms."""
    k1, k2 = jax.random.split(key)
    lo = jax.random.randint(k1, (m,), 0, U - min_w)
    width = jax.random.randint(k2, (m,), min_w, U // 2 + 1)
    hi = jnp.minimum(lo + width, U)
    pos = jnp.arange(U)[None, :]
    return ((pos >= lo[:, None]) & (pos < hi[:, None])).astype(jnp.float32)


def ngram_marginal_queries(key: jax.Array, m: int, U: int, arity: int = 64) -> jax.Array:
    """Random subset-marginal queries over a token domain (LM DP pipeline).

    Each row marks exactly ``arity`` *distinct* bins: indices are drawn
    without replacement per row (argsort of per-row uniforms — a random
    ``arity``-subset), so every row sums to ``arity``. The old
    ``randint``-with-replacement draw silently yielded rows with fewer
    distinct bins, skewing row norms and the EM utility scale.
    """
    if arity > U:
        raise ValueError(f"arity {arity} exceeds domain size {U}")
    u = jax.random.uniform(key, (m, U))
    idx = jnp.argsort(u, axis=1)[:, :arity]     # per-row random subset
    q = jnp.zeros((m, U), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(m)[:, None], idx.shape)
    return q.at[rows, idx].set(1.0)


def max_error(Q, h: jax.Array, p: jax.Array) -> jax.Array:
    """‖Q(p − h)‖_∞ — the utility objective (Eq. 1).

    ``Q`` is a dense (m, U) matrix or any `core.workload.Workload`:
    workloads answer through their own ``max_err`` (factored ones without
    densifying), and the dense array path below is byte-for-byte the
    pre-workload expression.
    """
    if hasattr(Q, "max_err"):
        return Q.max_err(h, p)
    return jnp.max(jnp.abs(Q @ (p - h)))


def random_feasible_lp(key: jax.Array, m: int, d: int, slack: float = 0.1):
    """§5.2 LP instance: A ~ N(0, I), x* ∈ Δ([d]), b = A x* + |δ| (feasible).

    Returns (A, b, x_star) as float32 arrays.
    """
    ka, kx, kd = jax.random.split(key, 3)
    A = jax.random.normal(ka, (m, d), jnp.float32)
    x_star = jax.random.dirichlet(kx, jnp.ones((d,), jnp.float32))
    delta = slack * jnp.abs(jax.random.normal(kd, (m,), jnp.float32))
    b = A @ x_star + delta
    return A, b, x_star


def random_packing_lp(key: jax.Array, m: int, d: int):
    """Positive (packing) LP for the constraint-private dual solver (§4.2).

    max c^T x  s.t.  A x ≤ b,  x ≥ 0  with A, b, c > 0.
    """
    ka, kb, kc = jax.random.split(key, 3)
    A = jax.random.uniform(ka, (m, d), jnp.float32, 0.1, 1.0)
    c = jax.random.uniform(kc, (d,), jnp.float32, 0.5, 1.5)
    b = jax.random.uniform(kb, (m,), jnp.float32, 0.5, 1.5)
    return A, b, c


def np_seed(key: jax.Array) -> int:
    """Derive a numpy seed from a JAX key (for offline index builds)."""
    return int(np.asarray(jax.random.key_data(key)).ravel()[-1] % (2**31 - 1))
