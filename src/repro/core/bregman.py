"""Dense distributions and Bregman projections (paper §A).

``Γ_s A`` projects a measure ``A`` onto the set of 1/s-dense distributions
(Def. A.2): ``(Γ_s A)_a = (1/s)·min(1, c·A_a)`` with ``c`` solving
``Σ_a min(1, c·A_a) = s``. The solution is found exactly: sorting ``A``
descending, the constraint is piecewise linear in ``c`` with breakpoints
``1/A_(i)``; scan the pieces and solve the active one in closed form.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _solve_c(a: jax.Array, s: float) -> jax.Array:
    """Find c ≥ 0 with Σ min(1, c·a_i) = s (requires 1 ≤ s ≤ sum(a>0) count)."""
    n = a.shape[0]
    desc = -jnp.sort(-a)  # descending
    # With c in the piece where exactly the j largest entries are clipped to 1:
    #   j + c · suffix_sum(j) = s  →  c = (s − j) / suffix_sum(j)
    # valid iff c·desc[j] ≤ 1 (next entry unclipped) and c·desc[j−1] ≥ 1.
    suffix = jnp.concatenate([jnp.cumsum(desc[::-1])[::-1], jnp.zeros((1,), a.dtype)])
    j = jnp.arange(n + 1, dtype=a.dtype)
    c_cand = (s - j) / jnp.maximum(suffix, 1e-38)
    thresh_hi = jnp.concatenate([jnp.full((1,), jnp.inf, a.dtype), desc])  # desc[j-1]
    thresh_lo = jnp.concatenate([desc, jnp.zeros((1,), a.dtype)])          # desc[j]
    valid = (c_cand * thresh_lo <= 1.0 + 1e-6) & (c_cand * thresh_hi >= 1.0 - 1e-6) & (c_cand >= 0)
    # The first valid piece is the solution; fall back to the last piece.
    idx = jnp.argmax(valid)
    return jnp.where(jnp.any(valid), c_cand[idx], c_cand[-1])


@partial(jax.jit, static_argnames=("s",))
def bregman_project_dense(a: jax.Array, s: float) -> jax.Array:
    """KL (Bregman) projection of measure ``a`` to the 1/s-dense simplex.

    Returns a distribution y with ``‖y‖_∞ ≤ 1/s`` and ``Σy = 1`` minimizing
    ``KL(y ‖ a/Σa)`` (Def. A.2). For s ≤ 1 this is just normalization.

    ``s`` is a static: the s ≤ 1 short-circuit is a Python branch, and the
    fused dual-LP driver inlines this projection into its `lax.scan` body
    (every iteration projects the constraint distribution in-graph —
    DESIGN.md §6). Jitted at module level so host-loop callers share one
    compiled program per (shape, s).
    """
    a = jnp.maximum(a, 1e-38)
    if s <= 1.0:
        return a / jnp.sum(a)
    c = _solve_c(a, float(s))
    y = jnp.minimum(1.0, c * a) / s
    return y / jnp.sum(y)  # guard tiny numeric drift
