"""Adaptive marginal release: worst-approximated-marginal MWEM.

The factored-workload analogue of MWEM's query loop, at clique
granularity: each round privately selects the *worst-approximated
marginal* (EM over per-clique utilities ``u_c = max |marg_c(h − p)|``,
run through the same lazy Gumbel machinery as the per-query oracle),
Laplace-measures the selected marginal's whole table, and
multiplicative-weights-updates the synthetic histogram against every
cell of that table at once — one gather per domain element, since a
clique's cells partition the domain.

Privacy per round (sequential composition, `PrivacyLedger`):
  * selection: EM with Δu = 1/n (one record moves a marginal cell by
    1/n, so the per-clique max-abs utility moves by ≤ 1/n) at
    ``eps_em`` — the `lazy_em` log-space scale is ``eps_em/(2Δu)``.
  * measurement: one record changes two cells of a marginal by 1/n
    each ⇒ L1 sensitivity 2/n for the whole table ⇒ per-cell Laplace
    noise ``2/(n·eps_meas)`` releases the entire marginal.

Everything flows through `MarginalWorkload`'s factored primitives
(`clique_abs_err`, `cell_maps`, segment-sum tables) — no (m, U) or
per-query loop appears at any size.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.accountant import PrivacyLedger, calibrate_eps0
from repro.core.lazy_em import LazyEMResult, default_tail_cap, lazy_em
from repro.core.queries import max_error
from repro.core.workload import MarginalWorkload
from repro.obs.clock import perf_counter
from repro.obs.telemetry import record_run


@dataclass(frozen=True)
class AdaptiveConfig:
    eps: float = 1.0
    delta: float = 1e-3
    T: int = 10
    n_records: Optional[int] = None   # dataset size n → sensitivities 1/n, 2/n
    measure_frac: float = 0.5         # ε₀ fraction spent on the measurement
    eta: Optional[float] = None       # MW step size; default √(ln U / T)
    k: Optional[int] = None           # lazy-EM top-k; default ⌈√n_cliques⌉
    tail_cap: Optional[int] = None


class AdaptiveResult(NamedTuple):
    p_hat: jax.Array        # (U,) released synthetic histogram
    selected: jax.Array     # (T,) chosen clique ids
    final_error: jax.Array  # max over the workload's queries
    clique_errors: jax.Array  # (T,) pre-update worst-clique |error|
    n_scored: jax.Array     # total candidates the lazy oracle touched
    eps_spent: float
    delta_spent: float


def select_worst_marginal(key: jax.Array, W: MarginalWorkload,
                          v: jax.Array, scale: float,
                          k: Optional[int] = None,
                          tail_cap: Optional[int] = None) -> LazyEMResult:
    """Lazy Gumbel EM over cliques scored by ``max |marg_c(v)|``.

    ``scale`` is the EM log-space factor ``eps_em/(2Δu)``. The utility
    vector comes from `MarginalWorkload.clique_abs_err` — segment sums,
    never rows — and feeds the identical `lazy_em` sampler the per-query
    oracle uses, so its mechanism statistics carry over unchanged.
    """
    nc = W.n_cliques
    k = k or max(1, math.ceil(math.sqrt(nc)))
    return lazy_em(key, W.clique_abs_err(v) * scale, k=min(k, nc),
                   tail_cap=tail_cap or default_tail_cap(nc))


@partial(jax.jit, static_argnames=("eta",))
def _adaptive_update(W: MarginalWorkload, log_w: jax.Array,
                     sel: jax.Array, meas: jax.Array, eta: float):
    """MW update of every cell of clique ``sel`` in one pass.

    The clique's cells partition the domain, so the per-cell MWEM update
    ``p(u) ∝ p(u)·exp(η·(meas_cell − cur_cell))`` collapses to a single
    gather through the clique's on-the-fly cell map.
    """
    cm = W.cell_maps(sel[None])[0]                     # (U,) cell of each u
    p = jax.nn.softmax(log_w)
    cur = jax.ops.segment_sum(p, cm, num_segments=meas.shape[0])
    log_w = log_w + eta * (meas - cur)[cm]
    return log_w - jax.scipy.special.logsumexp(log_w)


@jax.jit
def _measure_marginal(W: MarginalWorkload, h: jax.Array, sel: jax.Array,
                      key: jax.Array, lap_scale: jax.Array) -> jax.Array:
    """Laplace release of clique ``sel``'s whole table (pad cells noisy
    too — they multiply nothing downstream)."""
    cm = W.cell_maps(sel[None])[0]
    tab = jax.ops.segment_sum(h, cm, num_segments=W.max_cells)
    return tab + lap_scale * jax.random.laplace(key, (W.max_cells,))


def run_adaptive_marginals(
    W: MarginalWorkload,
    h: jax.Array,
    cfg: AdaptiveConfig,
    key: jax.Array,
    ledger: Optional[PrivacyLedger] = None,
) -> AdaptiveResult:
    """Worst-approximated-marginal MWEM over a factored workload.

    A host loop (T is small — one marginal per round) with jitted,
    shape-stable bodies shared across rounds and instances.
    """
    if not isinstance(W, MarginalWorkload):
        raise TypeError(
            f"run_adaptive_marginals needs a MarginalWorkload, got "
            f"{type(W).__name__}")
    if cfg.n_records is None:
        raise ValueError("AdaptiveConfig.n_records (dataset size n) is required")
    n = cfg.n_records
    eps0 = calibrate_eps0(cfg.eps, cfg.delta, cfg.T, scheme="mwem")
    eps_em = eps0 * (1.0 - cfg.measure_frac)
    eps_meas = eps0 * cfg.measure_frac
    scale = float(eps_em * n / 2.0)                    # eps_em / (2·(1/n))
    lap_scale = float((2.0 / n) / max(eps_meas, 1e-12))
    eta = float(cfg.eta if cfg.eta is not None
                else math.sqrt(math.log(W.U) / cfg.T))
    ledger = ledger if ledger is not None else PrivacyLedger()

    t0 = perf_counter()
    h = jnp.asarray(h, jnp.float32)
    log_w = jnp.zeros((W.U,), jnp.float32) - jnp.log(W.U)
    selected, cerrs, scored = [], [], 0
    for _ in range(cfg.T):
        key, k_sel, k_meas = jax.random.split(key, 3)
        v = h - jax.nn.softmax(log_w)
        res = select_worst_marginal(k_sel, W, v, scale,
                                    k=cfg.k, tail_cap=cfg.tail_cap)
        sel = res.index
        meas = _measure_marginal(W, h, sel, k_meas, jnp.float32(lap_scale))
        log_w = _adaptive_update(W, log_w, sel, meas, eta)
        ledger.record(eps_em, 0.0, "adaptive_em")
        ledger.record(eps_meas, 0.0, "adaptive_measure")
        selected.append(sel)
        cerrs.append(W.clique_abs_err(v)[sel])
        scored += int(res.n_scored)

    p_hat = jax.nn.softmax(log_w)
    final_error = max_error(W, h, p_hat)
    eps_spent, delta_spent = ledger.composed()
    record_run(workload="core.adaptive_marginals", driver="host",
               mode="adaptive", m=W.n_cliques, n_scored=scored,
               overflow_count=0, total_seconds=perf_counter() - t0,
               amortized=False)
    return AdaptiveResult(
        p_hat=p_hat,
        selected=jnp.stack(selected),
        final_error=final_error,
        clique_errors=jnp.stack(cerrs),
        n_scored=jnp.int32(scored),
        eps_spent=float(eps_spent),
        delta_spent=float(delta_spent),
    )
