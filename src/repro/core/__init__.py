"""The paper's contribution: Fast-MWEM and its private selection machinery."""

from repro.core.gumbel import gumbel, truncated_gumbel, tail_prob
from repro.core.em import exact_em, em_scores, em_utility_bound
from repro.core.lazy_em import (
    LazyEMResult,
    default_tail_cap,
    lazy_em,
    lazy_em_from_topk,
)
from repro.core.accountant import (
    PrivacyLedger,
    advanced_composition,
    calibrate_eps0,
)
from repro.core.bregman import bregman_project_dense
from repro.core.workload import (
    DenseWorkload,
    MarginalWorkload,
    Workload,
    as_workload,
)
from repro.core.adaptive import (
    AdaptiveConfig,
    AdaptiveResult,
    run_adaptive_marginals,
    select_worst_marginal,
)
from repro.core.mwem import (
    MWEMBatchResult,
    MWEMConfig,
    MWEMResult,
    MWEMState,
    mwem_iteration_counts,
    release_cost,
    run_mwem,
    run_mwem_batch,
    run_mwem_fused,
)
from repro.core.distributed import run_mwem_sharded, run_mwem_sharded_batch
from repro.core.lp_scalar import (
    ScalarLPBatchResult,
    ScalarLPConfig,
    ScalarLPResult,
    scalar_lp_release_cost,
    solve_lp_batch,
    solve_scalar_lp,
    solve_scalar_lp_fused,
)
from repro.core.lp_dual import (
    DualLPConfig,
    DualLPResult,
    dual_lp_release_cost,
    lp_release_cost,
    solve_constraint_private_lp,
    solve_constraint_private_lp_fused,
)

__all__ = [
    "gumbel",
    "truncated_gumbel",
    "tail_prob",
    "exact_em",
    "em_scores",
    "em_utility_bound",
    "LazyEMResult",
    "default_tail_cap",
    "lazy_em",
    "lazy_em_from_topk",
    "PrivacyLedger",
    "advanced_composition",
    "calibrate_eps0",
    "bregman_project_dense",
    "DenseWorkload",
    "MarginalWorkload",
    "Workload",
    "as_workload",
    "AdaptiveConfig",
    "AdaptiveResult",
    "run_adaptive_marginals",
    "select_worst_marginal",
    "MWEMBatchResult",
    "MWEMConfig",
    "MWEMResult",
    "MWEMState",
    "release_cost",
    "run_mwem",
    "run_mwem_batch",
    "run_mwem_fused",
    "run_mwem_sharded",
    "run_mwem_sharded_batch",
    "mwem_iteration_counts",
    "ScalarLPBatchResult",
    "ScalarLPConfig",
    "ScalarLPResult",
    "scalar_lp_release_cost",
    "solve_lp_batch",
    "solve_scalar_lp",
    "solve_scalar_lp_fused",
    "DualLPConfig",
    "DualLPResult",
    "dual_lp_release_cost",
    "lp_release_cost",
    "solve_constraint_private_lp",
    "solve_constraint_private_lp_fused",
]
