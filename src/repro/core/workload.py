"""Workload abstraction: dense query matrices and factored k-way marginals.

Two implementations of one protocol (DESIGN.md §9):

- `DenseWorkload` wraps today's explicit ``(m, U)`` matrix. Every primitive
  is the exact expression the drivers inlined before the refactor, so the
  dense path stays bitwise identical.
- `MarginalWorkload` represents k-way marginals over a factored categorical
  domain ``U = Π card[i]`` as structured index maps — per query only a
  clique id and a cell offset; rows are *never* stored. The cell map of a
  clique (which marginal cell each domain point lands in) is recomputed on
  the fly from ``arange(U)`` by mixed-radix arithmetic, so the whole
  representation is ``O(m + n_cliques·kmax)`` integers.

Complement augmentation is by *sign convention*, not row doubling: for
probes with ``Σv = 0`` (histogram differences), ``⟨1−q, v⟩ = −⟨q, v⟩``, so
augmented id ``j`` means query ``j % m`` with sign ``+1 if j < m else −1``
(`aug_decompose`). No workload ever materializes ``[Q; 1−Q]``.

Bitwise-parity contract (the conformance safety rail): `scores(v)` is the
selection oracle. For ``m ≤ score_block`` it is a single ``(m, U) @ (U,)``
matmul over implicit one-hot rows — the same op shape and bitwise-equal
operands as the dense path, hence bitwise-equal scores. `answer_all(v)` is
the fast path (per-clique segment sums, ``O(n_cliques · U)`` work and
``O(chunk · U)`` memory); scatter reassociation makes it allclose, not
bitwise, which is why the two paths exist separately.

Instances are registered as JAX pytrees: they flow through ``jit`` as
*arguments* (index tables are leaves), so the drivers' compiled-fn caches
keyed on ``tree_structure(W)`` hit across instances of the same shape —
the repo's standing anti-retrace pattern.
"""

from __future__ import annotations

import itertools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Workload", "DenseWorkload", "MarginalWorkload", "as_workload",
    "aug_decompose",
]

# require_dense() refuses to materialize tables past this many bytes —
# callers that genuinely need dense (sharded driver, LSH builds) get a
# loud error at the scale the factored path exists to serve.
_DENSIFY_LIMIT_BYTES = 2**31


def aug_decompose(aug_idx: jax.Array, m: int) -> Tuple[jax.Array, jax.Array]:
    """Augmented id → (base query id, ±1 sign) under the §3.4 closure."""
    base = (aug_idx % m).astype(jnp.int32)
    sign = jnp.where(aug_idx < m, 1.0, -1.0).astype(jnp.float32)
    return base, sign


class Workload:
    """Protocol base. Subclasses provide ``m``/``U`` plus the primitives
    below; shared derived helpers live here."""

    m: int
    U: int
    is_dense: bool

    # -- primitives (subclass responsibility) ---------------------------
    def row(self, j) -> jax.Array:          # (U,) float32, traceable j
        raise NotImplementedError

    def rows(self, ids) -> jax.Array:       # (t, U) float32, traceable ids
        raise NotImplementedError

    def scores(self, v) -> jax.Array:       # (m,) oracle path (parity)
        raise NotImplementedError

    def answer_all(self, v) -> jax.Array:   # (m,) fast path
        raise NotImplementedError

    def densify(self, limit: int = _DENSIFY_LIMIT_BYTES) -> np.ndarray:
        raise NotImplementedError

    # -- shared derived API --------------------------------------------
    @property
    def n_aug(self) -> int:
        """Size of the complement-augmented id space (no rows doubled)."""
        return 2 * self.m

    @property
    def dense_nbytes(self) -> int:
        """Bytes a dense ``(m, U)`` float32 table takes (or would take)."""
        return 4 * self.m * self.U

    def matvec(self, v) -> jax.Array:
        """Workload answers ``Q v`` (fast path)."""
        return self.answer_all(v)

    def probe_scores(self, v) -> jax.Array:
        """Full (m,) signed scores for exhaustive probes: the bitwise
        parity matmul while it's affordable, the fast path past it."""
        return self.answer_all(v)

    def score_in_graph(self, v, aug_ids) -> jax.Array:
        """Traceable augmented-id scores over implicit one-hot products:
        ``sign_j · ⟨q_{j % m}, v⟩``. Same op shape as the dense tail gather
        (`(t, U) @ (U,)`), so bitwise with `core.mwem._aug_score`."""
        base, sign = aug_decompose(jnp.asarray(aug_ids), self.m)
        return (self.rows(base) @ v) * sign

    def max_err(self, h, p) -> jax.Array:
        """‖Q(p − h)‖_∞ without densification (Eq. 1)."""
        return jnp.max(jnp.abs(self.answer_all(p - h)))

    def require_dense(self, context: str,
                      limit: int = _DENSIFY_LIMIT_BYTES) -> jnp.ndarray:
        """Dense table or a loud error naming the consumer — the documented
        densify-fallback for families without a factored build."""
        try:
            return jnp.asarray(self.densify(limit))
        except ValueError as e:
            raise ValueError(
                f"{context} requires a dense (m, U) table but "
                f"{type(self).__name__} with m={self.m}, U={self.U} "
                f"refuses to materialize it: {e}") from e


@jax.tree_util.register_pytree_node_class
class DenseWorkload(Workload):
    """Explicit ``(m, U)`` query matrix — the pre-refactor representation.

    Every primitive is verbatim the expression the drivers used inline, so
    swapping raw ``Q`` for ``DenseWorkload(Q)`` is bitwise-neutral.
    """

    is_dense = True

    def __init__(self, Q):
        self.Q = Q if isinstance(Q, jax.core.Tracer) else \
            jnp.asarray(Q, jnp.float32)

    @property
    def m(self) -> int:
        return int(self.Q.shape[0])

    @property
    def U(self) -> int:
        return int(self.Q.shape[1])

    @property
    def nbytes(self) -> int:
        return 4 * self.m * self.U

    def row(self, j) -> jax.Array:
        return self.Q[j]

    def rows(self, ids) -> jax.Array:
        return self.Q[jnp.asarray(ids)]

    def scores(self, v) -> jax.Array:
        return self.Q @ v

    def answer_all(self, v) -> jax.Array:
        return self.Q @ v

    def max_err(self, h, p) -> jax.Array:
        # verbatim queries.max_error — keeps the dense path bitwise
        return jnp.max(jnp.abs(self.Q @ (p - h)))

    def score_in_graph(self, v, aug_ids) -> jax.Array:
        base, sign = aug_decompose(jnp.asarray(aug_ids), self.m)
        return (self.Q[base] @ v) * sign

    def densify(self, limit: int = _DENSIFY_LIMIT_BYTES) -> np.ndarray:
        return np.asarray(self.Q, np.float32)

    def tree_flatten(self):
        # aux must not read the leaf: jax round-trips pytrees with
        # placeholder leaves during vmap/jit bookkeeping
        return (self.Q,), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        obj = object.__new__(cls)
        obj.Q = leaves[0]
        return obj

    def __repr__(self):
        return f"DenseWorkload(m={self.m}, U={self.U})"


@jax.tree_util.register_pytree_node_class
class MarginalWorkload(Workload):
    """k-way marginal cells over a factored domain, rows kept implicit.

    Domain: mixed-radix product of ``card`` (last attribute fastest), so
    point ``u`` has digit ``(u // dstride[i]) % card[i]`` on attribute
    ``i``. A clique ``(a_1..a_k)`` defines a marginal table whose cell map
    ``cm_c(u) = Σ_j digit_{a_j}(u) · cstride_j`` is recomputed from
    ``arange(U)`` whenever needed. Query ``t`` is the indicator of cell
    ``q_offset[t]`` of clique ``q_clique[t]``: one augmented marginal cell
    per query, ``m = Σ_c Π_j card[a_j]`` total.

    Leaves are the integer index maps (they ride through jit as arguments);
    the static shape/metadata tuple is pytree aux so compiled-driver caches
    key on it.
    """

    is_dense = False

    def __init__(self, card: Sequence[int],
                 cliques: Sequence[Sequence[int]], *,
                 score_block: int = 512, clique_chunk: int = 32):
        card = tuple(int(c) for c in card)
        cliques = tuple(tuple(int(a) for a in cl) for cl in cliques)
        if not cliques:
            raise ValueError("MarginalWorkload needs at least one clique")
        for cl in cliques:
            if len(set(cl)) != len(cl):
                raise ValueError(f"clique {cl} repeats an attribute")
            if any(a < 0 or a >= len(card) for a in cl):
                raise ValueError(f"clique {cl} references a missing "
                                 f"attribute (n_attrs={len(card)})")
        # mixed-radix domain strides, last attribute fastest
        dstr = np.ones(len(card), np.int64)
        for i in range(len(card) - 2, -1, -1):
            dstr[i] = dstr[i + 1] * card[i + 1]
        U = int(dstr[0] * card[0]) if card else 1
        if U >= 2**31:
            raise ValueError(f"domain size {U} overflows int32 cell maps")
        nc = len(cliques)
        kmax = max(len(cl) for cl in cliques)
        cl_dstride = np.ones((nc, kmax), np.int32)
        cl_card = np.ones((nc, kmax), np.int32)   # padding: card 1 → digit 0
        cl_stride = np.zeros((nc, kmax), np.int32)  # padding: stride 0
        cl_cells = np.ones((nc,), np.int32)
        qc, qo = [], []
        for c, cl in enumerate(cliques):
            strides = np.ones(len(cl), np.int64)
            for j in range(len(cl) - 2, -1, -1):
                strides[j] = strides[j + 1] * card[cl[j + 1]]
            ncells = int(strides[0] * card[cl[0]])
            cl_cells[c] = ncells
            for j, a in enumerate(cl):
                cl_dstride[c, j] = dstr[a]
                cl_card[c, j] = card[a]
                cl_stride[c, j] = strides[j]
            qc.append(np.full(ncells, c, np.int32))
            qo.append(np.arange(ncells, dtype=np.int32))
        self.card, self.cliques = card, cliques
        self._U, self.n_cliques, self.kmax = U, nc, kmax
        self.max_cells = int(cl_cells.max())
        self.score_block = int(score_block)
        self.clique_chunk = int(clique_chunk)
        self.q_clique = jnp.asarray(np.concatenate(qc))
        self.q_offset = jnp.asarray(np.concatenate(qo))
        self._m = int(self.q_clique.shape[0])
        self.cl_dstride = jnp.asarray(cl_dstride)
        self.cl_card = jnp.asarray(cl_card)
        self.cl_stride = jnp.asarray(cl_stride)
        self.cl_cells = jnp.asarray(cl_cells)

    @classmethod
    def all_kway(cls, card: Sequence[int], k: int, *,
                 max_cliques: int | None = None, **kw) -> "MarginalWorkload":
        """All (or the first ``max_cliques``) k-way marginals of ``card``."""
        cliques = itertools.combinations(range(len(card)), k)
        if max_cliques is not None:
            cliques = itertools.islice(cliques, max_cliques)
        return cls(card, list(cliques), **kw)

    # -- static metadata ------------------------------------------------
    @property
    def m(self) -> int:
        return self._m

    @property
    def U(self) -> int:
        return self._U

    @property
    def nbytes(self) -> int:
        """Bytes of the factored representation actually held."""
        return sum(4 * int(np.prod(a.shape)) for a in
                   (self.q_clique, self.q_offset, self.cl_dstride,
                    self.cl_card, self.cl_stride, self.cl_cells))

    # -- implicit rows --------------------------------------------------
    def cell_maps(self, cl_ids) -> jax.Array:
        """(t,) clique ids → (t, U) int32 marginal-cell map, recomputed
        from ``arange(U)`` by mixed-radix arithmetic (no stored table)."""
        cl_ids = jnp.asarray(cl_ids, jnp.int32)
        u = jnp.arange(self.U, dtype=jnp.int32)[None, :]
        cm = jnp.zeros((cl_ids.shape[0], self.U), jnp.int32)
        for j in range(self.kmax):  # kmax is tiny and static: unroll
            ds = self.cl_dstride[cl_ids, j][:, None]
            cd = self.cl_card[cl_ids, j][:, None]
            cs = self.cl_stride[cl_ids, j][:, None]
            cm = cm + ((u // ds) % cd) * cs
        return cm

    def rows(self, ids) -> jax.Array:
        ids = jnp.asarray(ids, jnp.int32)
        cm = self.cell_maps(self.q_clique[ids])
        return (cm == self.q_offset[ids][:, None]).astype(jnp.float32)

    def row(self, j) -> jax.Array:
        return self.rows(jnp.reshape(jnp.asarray(j, jnp.int32), (1,)))[0]

    # -- scoring --------------------------------------------------------
    def scores(self, v) -> jax.Array:
        """Oracle path: blockwise implicit-row matmul. A single block when
        ``m ≤ score_block`` — same op shape as dense ``Q @ v``, hence
        bitwise; larger workloads chunk (reassociation accepted there)."""
        B = self.score_block
        if self.m <= B:
            return self.rows(jnp.arange(self.m)) @ v
        nb = -(-self.m // B)
        ids = jnp.clip(jnp.arange(nb * B), 0, self.m - 1)
        out = [self.rows(ids[b * B:(b + 1) * B]) @ v for b in range(nb)]
        return jnp.concatenate(out)[:self.m]

    def marginal_tables(self, v) -> jax.Array:
        """(n_cliques, max_cells) per-clique marginals of ``v`` by segment
        sums, ``O(clique_chunk · U)`` live memory. Cells past a clique's
        arity stay 0."""
        C = min(self.clique_chunk, self.n_cliques)
        nb = -(-self.n_cliques // C)

        def block(b):
            ids = jnp.clip(b * C + jnp.arange(C), 0, self.n_cliques - 1)
            cm = self.cell_maps(ids)
            tab = jnp.zeros((C, self.max_cells), jnp.float32)
            return tab.at[jnp.arange(C)[:, None], cm].add(
                v.astype(jnp.float32)[None, :])

        if nb == 1:
            tabs = block(0)
        else:
            tabs = jax.lax.map(block, jnp.arange(nb))
            tabs = tabs.reshape(nb * C, self.max_cells)
        return tabs[:self.n_cliques]

    def answer_all(self, v) -> jax.Array:
        """Fast path: all m answers from the clique tables — sublinear in
        ``m · U`` (each domain point is touched once per clique, not once
        per query)."""
        tabs = self.marginal_tables(v)
        return tabs[self.q_clique, self.q_offset]

    def probe_scores(self, v) -> jax.Array:
        # the single-matmul parity path at small m (dense-vs-factored
        # bitwise probes), the segment-sum fast path beyond it
        if self.m <= self.score_block:
            return self.scores(v)
        return self.answer_all(v)

    def clique_abs_err(self, v) -> jax.Array:
        """(n_cliques,) max |cell score| per clique — the worst-approximated
        -marginal statistic driving adaptive selection."""
        tabs = jnp.abs(self.marginal_tables(v))
        valid = jnp.arange(self.max_cells)[None, :] < self.cl_cells[:, None]
        return jnp.max(jnp.where(valid, tabs, 0.0), axis=1)

    def clique_slice(self, c: int) -> Tuple[int, int]:
        """Host-side [start, stop) query-id range of clique ``c``."""
        starts = np.concatenate([[0], np.cumsum(np.asarray(self.cl_cells))])
        return int(starts[c]), int(starts[c + 1])

    # -- densification --------------------------------------------------
    def densify(self, limit: int = _DENSIFY_LIMIT_BYTES) -> np.ndarray:
        if self.dense_nbytes > limit:
            raise ValueError(
                f"dense table would be {self.dense_nbytes} bytes "
                f"(> limit {limit})")
        u = np.arange(self.U, dtype=np.int64)
        qc = np.asarray(self.q_clique)
        qo = np.asarray(self.q_offset)
        ds = np.asarray(self.cl_dstride, np.int64)
        cd = np.asarray(self.cl_card, np.int64)
        cs = np.asarray(self.cl_stride, np.int64)
        Q = np.empty((self.m, self.U), np.float32)
        for c in range(self.n_cliques):
            cm = np.zeros_like(u)
            for j in range(self.kmax):
                cm += ((u // ds[c, j]) % cd[c, j]) * cs[c, j]
            sel = qc == c
            Q[sel] = (cm[None, :] == qo[sel][:, None]).astype(np.float32)
        return Q

    # -- pytree ---------------------------------------------------------
    def tree_flatten(self):
        leaves = (self.q_clique, self.q_offset, self.cl_dstride,
                  self.cl_card, self.cl_stride, self.cl_cells)
        aux = (self.card, self.cliques, self._m, self._U, self.n_cliques,
               self.kmax, self.max_cells, self.score_block,
               self.clique_chunk)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        obj = object.__new__(cls)
        (obj.card, obj.cliques, obj._m, obj._U, obj.n_cliques, obj.kmax,
         obj.max_cells, obj.score_block, obj.clique_chunk) = aux
        (obj.q_clique, obj.q_offset, obj.cl_dstride, obj.cl_card,
         obj.cl_stride, obj.cl_cells) = leaves
        return obj

    def __repr__(self):
        return (f"MarginalWorkload(m={self.m}, U={self.U}, "
                f"n_cliques={self.n_cliques}, kmax={self.kmax})")


def as_workload(Q) -> Workload:
    """Coerce raw arrays to `DenseWorkload`; pass workloads through."""
    if isinstance(Q, Workload):
        return Q
    return DenseWorkload(Q)
