"""MWEM (Alg. 1) and Fast-MWEM (Alg. 2) for private linear query release.

The engine is written so that *the only difference* between classic MWEM and
Fast-MWEM is the private-selection oracle — exhaustive EM vs LazyEM over a
k-MIPS index — exactly the surface the paper modifies. Everything else
(multiplicative-weights update, accounting, output averaging) is shared.

Two drivers execute the same iteration (DESIGN.md §2):

* **fused** (`run_mwem_fused`): the whole T-iteration loop is one jitted
  `jax.lax.scan` — selection, the overflow fallback (`lax.cond` to the
  exhaustive Gumbel-max), and the MW update all stay on device; per-iteration
  traces come back as stacked scan outputs in a single transfer. Requires an
  index whose `query(v, k)` is traceable (`supports_in_graph`).
* **host** (`driver="host"`): the original Python loop, one dispatch per
  step. Retained for indices whose search cannot be traced into a scan
  and as the reference for equivalence tests (every built-in index —
  flat/IVF/LSH/NSW — now traces, so auto-routing only lands here for
  third-party indices without ``supports_in_graph``).
* **sharded** (`repro.core.distributed.run_mwem_sharded`, DESIGN.md §4):
  the same scan shard-mapped over a device mesh — Q rows over the data
  axes, the weight state over "model", per-shard IVF selection. Selected
  automatically when more than one device is visible and the workload can
  shard.

`run_mwem` routes between them (`MWEMConfig.driver`); `run_mwem_batch` vmaps
the fused scan over a batch of seeds (and optionally histograms) for
replicated/ensemble release.

Implementation notes:
* weights live in log-space (`log_w`); the multiplicative update is additive
  and `p = softmax(log_w)` — numerically stable for tens of thousands of
  iterations.
* absolute-value scores use the complement closure (§3.4): since
  ``Σ(h − p) = 0``, ``⟨1−q, h−p⟩ = −⟨q, h−p⟩``; augmented index id ``j``
  encodes query ``j % m`` with sign ``+1`` for ``j < m`` else ``−1`` — the
  augmented matrix is never materialized for scoring.
* the update rule is selectable (`"paper"`, `"signed"`, `"hardt"`) — see
  DESIGN.md §1: Alg. 1 as printed omits the sign/measurement step; the
  default `"hardt"` is the original MWEM update. Comparisons always use the
  same rule on both sides so the EM-vs-LazyEM effect is isolated.
* the LazyEM tail buffer can overflow (prob. ≈ e^{-Ω(√m)}); both drivers
  fall back to the exhaustive oracle for that iteration, preserving
  exactness — the fused driver does so in-graph via `lax.cond`.
* both drivers consume randomness through the identical split chain
  (`key → (key, k_sel, k_meas)` per iteration; the fused driver pre-splits
  the whole chain with a key-only scan), so on the same backend they make
  the same selections up to float reassociation in XLA fusion.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accountant import PrivacyLedger, calibrate_eps0
from repro.obs.clock import perf_counter
from repro.obs.telemetry import MechanismTelemetry, aggregate_traces, record_run
from repro.obs.trace import annotate as obs_annotate
from repro.core.gumbel import gumbel
from repro.core.lazy_em import default_tail_cap, fallback_key, lazy_em_from_topk
from repro.core.queries import max_error
from repro.core.workload import Workload, as_workload
from repro.kernels.mwem_step import ops as step_ops
from repro.kernels.mwem_step.ref import mwem_step_ref, mwu_apply_ref
from repro.mips.base import resolve_pallas


@dataclass(frozen=True)
class MWEMConfig:
    eps: float = 1.0
    delta: float = 1e-3
    T: int = 100
    update_rule: str = "hardt"   # "paper" | "signed" | "hardt"
    mode: str = "fast"           # "exact" | "fast"
    driver: str = "auto"         # "auto" | "fused" | "host" | "sharded"
    k: Optional[int] = None      # top-k size; default ceil(√m)
    tail_cap: Optional[int] = None
    margin_slack: float = 0.0    # c ≥ 0 → Alg. 6 privacy-preserving approx mode
    eta: Optional[float] = None  # default √(ln U / T)
    measure_frac: float = 0.5    # ε₀ fraction spent on the Laplace measurement
    eval_every: int = 0          # 0 → only final error
    n_records: Optional[int] = None  # dataset size n → sensitivity Δu = 1/n
    # Megakernel knob for the fused/sharded scans (mips.base semantics):
    # "auto"/"always" run the carried-density mega step — Pallas kernel when
    # resolve_pallas says so AND the shape qualifies, else the XLA ref, both
    # bitwise the host math; "never" keeps the classic pre-fusion body (the
    # roofline baseline).
    use_pallas: str = "auto"

    @staticmethod
    def iterations_for(alpha: float, m: int) -> int:
        """T = 4 α⁻² ln m (Alg. 1/2 line 3)."""
        return max(1, math.ceil(4.0 * math.log(m) / (alpha * alpha)))


def mwem_iteration_counts(alpha: float, m: int) -> int:
    return MWEMConfig.iterations_for(alpha, m)


class MWEMState(NamedTuple):
    log_w: jax.Array   # (U,) log weights
    p_sum: jax.Array   # (U,) running sum of iterates for the averaged output


@dataclass
class MWEMResult:
    p_hat: jax.Array
    final_error: float
    errors: list = field(default_factory=list)        # (t, ‖Q(p−h)‖_∞) pairs
    selected: list = field(default_factory=list)      # chosen query index per t
    n_scored: list = field(default_factory=list)      # score evaluations per t
    overflow_count: int = 0
    iter_seconds: list = field(default_factory=list)
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)
    # host-side aggregation of the scan traces (repro.obs.telemetry) —
    # always populated by the drivers; `amortized=True` marks timing that
    # covers a whole scan/batch rather than measured per-iteration steps
    telemetry: Optional[MechanismTelemetry] = None


@dataclass
class MWEMBatchResult:
    """Stacked outputs of `run_mwem_batch` (leading axis = batch of seeds)."""

    p_hat: jax.Array            # (B, U)
    final_errors: np.ndarray    # (B,)
    selected: np.ndarray        # (B, T)
    n_scored: np.ndarray        # (B, T)
    overflow_counts: np.ndarray  # (B,)
    errors: Optional[np.ndarray] = None  # (B, n_evals) when eval_every set
    eval_every: int = 0
    total_seconds: float = 0.0
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)  # per run
    ledgers: Optional[list] = None  # per-lane ledgers when the caller passed them
    telemetry: Optional[MechanismTelemetry] = None  # whole-batch aggregation

    def unbatch(self) -> list:
        """Materialize one MWEMResult per batch element.

        Each element carries its own ledger when the caller passed per-lane
        ledgers to `run_mwem_batch`; otherwise all elements share the
        per-run ledger (and the B× composition is the caller's contract —
        DESIGN.md §2). Lanes execute concurrently under vmap, so there is
        no honest per-lane, per-iteration wall-clock: ``iter_seconds``
        stays empty and each element's ``telemetry`` record carries the
        whole batch's ``total_seconds`` with ``amortized=True`` — callers
        that need timing read it there instead of mistaking an invented
        ``total/T`` split for a measurement.
        """
        B, T = self.selected.shape
        out = []
        for b in range(B):
            errors = []
            if self.errors is not None:
                errors = [(t, float(e)) for t, e in
                          zip(range(self.eval_every, T + 1, self.eval_every),
                              self.errors[b])]
            tel = None
            if self.telemetry is not None:
                tel = aggregate_traces(
                    workload=self.telemetry.workload,
                    driver=self.telemetry.driver,
                    mode=self.telemetry.mode,
                    m=self.telemetry.m,
                    n_scored=self.n_scored[b],
                    overflow_count=int(self.overflow_counts[b]),
                    total_seconds=self.total_seconds,  # whole-batch wall-clock
                    amortized=True,
                    lanes=1,
                )
            out.append(MWEMResult(
                p_hat=self.p_hat[b],
                final_error=float(self.final_errors[b]),
                errors=errors,
                selected=[int(s) for s in self.selected[b]],
                n_scored=[int(s) for s in self.n_scored[b]],
                overflow_count=int(self.overflow_counts[b]),
                iter_seconds=[],
                ledger=self.ledgers[b] if self.ledgers is not None else self.ledger,
                telemetry=tel,
            ))
        return out


class _Calibration(NamedTuple):
    eps_em: float
    eps_meas: float
    scale: float      # EM log-space factor ε₀/(2Δu)
    lap_scale: float  # Laplace measurement noise scale
    eta: float
    k: int
    tail_cap: int


def _calibrate(cfg: MWEMConfig, m: int, U: int) -> _Calibration:
    """Per-iteration budgets, noise scales and buffer sizes from the config."""
    eps0 = calibrate_eps0(cfg.eps, cfg.delta, cfg.T, scheme="mwem")
    if cfg.update_rule == "paper":
        eps_em, eps_meas = eps0, 0.0
    else:
        eps_em = eps0 * (1.0 - cfg.measure_frac)
        eps_meas = eps0 * cfg.measure_frac
    # Δu = 1/n: changing one of the n records moves one histogram cell by 1/n,
    # so each |⟨q, h−p⟩| utility moves by at most 1/n (q ∈ [0,1]^U).
    if cfg.n_records is None:
        raise ValueError("MWEMConfig.n_records (dataset size n) is required")
    sensitivity = 1.0 / cfg.n_records
    return _Calibration(
        eps_em=eps_em,
        eps_meas=eps_meas,
        scale=float(eps_em / (2.0 * sensitivity)),
        lap_scale=float(sensitivity / max(eps_meas, 1e-12)),
        eta=float(cfg.eta if cfg.eta is not None else math.sqrt(math.log(U) / cfg.T)),
        k=cfg.k or max(1, math.ceil(math.sqrt(m))),
        tail_cap=cfg.tail_cap or default_tail_cap(2 * m),
    )


def _aug_score(W: Workload, v: jax.Array, aug_idx: jax.Array) -> jax.Array:
    """Scores of augmented ids: ⟨q_{j%m}, v⟩ · sign(j<m) (== |·| at the top).

    Delegates to the workload's traceable `score_in_graph` — on dense
    workloads this is verbatim the pre-refactor gather (`(Q[base] @ v) ·
    sign`); factored workloads build the candidate rows implicitly."""
    return W.score_in_graph(v, aug_idx)


def _gumbel_argmax(key: jax.Array, x: jax.Array) -> jax.Array:
    g = gumbel(key, x.shape)
    return jnp.argmax(x + g).astype(jnp.int32)


def _exact_argmax(key: jax.Array, W: Workload, v: jax.Array, scale: float) -> jax.Array:
    """Exhaustive EM (Alg. 1 oracle): score all m queries, Gumbel-max.

    `Workload.scores` is the parity path: dense is ``Q @ v`` unchanged,
    factored is the same-shaped implicit-row matmul (bitwise for
    ``m ≤ score_block``)."""
    return _gumbel_argmax(key, jnp.abs(W.scores(v)) * scale)


_exact_select = jax.jit(_exact_argmax, static_argnames=("scale",))


def _measure_noise(key: jax.Array, rule: str, lap_scale: float) -> jax.Array:
    """Realized Laplace measurement noise — drawn outside the MWU seam so
    the arithmetic below (and the megakernel behind it) is deterministic.
    ``rule="paper"`` takes no measurement and must not consume the key."""
    if rule == "paper":
        return jnp.float32(0.0)
    return lap_scale * jax.random.laplace(key)


@partial(jax.jit, static_argnames=("rule", "eta", "lap_scale"))
def _mwu_step(state: MWEMState, p: jax.Array, q_row: jax.Array, h: jax.Array,
              key: jax.Array, rule: str, eta: float, lap_scale: float) -> MWEMState:
    """One multiplicative-weights update given the selected query row.

    ``p = softmax(state.log_w)`` is passed in (every caller already has it
    for the probe vector) rather than recomputed. This is the ONE MWU entry
    point (host loop + classic scan bodies); the arithmetic lives in
    `kernels.mwem_step.mwu_apply_ref`, the same expression the megakernel
    route and the sharded tail consume — a single integration seam.
    """
    noise = _measure_noise(key, rule, lap_scale)
    log_w, p_new = mwu_apply_ref(state.log_w, p, q_row, h, noise,
                                 rule=rule, eta=eta)
    return MWEMState(log_w=log_w, p_sum=state.p_sum + p_new)


def _record_iteration(ledger: PrivacyLedger, mode: str, rule: str,
                      cal: _Calibration, c_idx: float, margin_slack: float) -> None:
    """Ledger entries for one iteration — shared by both drivers so fused
    and host runs compose to identical privacy totals."""
    if mode == "exact":
        ledger.record(cal.eps_em, 0.0, "em")
    else:
        ledger.record(cal.eps_em, 0.0, "lazy_em")
        if c_idx > 0.0 and margin_slack == 0.0:
            ledger.record_approx_slack(c_idx)  # Thm F.2 runtime mode
    if rule != "paper":
        ledger.record(cal.eps_meas, 0.0, "laplace")


def release_cost(cfg: MWEMConfig, m: int, U: int, index=None
                 ) -> tuple[list, float, float]:
    """The exact privacy-cost bundle one `run_mwem*` run records.

    Returns ``(events, gamma, slack)`` — the (ε₀, δ₀, label) event list for
    T iterations, the index failure mass γ (Thm 3.3), and the already-
    doubled approx slack Σ2c (Thm F.2) — built through the same
    `_calibrate`/`_record_iteration` path the drivers use, so an admission
    controller previews *precisely* what execution will spend
    (`PrivacyLedger.preview(*release_cost(...))` == post-run `composed()`).
    """
    cal = _calibrate(cfg, m, U)
    c_idx = _check_fast_index(cfg, index, fused=False)
    tmp = PrivacyLedger()
    if cfg.mode == "fast":
        tmp.record_index_failure(getattr(index, "failure_mass", 1.0 / m))
    for _ in range(cfg.T):
        _record_iteration(tmp, cfg.mode, cfg.update_rule, cal,
                          c_idx, cfg.margin_slack)
    return list(tmp.events), tmp.index_failure_mass, tmp.approx_slack


def split_chain(key: jax.Array, T: int):
    """Pre-split the per-iteration key pairs by walking the host loop's
    exact chain (``key → key, k_sel, k_meas``) as one key-only scan.

    This is THE key chain: the host loop consumes it step by step, the
    fused and sharded drivers pre-split it through this helper — one point
    of truth, so cross-driver bitwise selection parity cannot drift.
    Returns ``(sel_keys, meas_keys)``, each (T,)-stacked.
    """

    def body(carry_key, _):
        carry_key, k_sel, k_meas = jax.random.split(carry_key, 3)
        return carry_key, (k_sel, k_meas)

    _, keys = jax.lax.scan(body, key, None, length=T)
    return keys


# ---------------------------------------------------------------------------
# Fused on-device driver (DESIGN.md §2)
# ---------------------------------------------------------------------------

_FUSED_STATICS = ("T", "mode", "rule", "eta", "scale", "lap_scale", "k",
                  "tail_cap", "margin_slack", "eval_every", "use_pallas")


def _mega_route(use_pallas: str, U: int) -> tuple[bool, bool]:
    """Resolve the scan-body route from the `use_pallas` knob (static).

    Returns ``(mega, kernel)``: ``mega`` picks the carried-density fused
    step (the megakernel dataflow — DESIGN.md §7) vs the classic
    softmax-per-step body; ``kernel`` picks the Pallas `mwem_step` kernel
    inside the mega route vs its XLA ref — "auto" off-TPU and shapes the
    kernel cannot take fall back to the ref automatically.
    """
    mega = use_pallas != "never"
    kernel = (mega and resolve_pallas(use_pallas)
              and step_ops.mwem_step_supported(U))
    return mega, kernel


def _fused_core(W: Workload, h: jax.Array, state0: MWEMState, key: jax.Array,
                *, query_fn: Optional[Callable], T: int, mode: str, rule: str,
                eta: float, scale: float, lap_scale: float, k: int,
                tail_cap: int, margin_slack: float, eval_every: int,
                use_pallas: str = "auto", query_returns_scores: bool = False):
    """The whole (Fast-)MWEM loop as one `lax.scan` — zero host round-trips.

    Pre-splits the per-iteration key pairs with a key-only scan that walks
    the exact chain the host loop uses (``key → key, k_sel, k_meas``), so
    the two drivers are distributionally (and, modulo XLA float
    reassociation, bitwise) interchangeable.

    ``query_returns_scores``: the probe is exhaustive and hands back the
    full (m,) signed score vector — tail scoring and the overflow fallback
    become O(tail_cap)/O(m) lookups instead of re-touching Q.

    ``use_pallas != "never"`` swaps the step tail for the megakernel
    dataflow: the scan carries ``(state, p)`` so the per-step softmax
    disappears (the MWU renormalizes in the same pass), and measure + MWU +
    renorm run as one VMEM-resident `kernels.mwem_step` call that streams
    only the winning query row. Selection and the overflow `lax.cond` stay
    outside the kernel — bitwise host parity is the contract.
    """
    m = W.m
    U = state0.log_w.shape[-1]
    mega, kernel = _mega_route(use_pallas, U)
    sel_keys, meas_keys = split_chain(key, T)

    def select(k_sel, v):
        """Private selection → ``(sel, n_scored, tail_count, overflow)``.

        On tail-buffer overflow the `lax.cond` redoes the step with the
        exhaustive Gumbel-max under `lazy_em.fallback_key` (a fresh key —
        the lazy pass already consumed ``k_sel``'s Gumbels, and the host
        driver folds identically, so parity holds). The cond keeps the
        heavy branch unexecuted on the non-overflow path of an unbatched
        run.
        """
        if mode == "exact":
            return (_exact_argmax(k_sel, W, v, scale), jnp.int32(m),
                    jnp.int32(0), jnp.bool_(False))
        if query_returns_scores:
            aug_idx, raw, s_full = query_fn(v, k)
            score_fn = lambda idx: jnp.where(  # noqa: E731
                idx < m, s_full[idx % m], -s_full[idx % m]) * scale
            fallback = lambda _: _gumbel_argmax(  # noqa: E731
                fallback_key(k_sel), jnp.abs(s_full) * scale)
        else:
            aug_idx, raw = query_fn(v, k)
            if kernel and W.is_dense:
                # tail candidates stream once via the scalar-prefetched
                # gather-score kernel (bitwise `_aug_score` — per-row dot)
                score_fn = lambda idx: (  # noqa: E731
                    step_ops.aug_gather_score(W.Q, v, idx) * scale)
            elif kernel:
                # factored row fetch: offsets + implicit one-hot products,
                # no (m, U) gather anywhere
                score_fn = lambda idx: (  # noqa: E731
                    step_ops.marginal_gather_score(W, v, idx) * scale)
            else:
                score_fn = lambda idx: _aug_score(W, v, idx) * scale  # noqa: E731
            fallback = lambda _: _exact_argmax(  # noqa: E731
                fallback_key(k_sel), W, v, scale)
        out = lazy_em_from_topk(
            k_sel, aug_idx, raw * scale, 2 * m,
            score_fn=score_fn,
            tail_cap=tail_cap,
            margin_slack=margin_slack * scale if margin_slack else 0.0,
        )
        sel = jax.lax.cond(
            out.overflow,
            fallback,
            lambda _: (out.index % m).astype(jnp.int32),
            operand=None,
        )
        n_scored = jnp.where(out.overflow, jnp.int32(m), out.n_scored)
        return sel, n_scored, out.tail_count, out.overflow

    def eval_ys(t, p_sum):
        # Gated on the eval schedule: the Θ(mU) error matmul would
        # otherwise run every iteration and erase the sublinear win.
        return jax.lax.cond(
            t % eval_every == 0,
            lambda _: max_error(W, h, p_sum / t.astype(jnp.float32)),
            lambda _: jnp.float32(jnp.nan),
            operand=None,
        )

    ts = jnp.arange(1, T + 1)

    if mega:
        def body(carry, xs):
            state, p = carry
            t, k_sel, k_meas = xs
            v = h - p
            sel, n_scored, tail_count, overflow = select(k_sel, v)
            noise = _measure_noise(k_meas, rule, lap_scale)
            if kernel and W.is_dense:
                lw, p_new, ps = step_ops.mwem_step(
                    state.log_w, p, state.p_sum, W.Q, sel, h, noise,
                    rule=rule, eta=eta)
            elif kernel:
                # factored winner row arrives materialized (one implicit
                # one-hot expansion); same kernel body via the
                # no-prefetch-table variant
                lw, p_new, ps = step_ops.mwu_apply(
                    state.log_w, p, state.p_sum, W.row(sel), h, noise,
                    rule=rule, eta=eta)
            else:
                lw, p_new, ps = mwem_step_ref(
                    state.log_w, p, state.p_sum, W.row(sel), h, noise,
                    rule=rule, eta=eta)
            new_state = MWEMState(log_w=lw, p_sum=ps)
            ys = (sel, n_scored, tail_count, overflow)
            if eval_every:
                ys = ys + (eval_ys(t, new_state.p_sum),)
            return (new_state, p_new), ys

        carry0 = (state0, jax.nn.softmax(state0.log_w))
        (final_state, _), traces = jax.lax.scan(
            body, carry0, (ts, sel_keys, meas_keys))
        return final_state, traces

    def body(state, xs):
        t, k_sel, k_meas = xs
        p = jax.nn.softmax(state.log_w)
        v = h - p
        sel, n_scored, tail_count, overflow = select(k_sel, v)
        new_state = _mwu_step(state, p, W.row(sel), h, k_meas, rule=rule,
                              eta=eta, lap_scale=lap_scale)
        ys = (sel, n_scored, tail_count, overflow)
        if eval_every:
            ys = ys + (eval_ys(t, new_state.p_sum),)
        return new_state, ys

    return jax.lax.scan(body, state0, (ts, sel_keys, meas_keys))


def _fused_core_waved(W: Workload, h: jax.Array, state0: MWEMState,
                      keys: jax.Array, *, batch_query_fn: Callable, T: int,
                      mode: str, rule: str, eta: float, scale: float,
                      lap_scale: float, k: int, tail_cap: int,
                      margin_slack: float, eval_every: int,
                      use_pallas: str = "auto"):
    """The batched fused loop with a *wave-batched* probe (DESIGN.md §3).

    `run_mwem_batch`'s default shape is `vmap(_fused_core)`: every lane
    probes the index independently, which XLA lowers to per-lane scattered
    gathers. When the index serves a whole wave per call
    (``supports_batch_probe``), this core scans once over T carrying all B
    lanes and hands the stacked (B, U) probe block to
    ``index.query_in_graph_batch`` — on the kernel route, cells probed by
    several lanes stream from HBM once and scoring is MXU-batched.
    Everything after the probe (LazyEM, overflow fallback, MW update) is
    the vmapped per-lane math of `_fused_core`, and the key chain is the
    per-lane `split_chain`, so lane b reproduces `run_mwem_fused(key_b)`
    (same trace fields, same ledger path; bitwise when the batched probe
    equals the per-lane probe — exactly true on the XLA route, up to exact
    score ties on the batch-kernel route).
    """
    m = W.m
    B = keys.shape[0]
    U = state0.log_w.shape[-1]
    if mode != "fast":
        raise ValueError("the waved core only serves mode='fast' probes")
    mega, kernel = _mega_route(use_pallas, U)
    sel_keys, meas_keys = jax.vmap(lambda kk: split_chain(kk, T))(keys)
    sel_keys = jnp.moveaxis(sel_keys, 0, 1)    # (T, B, key)
    meas_keys = jnp.moveaxis(meas_keys, 0, 1)
    batched_h = h.ndim == 2
    mwu = partial(_mwu_step, rule=rule, eta=eta, lap_scale=lap_scale)

    def select_one(k_sel, v, aug_idx, raw):
        out = lazy_em_from_topk(
            k_sel, aug_idx, raw * scale, 2 * m,
            score_fn=lambda idx: _aug_score(W, v, idx) * scale,
            tail_cap=tail_cap,
            margin_slack=margin_slack * scale if margin_slack else 0.0,
        )
        sel = jax.lax.cond(
            out.overflow,
            lambda _: _exact_argmax(fallback_key(k_sel), W, v, scale),
            lambda _: (out.index % m).astype(jnp.int32),
            operand=None,
        )
        n_scored = jnp.where(out.overflow, jnp.int32(m), out.n_scored)
        return sel, n_scored, out.tail_count, out.overflow

    def eval_ys(t, p_sum):
        err_fn = jax.vmap(partial(max_error, W),
                          in_axes=(0 if batched_h else None, 0))
        return jax.lax.cond(
            t % eval_every == 0,
            lambda _: err_fn(h, p_sum / t.astype(jnp.float32)),
            lambda _: jnp.full((B,), jnp.nan, jnp.float32),
            operand=None,
        )

    ts = jnp.arange(1, T + 1)

    if mega:
        noise_fn = jax.vmap(partial(_measure_noise, rule=rule,
                                    lap_scale=lap_scale))
        step_ref = partial(mwem_step_ref, rule=rule, eta=eta)

        def body(carry, xs):
            state, p = carry                        # (B, U) each
            t, k_sel, k_meas = xs                   # keys (B, ...)
            v = h - p                               # (B, U)
            aug_idx, raw = batch_query_fn(v, k)     # (B, k) each
            sel, n_scored, tail_count, overflow = jax.vmap(select_one)(
                k_sel, v, aug_idx, raw)
            noise = noise_fn(k_meas)                # (B,)
            if kernel and W.is_dense:
                lw, p_new, ps = step_ops.mwem_step_batch(
                    state.log_w, p, state.p_sum, W.Q, sel, h, noise,
                    rule=rule, eta=eta)
            else:
                lw, p_new, ps = jax.vmap(
                    step_ref, in_axes=(0, 0, 0, 0, 0 if batched_h else None,
                                       0))(state.log_w, p, state.p_sum,
                                           W.rows(sel), h, noise)
            new_state = MWEMState(log_w=lw, p_sum=ps)
            ys = (sel, n_scored, tail_count, overflow)
            if eval_every:
                ys = ys + (eval_ys(t, new_state.p_sum),)
            return (new_state, p_new), ys

        carry0 = (state0, jax.nn.softmax(state0.log_w, axis=-1))
        (final_state, _), traces = jax.lax.scan(
            body, carry0, (ts, sel_keys, meas_keys))
    else:
        def body(state, xs):
            t, k_sel, k_meas = xs                   # keys (B, ...)
            p = jax.nn.softmax(state.log_w, axis=-1)   # (B, U)
            v = h - p                                   # (B, U)
            aug_idx, raw = batch_query_fn(v, k)         # (B, k) each
            sel, n_scored, tail_count, overflow = jax.vmap(select_one)(
                k_sel, v, aug_idx, raw)
            new_state = jax.vmap(mwu, in_axes=(0, 0, 0,
                                               0 if batched_h else None,
                                               0))(state, p, W.rows(sel), h,
                                                   k_meas)
            ys = (sel, n_scored, tail_count, overflow)
            if eval_every:
                ys = ys + (eval_ys(t, new_state.p_sum),)
            return new_state, ys

        final_state, traces = jax.lax.scan(body, state0,
                                           (ts, sel_keys, meas_keys))
    # (T, B) stacked scan outputs → the (B, T) layout vmap(core) produces
    traces = jax.tree_util.tree_map(lambda x: jnp.swapaxes(x, 0, 1), traces)
    return final_state, traces


_EXACT_DRIVER_CACHE: dict = {}


def _waved_route(index, batch_axes) -> bool:
    """Whether the batched driver should scan with the wave-batched probe
    instead of vmapping the per-lane core: the index must serve whole
    waves, and must not be on the full-score-reuse path (which hands the
    scan body the (m,) score vector the waved probe never materializes)."""
    return (batch_axes is not None
            and getattr(index, "supports_batch_probe", False)
            and not getattr(index, "has_full_scores", False))


def _fused_driver(index, statics: dict, batch_axes=None) -> Callable:
    """Build (or fetch) the jitted fused driver for an (index, config) pair.

    Compiled drivers are cached on the index instance (module-level for
    ``mode="exact"``) so repeated runs with the same shapes re-dispatch the
    cached executable. The carried `MWEMState` buffers are donated.
    ``batch_axes`` is a vmap ``in_axes`` tuple over (Q, h, state0, key) for
    the batched driver, or None for the single-run driver.
    """
    cache = (_EXACT_DRIVER_CACHE if index is None
             else index.__dict__.setdefault("_fused_driver_cache", {}))
    waved = _waved_route(index, batch_axes)
    # the route (and the kernel-vs-XLA probe under it) is resolved at trace
    # time, so a flipped `use_pallas` knob must never reuse a stale entry
    ck = (tuple(sorted(statics.items())), batch_axes, waved,
          getattr(index, "_use_pallas", None))
    entry = cache.get(ck)
    if entry is None:
        if waved:
            core = partial(_fused_core_waved,
                           batch_query_fn=index.query_in_graph_batch,
                           **statics)
        else:
            query_fn = None
            if getattr(index, "has_full_scores", False):
                query_fn = index.query_in_graph_with_scores
                statics = dict(statics, query_returns_scores=True)
            elif index is not None:
                query_fn = index.query_in_graph
            core = partial(_fused_core, query_fn=query_fn, **statics)
            if batch_axes is not None:
                core = jax.vmap(core, in_axes=batch_axes)
        entry = (jax.jit(core, donate_argnums=(2,)), {})
        cache[ck] = entry
    return entry


def _compiled_driver(entry, *args) -> Callable:
    """AOT-compile the driver for these arg shapes (cached), so callers can
    keep trace+compile out of the timed region — fused ``iter_seconds``
    measures execution only."""
    fn, exes = entry
    # treedef joins the key: workloads are pytrees whose aux (cliques,
    # chunk sizes) can differ between instances with identical leaf shapes
    skey = (jax.tree_util.tree_structure(args),
            tuple((tuple(x.shape), str(x.dtype))
                  for x in jax.tree_util.tree_leaves(args)))
    exe = exes.get(skey)
    if exe is None:
        exe = fn.lower(*args).compile()
        exes[skey] = exe
    return exe


def _fused_statics(cfg: MWEMConfig, cal: _Calibration) -> dict:
    return dict(T=cfg.T, mode=cfg.mode, rule=cfg.update_rule, eta=cal.eta,
                scale=cal.scale, lap_scale=cal.lap_scale, k=cal.k,
                tail_cap=cal.tail_cap, margin_slack=cfg.margin_slack,
                eval_every=cfg.eval_every, use_pallas=cfg.use_pallas)


def _check_fast_index(cfg: MWEMConfig, index, fused: bool) -> float:
    if cfg.mode not in ("exact", "fast"):
        raise ValueError(f"unknown mode {cfg.mode!r}")
    if cfg.mode != "fast":
        return 0.0
    if index is None:
        raise ValueError("fast mode requires a k-MIPS index")
    if fused and not getattr(index, "supports_in_graph", False):
        raise ValueError(
            f"{type(index).__name__} cannot be traced into the fused scan "
            "(supports_in_graph=False); use driver='host'")
    return float(getattr(index, "approx_margin", 0.0))


def run_mwem_fused(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> MWEMResult:
    """Run (Fast-)MWEM as a single fused scan dispatch.

    Exactly one device→host transfer moves the stacked per-iteration traces
    (`selected`, `n_scored`, `tail_count`, `overflow`, and the running error
    when ``eval_every`` is set) back; `MWEMResult` is reconstructed from
    them. ``iter_seconds`` holds the amortized *execution* wall-clock per
    iteration (total / T): trace+compile happen outside the timed region
    via a cached AOT executable, and individual steps are not observable
    from the host.
    """
    W = as_workload(Q)
    m, U = W.m, W.U
    cal = _calibrate(cfg, m, U)
    c_idx = _check_fast_index(cfg, index, fused=True)

    res = MWEMResult(p_hat=None, final_error=float("nan"),
                     ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))

    entry = _fused_driver(index if cfg.mode == "fast" else None,
                          _fused_statics(cfg, cal))
    state0 = MWEMState(log_w=jnp.zeros((U,), jnp.float32),
                       p_sum=jnp.zeros((U,), jnp.float32))
    args = (W, jnp.asarray(h, jnp.float32), state0, key)
    driver = _compiled_driver(entry, *args)
    t0 = perf_counter()
    with obs_annotate("mwem/fused"):
        final_state, traces = driver(*args)
        jax.block_until_ready(final_state.p_sum)
    total = perf_counter() - t0

    traces = jax.device_get(traces)
    sel_t, n_scored_t, _tail_t, over_t = traces[:4]
    res.selected = [int(s) for s in sel_t]
    res.n_scored = [int(s) for s in n_scored_t]
    res.overflow_count = int(np.sum(over_t))
    res.iter_seconds = [total / cfg.T] * cfg.T
    res.telemetry = record_run(
        workload="mwem", driver="fused", mode=cfg.mode, m=m,
        n_scored=n_scored_t, overflow_count=res.overflow_count,
        total_seconds=total, amortized=True)
    for _ in range(cfg.T):
        _record_iteration(res.ledger, cfg.mode, cfg.update_rule, cal,
                          c_idx, cfg.margin_slack)
    if cfg.eval_every:
        errs = traces[4]
        res.errors = [(t, float(errs[t - 1]))
                      for t in range(cfg.eval_every, cfg.T + 1, cfg.eval_every)]

    res.p_hat = final_state.p_sum / cfg.T
    res.final_error = float(max_error(W, h, res.p_hat))
    return res


@dataclass
class MWEMPendingBatch:
    """Handle for an in-flight `launch_mwem_batch` dispatch.

    Holds the device futures the async dispatch returned plus everything
    `finish_mwem_batch` needs to rebuild the exact `MWEMBatchResult` that
    `run_mwem_batch` would have produced synchronously. Nothing here has
    been blocked on: the scan may still be executing when the caller gets
    this object back, which is what lets a streaming server overlap the
    next wave's host-side prep and transfers with this wave's scan."""

    final_state: MWEMState      # (B, U) device futures
    traces: tuple               # stacked scan outputs, unfetched
    t0: float                   # perf_counter stamp at dispatch
    W: Workload
    h: jax.Array
    batched_h: bool
    cfg: MWEMConfig
    cal: _Calibration
    c_idx: float
    index: object
    lanes: int
    driver_label: str


def launch_mwem_batch(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    keys: jax.Array,
    index=None,
) -> MWEMPendingBatch:
    """Dispatch one batched wave asynchronously — the launch half of
    `run_mwem_batch`.

    Calibration, driver lookup, and the cached AOT compile all happen
    here; the compiled executable is dispatched *without* blocking, so the
    returned handle's device buffers are futures. `finish_mwem_batch`
    blocks and assembles the result; ``run_mwem_batch(...)`` is exactly
    ``finish_mwem_batch(launch_mwem_batch(...))``, so a launched wave is
    bitwise identical to a synchronous one.
    """
    if cfg.driver == "host":
        raise ValueError("run_mwem_batch always uses the fused driver; "
                         "loop run_mwem(..., driver='host') for host runs")
    W = as_workload(Q)
    m, U = W.m, W.U
    keys = jnp.asarray(keys)
    B = keys.shape[0]
    h = jnp.asarray(h, jnp.float32)
    batched_h = h.ndim == 2
    cal = _calibrate(cfg, m, U)
    c_idx = _check_fast_index(cfg, index, fused=True)

    batch_axes = (None, 0 if batched_h else None, 0, 0)
    entry = _fused_driver(index if cfg.mode == "fast" else None,
                          _fused_statics(cfg, cal),
                          batch_axes=batch_axes)
    driver_label = ("waved"
                    if _waved_route(index if cfg.mode == "fast" else None,
                                    batch_axes)
                    else "fused")
    state0 = MWEMState(log_w=jnp.zeros((B, U), jnp.float32),
                       p_sum=jnp.zeros((B, U), jnp.float32))
    args = (W, h, state0, keys)
    driver = _compiled_driver(entry, *args)
    t0 = perf_counter()
    with obs_annotate(f"mwem/batch/{driver_label}"):
        final_state, traces = driver(*args)
    return MWEMPendingBatch(
        final_state=final_state, traces=traces, t0=t0, W=W, h=h,
        batched_h=batched_h, cfg=cfg, cal=cal, c_idx=c_idx, index=index,
        lanes=B, driver_label=driver_label)


def finish_mwem_batch(pending: MWEMPendingBatch,
                      ledgers: Optional[list] = None) -> MWEMBatchResult:
    """Block on a launched wave and assemble its `MWEMBatchResult` — the
    finish half of `run_mwem_batch` (ledger charging, trace fetch, and
    telemetry all happen here, after the device work lands)."""
    W, cfg, cal = pending.W, pending.cfg, pending.cal
    index, B = pending.index, pending.lanes
    h, batched_h = pending.h, pending.batched_h
    m = W.m
    if ledgers is not None and len(ledgers) != B:
        raise ValueError(f"ledgers must have one entry per lane "
                         f"({len(ledgers)} != {B})")
    with obs_annotate(f"mwem/batch/{pending.driver_label}/finish"):
        final_state, traces = pending.final_state, pending.traces
        jax.block_until_ready(final_state.p_sum)
    total = perf_counter() - pending.t0

    p_hat = final_state.p_sum / cfg.T
    if W.is_dense:  # pre-refactor expression, kept bitwise
        final_errors = jnp.max(jnp.abs((h - p_hat) @ W.Q.T), axis=-1)
    else:
        final_errors = jax.vmap(
            lambda hh, pp: max_error(W, hh, pp),
            in_axes=(0 if batched_h else None, 0))(h, p_hat)

    ledger = PrivacyLedger()
    if cfg.mode == "fast":
        ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))
    for _ in range(cfg.T):
        _record_iteration(ledger, cfg.mode, cfg.update_rule, cal,
                          pending.c_idx, cfg.margin_slack)
    if ledgers is not None:
        for lane in ledgers:
            if lane is not None:
                lane.record_events(ledger.events, ledger.index_failure_mass,
                                   ledger.approx_slack)

    traces = jax.device_get(traces)
    errors = None
    if cfg.eval_every:
        eval_ts = range(cfg.eval_every, cfg.T + 1, cfg.eval_every)
        errors = np.asarray(traces[4])[:, [t - 1 for t in eval_ts]]
    telemetry = record_run(
        workload="mwem", driver=pending.driver_label, mode=cfg.mode, m=m,
        n_scored=np.asarray(traces[1]),
        overflow_count=int(np.asarray(traces[3]).sum()),
        total_seconds=total, amortized=True, lanes=B)
    return MWEMBatchResult(
        p_hat=p_hat,
        final_errors=np.asarray(final_errors),
        selected=np.asarray(traces[0]),
        n_scored=np.asarray(traces[1]),
        overflow_counts=np.asarray(traces[3]).sum(axis=1),
        errors=errors,
        eval_every=cfg.eval_every,
        total_seconds=total,
        ledger=ledger,
        ledgers=list(ledgers) if ledgers is not None else None,
        telemetry=telemetry,
    )


def aot_compile_batch(Q, cfg: MWEMConfig, lanes: int, index=None,
                      batched_h: bool = True) -> bool:
    """Populate the batched driver's AOT executable cache for a
    ``lanes``-wide wave without dispatching any work.

    The streaming serving tier compiles one executable per wave size in a
    small ladder up front (`ReleaseService.prewarm`), then picks the best
    fit per wave instead of padding every short wave to one size. Returns
    True when a new executable was compiled, False when the cache already
    held this (shape, statics) entry. The compiled artifact lands in the
    same cache `run_mwem_batch`/`launch_mwem_batch` consult, so the first
    live wave at this lane count pays zero trace+compile.
    """
    if cfg.driver == "host":
        raise ValueError("run_mwem_batch always uses the fused driver; "
                         "loop run_mwem(..., driver='host') for host runs")
    W = as_workload(Q)
    m, U = W.m, W.U
    cal = _calibrate(cfg, m, U)
    _check_fast_index(cfg, index, fused=True)
    batch_axes = (None, 0 if batched_h else None, 0, 0)
    entry = _fused_driver(index if cfg.mode == "fast" else None,
                          _fused_statics(cfg, cal),
                          batch_axes=batch_axes)
    h = jnp.zeros((lanes, U) if batched_h else (U,), jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(0)] * lanes)
    state0 = MWEMState(log_w=jnp.zeros((lanes, U), jnp.float32),
                       p_sum=jnp.zeros((lanes, U), jnp.float32))
    n_before = len(entry[1])
    _compiled_driver(entry, W, h, state0, keys)
    return len(entry[1]) > n_before


def run_mwem_batch(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    keys: jax.Array,
    index=None,
    ledgers: Optional[list] = None,
) -> MWEMBatchResult:
    """Vmapped fused scan over a batch of PRNG keys — replicated release.

    Args:
      keys: (B,)-stacked PRNG keys (e.g. ``jnp.stack([PRNGKey(s) for s in
        seeds])``); each batch element reproduces exactly what
        `run_mwem_fused` produces for that key.
      h: shared ``(U,)`` histogram, or ``(B, U)`` for per-element data.
      ledgers: optional list of B `PrivacyLedger`s, one per lane — each
        receives that lane's full event bundle (`release_cost`), which is
        how a multi-tenant caller (repro.serve) charges each tenant's
        session for its own slot in the wave. ``None`` entries skip a lane
        (padding slots).

    The privacy ledger on the result is *per run* (each batch element
    composes the same totals); serving B replicas spends B× the budget and
    the caller accounts for the multiplicity — either manually or by
    passing per-lane ``ledgers``.

    Batching is fused-only (``driver="host"`` raises). Indices that serve
    whole waves (``supports_batch_probe`` — IVF, and FlatAbs on TPU) route
    through the wave-batched scan core instead of `vmap`: one probe call
    covers all B lanes per iteration (the kernelized route reads cells
    probed by several lanes once — DESIGN.md §3). Per-lane parity with
    `run_mwem_fused` is bitwise on the XLA probe route; the TPU batch
    kernel's slot ordering can break *exact* score ties differently than
    a standalone probe (kernels/ivf_probe/ref.py). Cost caveat: under
    either route the
    overflow-fallback `lax.cond` lowers to a select that executes both
    branches every iteration, so for probe-only indices (IVF/LSH) each
    batched iteration pays the Θ(mU) exhaustive branch — batch those
    through a Python loop over `run_mwem` if selection cost matters more
    than dispatch (DESIGN.md §2).
    """
    if cfg.driver == "host":
        raise ValueError("run_mwem_batch always uses the fused driver; "
                         "loop run_mwem(..., driver='host') for host runs")
    B = jnp.asarray(keys).shape[0]
    if ledgers is not None and len(ledgers) != B:
        raise ValueError(f"ledgers must have one entry per lane "
                         f"({len(ledgers)} != {B})")
    return finish_mwem_batch(launch_mwem_batch(Q, h, cfg, keys, index=index),
                             ledgers=ledgers)


# ---------------------------------------------------------------------------
# Host-loop driver (reference / non-traceable indices)
# ---------------------------------------------------------------------------

def _run_mwem_host(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> MWEMResult:
    """One jit dispatch per step; `bool(out.overflow)` syncs to the host."""
    W = as_workload(Q)
    m, U = W.m, W.U
    cal = _calibrate(cfg, m, U)
    c_idx = _check_fast_index(cfg, index, fused=False)

    res = MWEMResult(p_hat=None, final_error=float("nan"),
                     ledger=ledger if ledger is not None else PrivacyLedger())
    state = MWEMState(log_w=jnp.zeros((U,), jnp.float32),
                      p_sum=jnp.zeros((U,), jnp.float32))

    if cfg.mode == "fast":
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))

        @jax.jit
        def fast_select(key, topk_idx, topk_scores, Wm, v):
            return lazy_em_from_topk(
                key, topk_idx,
                topk_scores * cal.scale,
                2 * m,
                score_fn=lambda idx: _aug_score(Wm, v, idx) * cal.scale,
                tail_cap=cal.tail_cap,
                margin_slack=cfg.margin_slack * cal.scale if cfg.margin_slack else 0.0,
            )

    with obs_annotate("mwem/host"):
        for t in range(cfg.T):
            key, k_sel, k_meas = jax.random.split(key, 3)
            t0 = perf_counter()
            p = jax.nn.softmax(state.log_w)
            v = h - p
            if cfg.mode == "exact":
                sel = int(_exact_select(k_sel, W, v, scale=cal.scale))
                res.n_scored.append(m)
            else:
                aug_idx, raw = index.query(v, cal.k)
                out = fast_select(k_sel, aug_idx, raw, W, v)
                if bool(out.overflow):
                    # fresh fold of k_sel (lazy_em.fallback_key) — the lazy
                    # pass already consumed k_sel's Gumbels; the fused
                    # drivers fold identically in-graph so parity holds
                    sel = int(_exact_select(fallback_key(k_sel), W, v,
                                            scale=cal.scale))
                    res.overflow_count += 1
                    res.n_scored.append(m)
                else:
                    sel = int(out.index) % m
                    res.n_scored.append(int(out.n_scored))
            _record_iteration(res.ledger, cfg.mode, cfg.update_rule, cal,
                              c_idx, cfg.margin_slack)
            state = _mwu_step(state, p, W.row(sel), h, k_meas,
                              rule=cfg.update_rule, eta=cal.eta,
                              lap_scale=cal.lap_scale)
            jax.block_until_ready(state.log_w)
            res.iter_seconds.append(perf_counter() - t0)
            res.selected.append(sel)
            if cfg.eval_every and (t + 1) % cfg.eval_every == 0:
                p_avg = state.p_sum / (t + 1)
                res.errors.append((t + 1, float(max_error(W, h, p_avg))))

    p_hat = state.p_sum / cfg.T
    res.p_hat = p_hat
    res.final_error = float(max_error(W, h, p_hat))
    res.telemetry = record_run(
        workload="mwem", driver="host", mode=cfg.mode, m=m,
        n_scored=res.n_scored, overflow_count=res.overflow_count,
        total_seconds=sum(res.iter_seconds), amortized=False)
    return res


def _sharded_fits(index, mesh, shape) -> bool:
    """Whether (m, U) actually divides over the mesh (or the default driver
    mesh) and the index's shard count matches — auto-routing must not pick
    a driver that will refuse the workload."""
    if shape is None:
        return True  # no workload in hand (introspection) — assume fits
    m, U = shape
    sharded_index = getattr(index, "supports_sharded", False)
    if mesh is not None:
        from repro.core.distributed import _data_shards

        n_data = _data_shards(mesh)[1]
        n_model = mesh.shape["model"]
    else:
        # default make_driver_mesh(): all devices on "data", model degree 1
        n_data, n_model = jax.device_count(), 1
    if sharded_index and index.n_shards != n_data:
        return False
    return m % n_data == 0 and U % n_model == 0


def _resolve_driver(cfg: MWEMConfig, index, mesh=None, shape=None,
                    densifiable: bool = True) -> str:
    if cfg.driver not in ("auto", "fused", "host", "sharded"):
        raise ValueError(f"unknown driver {cfg.driver!r}")
    if cfg.driver != "auto":
        return cfg.driver
    # the sharded driver kicks in when there is real device parallelism (or
    # the caller handed us a mesh, or the index only works sharded) and the
    # workload can shard: exact mode always can; fast mode needs a
    # per-shard index structure. Factored workloads past the densify limit
    # never auto-shard (the sharded driver's documented fallback is a dense
    # table) — they stay on the fused/host factored path.
    sharded_ok = (densifiable
                  and (cfg.mode == "exact"
                       or getattr(index, "supports_sharded", False)))
    sharded_only = (getattr(index, "supports_sharded", False)
                    and not getattr(index, "supports_in_graph", False))
    want = mesh is not None or jax.device_count() > 1 or sharded_only
    if sharded_ok and want and _sharded_fits(index, mesh, shape):
        return "sharded"
    if sharded_only:
        # a per-shard-only index has no host/fused query path — surface the
        # mismatch instead of crashing mid-run in the host loop
        raise ValueError(
            f"{type(index).__name__} only runs on the sharded driver, but "
            "the workload/mesh/shard counts do not line up "
            "(m must divide over the data shards, U over the model shards, "
            "and index.n_shards must equal the mesh's data extent)")
    if cfg.mode == "exact":
        return "fused"
    if index is not None and getattr(index, "supports_in_graph", False):
        return "fused"
    return "host"


def run_mwem(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
    mesh=None,
) -> MWEMResult:
    """Run (Fast-)MWEM for ``cfg.T`` iterations.

    Args:
      Q: (m, U) query matrix with entries in [0, 1].
      h: (U,) true normalized histogram.
      cfg: engine configuration. ``mode="fast"`` requires ``index``
        (``driver="sharded"`` builds a per-shard one when ``index=None``).
        ``driver="auto"`` shards the run across devices when more than one
        is visible (or a ``mesh`` is passed) and the index has a per-shard
        structure (`ShardedIVFIndex`); otherwise it fuses the loop
        on-device whenever the index's query is traceable (all built-in
        indices — flat/IVF/LSH/NSW); host-only third-party indices fall
        back to the Python loop. ``cfg.use_pallas`` picks the fused scan's
        step body (megakernel vs classic — DESIGN.md §7).
      key: PRNG key.
      index: a k-MIPS index over the complement-augmented queries
        (see repro.mips); must expose ``query(v, k) -> (aug_idx, raw_scores)``
        and attributes ``approx_margin`` (c ≥ 0) and ``failure_mass`` (γ).
      mesh: device mesh for the sharded driver (forces ``driver="auto"``
        routing onto it; ignored by the fused/host drivers).

    ``Q`` may be a raw ``(m, U)`` array or any `core.workload.Workload`
    (`MarginalWorkload` runs factored end to end on the fused/host
    drivers; the sharded driver densifies — its documented fallback).
    """
    W = as_workload(Q)
    from repro.core.workload import _DENSIFY_LIMIT_BYTES
    densifiable = W.is_dense or W.dense_nbytes <= _DENSIFY_LIMIT_BYTES
    driver = _resolve_driver(cfg, index, mesh=mesh, shape=(W.m, W.U),
                             densifiable=densifiable)
    if driver == "sharded":
        from repro.core.distributed import run_mwem_sharded

        return run_mwem_sharded(W, h, cfg, key, mesh=mesh, index=index,
                                ledger=ledger)
    if driver == "fused":
        return run_mwem_fused(W, h, cfg, key, index=index, ledger=ledger)
    return _run_mwem_host(W, h, cfg, key, index=index, ledger=ledger)
