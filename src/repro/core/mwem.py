"""MWEM (Alg. 1) and Fast-MWEM (Alg. 2) for private linear query release.

The engine is written so that *the only difference* between classic MWEM and
Fast-MWEM is the private-selection oracle — exhaustive EM vs LazyEM over a
k-MIPS index — exactly the surface the paper modifies. Everything else
(multiplicative-weights update, accounting, output averaging) is shared.

Implementation notes:
* weights live in log-space (`log_w`); the multiplicative update is additive
  and `p = softmax(log_w)` — numerically stable for tens of thousands of
  iterations.
* absolute-value scores use the complement closure (§3.4): since
  ``Σ(h − p) = 0``, ``⟨1−q, h−p⟩ = −⟨q, h−p⟩``; augmented index id ``j``
  encodes query ``j % m`` with sign ``+1`` for ``j < m`` else ``−1`` — the
  augmented matrix is never materialized for scoring.
* the update rule is selectable (`"paper"`, `"signed"`, `"hardt"`) — see
  DESIGN.md §1: Alg. 1 as printed omits the sign/measurement step; the
  default `"hardt"` is the original MWEM update. Comparisons always use the
  same rule on both sides so the EM-vs-LazyEM effect is isolated.
* the LazyEM tail buffer can overflow (prob. ≈ e^{-Ω(√m)}); the driver falls
  back to the exhaustive oracle for that iteration, preserving exactness.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.accountant import PrivacyLedger, calibrate_eps0
from repro.core.gumbel import gumbel
from repro.core.lazy_em import lazy_em_from_topk
from repro.core.queries import max_error


@dataclass(frozen=True)
class MWEMConfig:
    eps: float = 1.0
    delta: float = 1e-3
    T: int = 100
    update_rule: str = "hardt"   # "paper" | "signed" | "hardt"
    mode: str = "fast"           # "exact" | "fast"
    k: Optional[int] = None      # top-k size; default ceil(√m)
    tail_cap: Optional[int] = None
    margin_slack: float = 0.0    # c ≥ 0 → Alg. 6 privacy-preserving approx mode
    eta: Optional[float] = None  # default √(ln U / T)
    measure_frac: float = 0.5    # ε₀ fraction spent on the Laplace measurement
    eval_every: int = 0          # 0 → only final error
    n_records: Optional[int] = None  # dataset size n → sensitivity Δu = 1/n

    @staticmethod
    def iterations_for(alpha: float, m: int) -> int:
        """T = 4 α⁻² ln m (Alg. 1/2 line 3)."""
        return max(1, math.ceil(4.0 * math.log(m) / (alpha * alpha)))


def mwem_iteration_counts(alpha: float, m: int) -> int:
    return MWEMConfig.iterations_for(alpha, m)


class MWEMState(NamedTuple):
    log_w: jax.Array   # (U,) log weights
    p_sum: jax.Array   # (U,) running sum of iterates for the averaged output


@dataclass
class MWEMResult:
    p_hat: jax.Array
    final_error: float
    errors: list = field(default_factory=list)        # (t, ‖Q(p−h)‖_∞) pairs
    selected: list = field(default_factory=list)      # chosen query index per t
    n_scored: list = field(default_factory=list)      # score evaluations per t
    overflow_count: int = 0
    iter_seconds: list = field(default_factory=list)
    ledger: PrivacyLedger = field(default_factory=PrivacyLedger)


def _aug_score(Q: jax.Array, v: jax.Array, aug_idx: jax.Array) -> jax.Array:
    """Scores of augmented ids: ⟨q_{j%m}, v⟩ · sign(j<m) (== |·| at the top)."""
    m = Q.shape[0]
    base = aug_idx % m
    sign = jnp.where(aug_idx < m, 1.0, -1.0)
    return (Q[base] @ v) * sign


@partial(jax.jit, static_argnames=("rule", "eta", "scale", "lap_scale"))
def _mwu_update(state: MWEMState, q_row: jax.Array, h: jax.Array, key: jax.Array,
                rule: str, eta: float, scale: float, lap_scale: float) -> MWEMState:
    """One multiplicative-weights update given the selected query row."""
    p = jax.nn.softmax(state.log_w)
    if rule == "paper":
        log_w = state.log_w - eta * q_row
    else:
        true_ans = q_row @ h
        noise = lap_scale * jax.random.laplace(key)
        measured = true_ans + noise
        est = q_row @ p
        if rule == "signed":
            log_w = state.log_w + eta * jnp.sign(measured - est) * q_row
        elif rule == "hardt":
            log_w = state.log_w + q_row * (measured - est) / 2.0
        else:
            raise ValueError(f"unknown update rule {rule!r}")
    log_w = log_w - jnp.max(log_w)  # drift control
    p_new = jax.nn.softmax(log_w)
    return MWEMState(log_w=log_w, p_sum=state.p_sum + p_new)


@partial(jax.jit, static_argnames=("scale",))
def _exact_select(key: jax.Array, Q: jax.Array, h: jax.Array, log_w: jax.Array,
                  scale: float):
    """Exhaustive EM (Alg. 1 oracle): score all m queries, Gumbel-max."""
    p = jax.nn.softmax(log_w)
    v = h - p
    u = jnp.abs(Q @ v)
    x = u * scale
    g = gumbel(key, x.shape)
    return jnp.argmax(x + g), v


def run_mwem(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    key: jax.Array,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> MWEMResult:
    """Run (Fast-)MWEM for ``cfg.T`` iterations.

    Args:
      Q: (m, U) query matrix with entries in [0, 1].
      h: (U,) true normalized histogram.
      cfg: engine configuration. ``mode="fast"`` requires ``index``.
      key: PRNG key.
      index: a k-MIPS index over the complement-augmented queries
        (see repro.mips); must expose ``query(v, k) -> (aug_idx, raw_scores)``
        and attributes ``approx_margin`` (c ≥ 0) and ``failure_mass`` (γ).
    """
    m, U = Q.shape
    eps0 = calibrate_eps0(cfg.eps, cfg.delta, cfg.T, scheme="mwem")
    if cfg.update_rule == "paper":
        eps_em, eps_meas = eps0, 0.0
    else:
        eps_em = eps0 * (1.0 - cfg.measure_frac)
        eps_meas = eps0 * cfg.measure_frac
    # Δu = 1/n: changing one of the n records moves one histogram cell by 1/n,
    # so each |⟨q, h−p⟩| utility moves by at most 1/n (q ∈ [0,1]^U).
    if cfg.n_records is None:
        raise ValueError("MWEMConfig.n_records (dataset size n) is required")
    sensitivity = 1.0 / cfg.n_records
    scale = float(eps_em / (2.0 * sensitivity))
    lap_scale = float(sensitivity / max(eps_meas, 1e-12))
    eta = cfg.eta if cfg.eta is not None else math.sqrt(math.log(U) / cfg.T)

    k = cfg.k or max(1, math.ceil(math.sqrt(m)))
    tail_cap = cfg.tail_cap or min(2 * m, max(64, 4 * math.ceil(math.sqrt(2 * m))))

    res = MWEMResult(p_hat=None, final_error=float("nan"),
                     ledger=ledger if ledger is not None else PrivacyLedger())
    state = MWEMState(log_w=jnp.zeros((U,), jnp.float32),
                      p_sum=jnp.zeros((U,), jnp.float32))

    if cfg.mode == "fast":
        if index is None:
            raise ValueError("fast mode requires a k-MIPS index")
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))
        c_idx = float(getattr(index, "approx_margin", 0.0))

        @partial(jax.jit, static_argnames=())
        def fast_select(key, topk_idx, topk_scores, Qm, v):
            return lazy_em_from_topk(
                key, topk_idx,
                topk_scores * scale,
                2 * m,
                score_fn=lambda idx: _aug_score(Qm, v, idx) * scale,
                tail_cap=tail_cap,
                margin_slack=cfg.margin_slack * scale if cfg.margin_slack else 0.0,
            )

    for t in range(cfg.T):
        key, k_sel, k_meas = jax.random.split(key, 3)
        t0 = time.perf_counter()
        p = jax.nn.softmax(state.log_w)
        v = h - p
        if cfg.mode == "exact":
            sel, v = _exact_select(k_sel, Q, h, state.log_w, scale)
            sel = int(sel)
            res.n_scored.append(m)
            res.ledger.record(eps_em, 0.0, "em")
        else:
            aug_idx, raw = index.query(v, k)
            out = fast_select(k_sel, aug_idx, raw, Q, v)
            if bool(out.overflow):
                sel_arr, _ = _exact_select(k_sel, Q, h, state.log_w, scale)
                sel = int(sel_arr)
                res.overflow_count += 1
                res.n_scored.append(m)
            else:
                sel = int(out.index) % m
                res.n_scored.append(int(out.n_scored))
            res.ledger.record(eps_em, 0.0, "lazy_em")
            if c_idx > 0.0 and cfg.margin_slack == 0.0:
                res.ledger.record_approx_slack(c_idx)  # Thm F.2 runtime mode
        if cfg.update_rule != "paper":
            res.ledger.record(eps_meas, 0.0, "laplace")
        state = _mwu_update(state, Q[sel], h, k_meas, cfg.update_rule,
                            float(eta), scale, lap_scale)
        jax.block_until_ready(state.log_w)
        res.iter_seconds.append(time.perf_counter() - t0)
        res.selected.append(sel)
        if cfg.eval_every and (t + 1) % cfg.eval_every == 0:
            p_avg = state.p_sum / (t + 1)
            res.errors.append((t + 1, float(max_error(Q, h, p_avg))))

    p_hat = state.p_sum / cfg.T
    res.p_hat = p_hat
    res.final_error = float(max_error(Q, h, p_hat))
    return res
