"""Lazy Gumbel sampling — the paper's accelerated exponential mechanism.

Implements Algorithms 4 (perfect top-k), 5 (approximate top-k, runtime-
preserving, (ε+2c)-DP) and 6 (approximate top-k, privacy-preserving,
e^c·Θ(√n) runtime). The three are one code path parameterized by the margin
adjustment: Alg. 4 is Alg. 6 with c = 0; Alg. 5 is Alg. 6 with the margin
*not* lowered (``margin_slack=0``) while the caller accounts (ε+2c)-DP.

Fixed-shape JAX: the data-dependent binomial count ``C`` is drawn exactly,
but tail candidates live in a ``tail_cap``-sized buffer. If ``C > tail_cap``
the result carries ``overflow=True`` and the driver must fall back to the
exact mechanism for that iteration (exactness is preserved; only time is
lost — see DESIGN.md §1 faithfulness notes). E[C] ≤ n/k ≈ √n, so with
``tail_cap ≥ 4√n`` overflow is exponentially rare.

The tail indices are sampled *distinct* uniformly from ``[n] \\ S`` via the
order-statistics shift trick: with ``S`` sorted, complement index ``u`` maps
to ``u + |{j : s_j − j ≤ u}|``. Duplicate draws inside the buffer are
rejected by a sort-and-mask pass (a with-replacement draw would give some
elements two truncated Gumbels and bias the max upward by O(C²/n)).
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gumbel import gumbel, tail_prob, truncated_gumbel


def default_tail_cap(n: int) -> int:
    """4√n-sized tail buffer, clamped to [64, n] (DESIGN.md §2).

    E[C] ≤ n/k ≈ √n for the default k = ⌈√n⌉, so a 4√n buffer overflows
    with probability e^{-Ω(√n)}. Shared by the MWEM driver and the LP
    solvers so the overflow-rate analysis holds everywhere.
    """
    return min(n, max(64, 4 * math.ceil(math.sqrt(n))))


_FALLBACK_TAG = 1


def fallback_key(k_sel: jax.Array) -> jax.Array:
    """Key for the exhaustive redo after a tail-buffer overflow.

    The lazy draw consumed splits of ``k_sel``; redoing the overflowed step
    with ``k_sel`` itself would correlate the fallback Gumbels with the
    failed lazy draw's stream. Folding in a tag gives the redo its own
    stream while keeping host and fused drivers bitwise-aligned (both
    derive the same key from the same chain position). Consumed by the LP
    drivers (lp_scalar / lp_dual) on every overflow fallback.
    """
    return jax.random.fold_in(k_sel, _FALLBACK_TAG)


class LazyEMResult(NamedTuple):
    index: jax.Array        # selected candidate index in [n] (int32 scalar)
    n_scored: jax.Array     # number of score evaluations used (k + C_unique)
    tail_count: jax.Array   # the raw binomial draw C
    margin: jax.Array       # the threshold B actually used
    overflow: jax.Array     # True if C exceeded the tail buffer — caller must redo exactly


def _complement_shift(sorted_s: jax.Array, u: jax.Array) -> jax.Array:
    """Map complement-space indices ``u ∈ [0, n−k)`` to ``[n] \\ S``.

    With ``t_j = s_j − j`` (non-decreasing), the actual index is
    ``u + |{j : t_j ≤ u}|``.
    """
    t = sorted_s - jnp.arange(sorted_s.shape[0], dtype=sorted_s.dtype)
    shift = jnp.searchsorted(t, u, side="right")
    return u + shift.astype(u.dtype)


def draw_distinct_tail(
    key: jax.Array,
    topk_idx: jax.Array,
    n: int,
    tail_cap: int,
    C: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Draw ``C`` *distinct* uniform indices from ``[n] \\ S`` into a
    ``tail_cap``-sized buffer (Alg. 4 l.7, fixed-shape form).

    ``tail_cap`` i.i.d. complement-space draws are mapped around the sorted
    top-k set by `_complement_shift`; duplicates are rejected by a
    sort-and-mask pass and the first ``C`` uniques kept — by exchangeability
    a uniform C-subset. A with-replacement draw would hand some elements two
    truncated Gumbels and bias the max upward by O(C²/n), which is why every
    tail consumer (single-device LazyEM, the sharded driver) goes through
    this one helper.

    Entries of ``topk_idx`` that are ≥ n act as sentinels that exclude
    nothing below ``n`` (callers with padded/invalid top-k slots map them to
    ``n + j`` with distinct ``j`` so the shift stays monotone).

    Returns ``(tail_idx, active, overflow)``: the buffer of candidate
    indices, the mask of slots that are live (first-occurrence uniques
    within the first C), and the overflow flag (``C`` exceeded the buffer
    or the unique stream ran dry — the caller must redo the step exactly).
    """
    k = topk_idx.shape[0]
    u = jax.random.randint(key, (tail_cap,), 0, max(n - k, 1))
    sorted_s = jnp.sort(topk_idx.astype(jnp.int32))
    tail_idx = _complement_shift(sorted_s, u)
    order = jnp.argsort(u)  # stable → first occurrence keeps earliest slot
    su = u[order]
    dup_sorted = jnp.concatenate([jnp.array([False]), su[1:] == su[:-1]])
    first_occ = ~dup_sorted[jnp.argsort(order)]
    n_unique_before = jnp.cumsum(first_occ)
    active = first_occ & (n_unique_before <= C)
    overflow = (C > tail_cap) | (jnp.sum(active) < C)
    return tail_idx, active, overflow


def lazy_em_from_topk(
    key: jax.Array,
    topk_idx: jax.Array,
    topk_scores: jax.Array,
    n: int,
    score_fn: Callable[[jax.Array], jax.Array],
    tail_cap: int,
    margin_slack: float = 0.0,
) -> LazyEMResult:
    """Lazy Gumbel sampling given an (approximate) top-k set.

    Args:
      key: PRNG key.
      topk_idx: (k,) candidate indices of the (approximate) top-k set S.
      topk_scores: (k,) their EM log-space scores ``x_i = ε·u_i/(2Δ)``.
      n: total number of candidates.
      score_fn: maps an (t,) int32 index array to (t,) EM log-space scores;
        used only for the ≤ tail_cap tail candidates.
      tail_cap: tail buffer capacity (fixed shape).
      margin_slack: the approximation constant ``c``. 0 → Alg. 4/5;
        c > 0 lowers the threshold ``B ← B − c`` → Alg. 6 (ε-DP preserved
        under a c-approximate top-k, at e^c× expected tail size).

    Returns a LazyEMResult; jit-compatible (fixed shapes throughout).
    """
    k = topk_idx.shape[0]
    key_s, key_c, key_t, key_g = jax.random.split(key, 4)

    # Step 1-2 (Alg. 4 l.3-5): Gumbel-perturb S, compute the margin B.
    g_s = gumbel(key_s, (k,))
    pert_s = topk_scores + g_s
    M = jnp.max(pert_s)
    m_min = jnp.min(topk_scores)
    B = M - m_min - margin_slack

    # Step 3 (l.6): how many tail Gumbels exceed B.
    p = tail_prob(B)
    C = jax.random.binomial(key_c, n - k, p).astype(jnp.int32)

    # Step 4 (l.7): C *distinct* uniform indices from [n] \ S (see
    # `draw_distinct_tail` for the dedup/overflow contract).
    tail_idx, active, overflow = draw_distinct_tail(key_t, topk_idx, n,
                                                    tail_cap, C)

    # Step 5 (l.8): truncated Gumbels for the tail.
    g_t = truncated_gumbel(key_g, (tail_cap,), B)
    tail_scores = score_fn(tail_idx)
    pert_t = jnp.where(active, tail_scores + g_t, -jnp.inf)

    # Step 6 (l.9): argmax over S ∪ T.
    all_pert = jnp.concatenate([pert_s, pert_t])
    all_idx = jnp.concatenate([topk_idx.astype(jnp.int32), tail_idx.astype(jnp.int32)])
    winner = all_idx[jnp.argmax(all_pert)]

    n_scored = k + jnp.sum(active)
    return LazyEMResult(
        index=winner,
        n_scored=n_scored.astype(jnp.int32),
        tail_count=C,
        margin=B,
        overflow=overflow,
    )


def lazy_em(
    key: jax.Array,
    scores: jax.Array,
    k: int,
    tail_cap: int | None = None,
    margin_slack: float = 0.0,
) -> LazyEMResult:
    """Reference lazy EM over an explicit score vector (exact top-k).

    Used for statistical validation and as the pure-jnp oracle for the
    distributed / index-backed paths. ``scores`` are EM log-space scores.
    """
    n = scores.shape[0]
    if tail_cap is None:
        tail_cap = default_tail_cap(n)
    topk_scores, topk_idx = jax.lax.top_k(scores, k)
    return lazy_em_from_topk(
        key,
        topk_idx.astype(jnp.int32),
        topk_scores,
        n,
        score_fn=lambda idx: scores[idx],
        tail_cap=tail_cap,
        margin_slack=margin_slack,
    )
