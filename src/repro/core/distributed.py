"""Distributed Fast-MWEM: the sharded driver for the production mesh.

Layout (DESIGN.md §4):
  * Q (m × U):   rows over the batch axes ("pod","data"), cols over "model"
  * log-weights (U,): sharded over "model", replicated over data
  * per-data-shard IVF structure: centroids (nlist × U_loc, model-sharded
    cols) + padded cell tables (nlist × cap, local row ids) — built offline
    per shard by `repro.mips.ShardedIVFIndex`, never gathered.

Two iteration flavours share one body (`_make_iteration_body`):
  * ``exhaustive``: every shard scores all its rows; the partial inner
    products are psum-ed over "model" (m_loc floats of wire per iteration) —
    the distributed Θ(m) baseline. Per-row Gumbels are sliced out of the
    *global* (m,)-shaped draw keyed by the per-iteration selection key, so
    the sharded exhaustive mechanism is bitwise the host `_exact_argmax`
    (modulo psum float reassociation) — the host-parity anchor the
    equivalence tests lean on.
  * ``lazy`` (the paper): centroid scores (psum of nlist floats) pick
    nprobe cells; only the valid probed rows plus a Gumbel tail are scored
    and psum-ed — Θ(√m)-ish wire and FLOPs. The tail uses *binomial
    thinning*: C ~ Bin(m−k, p) splits exactly into independent per-shard
    Bin(m_loc − k_loc, p) draws, and each shard's tail reuses the
    single-device dedup machinery (`lazy_em.draw_distinct_tail`:
    complement-shift around the shard's top-k, sort-and-mask rejection of
    duplicate draws) so no element carries two truncated Gumbels. If any
    shard's tail buffer overflows, the whole iteration `lax.cond`s into the
    exhaustive per-shard scan — exactness is preserved, mirroring the fused
    driver's fallback.

Selection is reproduced exactly: every shard computes the same global
argmax from the all-gathered (id, score+Gumbel) candidates, then the
winning query row is broadcast by a one-hot psum and the multiplicative-
weights update (the same `_mwu_step` semantics as the host/fused drivers,
including the Laplace measurement) is applied to the model-sharded state.

`run_mwem_sharded` wraps the iteration in a full T-step `lax.scan` inside
one `shard_map` — a single dispatch for the whole run, per-iteration traces
returned as stacked scan outputs, and `PrivacyLedger` bookkeeping through
the same `_record_iteration` path as the other drivers.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.accountant import PrivacyLedger
from repro.core.gumbel import tail_prob, truncated_gumbel
from repro.core.lazy_em import default_tail_cap, draw_distinct_tail, fallback_key
from repro.core.mwem import (
    MWEMBatchResult,
    MWEMConfig,
    MWEMResult,
    _calibrate,
    _check_fast_index,
    _compiled_driver,
    _measure_noise,
    _record_iteration,
    release_cost,
    split_chain,
)
from repro.core.queries import max_error
from repro.kernels.mwem_step.ops import mwem_step_supported, mwu_apply
from repro.obs.clock import perf_counter
from repro.obs.telemetry import aggregate_traces, record_run
from repro.obs.trace import annotate as obs_annotate


def _fold_axes(key, axes):
    for ax in axes:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    return key


def _raw_key(key):
    key = jnp.asarray(key)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return key


@partial(jax.jit, static_argnames="T")
def _split_chain(key, T: int):
    """`mwem.split_chain` (the one shared key chain, so all three drivers
    consume identical randomness) as (T, 2)-stacked *raw* key data —
    shard_map replicates raw uint32 cleanly."""
    sel, meas = split_chain(key, T)
    return _raw_key(sel), _raw_key(meas)


def _make_iteration_body(mesh, *, m: int, U: int, nlist: int, cap: int,
                         nprobe: int, k_loc: int, tail_cap: int,
                         scale: float, eta: float, lap_scale: float,
                         rule: str, mode: str, multi_pod: bool,
                         fallback: bool = True, use_pallas: bool = False,
                         interpret: bool = True):
    """Returns ``(body, data_axes)`` where ``body`` is the per-shard
    iteration ``(Q, cents, cells, cell_rows, h, logw, p_sum, k_sel, k_meas)
    → (logw', p_sum', stats)`` run inside shard_map. All array arguments
    are the *local* shards; keys are replicated raw key data.

    ``use_pallas`` swaps the lazy probe's gather → matvec → top_k for the
    fused `kernels.ivf_probe` kernel (valid only when "model" has extent 1 —
    the kernel fuses dot+top-k, so the partial-dot psum of a model-sharded
    probe cannot interpose; `run_mwem_sharded` gates this). ``cell_rows``
    is the per-shard (nlist, cap, U_loc) cell-grouped copy of Q the kernel
    streams from, built once per dispatch by the scan wrapper (a dummy
    (1, 1, U_loc) when the XLA path runs)."""
    data_axes = ("pod", "data") if multi_pod else ("data",)
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    m_loc = m // n_data
    n_cand = k_loc + tail_cap  # fixed candidate buffer per shard

    def _global_softmax(logw):
        lmax = jax.lax.pmax(jnp.max(logw), "model")
        ex = jnp.exp(logw - lmax)
        Z = jax.lax.psum(jnp.sum(ex), "model")
        return ex / Z

    def _shard_id():
        sid = jnp.int32(0)
        for ax in data_axes:
            sid = sid * mesh.shape[ax] + jax.lax.axis_index(ax)
        return sid

    def _exhaustive_candidates(Q, v, k_sel, shard_id):
        """Score all local rows; Gumbels come from the *global* (m,) draw
        keyed by k_sel (each shard slices its segment), so the mechanism is
        bitwise the host `_exact_argmax`. Output padded to the lazy
        candidate buffer so both `lax.cond` branches agree on shapes."""
        scores = jax.lax.psum(Q @ v, "model")              # (m_loc,)
        x = jnp.abs(scores) * scale
        g_full = jax.random.gumbel(k_sel, (m,))
        g = jax.lax.dynamic_slice(g_full, (shard_id * m_loc,), (m_loc,))
        pert = x + g
        best = jnp.argmax(pert)
        cand_gids = jnp.zeros((n_cand,), jnp.int32)
        cand_gids = cand_gids.at[0].set(shard_id * m_loc + best.astype(jnp.int32))
        cand_pert = jnp.full((n_cand,), -jnp.inf, jnp.float32)
        cand_pert = cand_pert.at[0].set(pert[best])
        return cand_gids, cand_pert, jnp.float32(m_loc)

    def _lazy_candidates(Q, cents, cells, cell_rows, v, k_sel, shard_id):
        """IVF-pruned top-k plus the thinned Gumbel tail, per shard.
        Returns the candidate buffer and this shard's overflow flag."""
        k1 = _fold_axes(k_sel, data_axes)                  # per-shard stream
        kg, kc, kt, kg2 = jax.random.split(k1, 4)

        if use_pallas:
            # ---- fused kernel probe (model extent 1, no psums needed):
            # centroid top-nprobe + scalar-prefetched cell streaming, the
            # gathered candidate matrix never materialized (kernels/ivf_probe)
            from repro.kernels.ivf_probe import ivf_probe_topk

            top_ids, top_abs, n_valid = ivf_probe_topk(
                cents[0], cell_rows, cells[0], v, k_loc, nprobe,
                interpret=interpret, absolute=True)
            top_x = top_abs * scale                        # -inf pads survive
            top_valid = top_ids >= 0
            n_probe_scored = jnp.float32(nlist) + n_valid.astype(jnp.float32)
        else:
            # ---- IVF pruning: pick nprobe cells by centroid score ----
            cscores = jax.lax.psum(cents[0] @ v, "model")  # (nlist,)
            _, probe = jax.lax.top_k(jnp.abs(cscores), nprobe)
            cand = cells[0][probe].reshape(-1)             # (nprobe·cap,)
            valid = cand >= 0
            rows = Q[jnp.clip(cand, 0)]                    # (cand, U_loc)
            cscore = jax.lax.psum(rows @ v, "model")
            x_cand = jnp.where(valid, jnp.abs(cscore) * scale, -jnp.inf)
            top_x, top_pos = jax.lax.top_k(x_cand, k_loc)
            top_ids = cand[top_pos]
            top_valid = top_ids >= 0
            n_probe_scored = (jnp.float32(nlist)
                              + jnp.sum(valid).astype(jnp.float32))

        # ---- lazy Gumbel over the shard's top-k ----
        g = jax.random.gumbel(kg, (k_loc,))
        pert_top = top_x + g
        M = jnp.max(pert_top)
        # an all-padding probe gives M = min = -inf and B = NaN; force the
        # margin to +inf instead (C = 0, tail inert) so the shard simply
        # contributes no candidates rather than poisoning the binomial
        B = M - jnp.min(top_x)
        B = jnp.where(jnp.isnan(B), jnp.inf, B)
        # binomial thinning of the global tail across shards
        pt = tail_prob(B)
        C = jax.random.binomial(kc, m_loc - k_loc, pt).astype(jnp.int32)
        # distinct tail draws from [m_loc] \ top-k — the same complement-
        # shift + sort-and-mask dedup the single-device LazyEM uses (a
        # with-replacement draw would bias the max upward, lazy_em.py §).
        # Invalid top slots map to distinct ≥ m_loc sentinels: they exclude
        # nothing and keep the shift monotone; they can only occur when the
        # probe found < k_loc rows, in which case B = ∞ ⇒ C = 0 and the
        # tail is inert anyway.
        safe_top = jnp.where(top_valid, top_ids,
                             m_loc + jnp.arange(k_loc, dtype=top_ids.dtype))
        tail_ids, active, overflow = draw_distinct_tail(
            kt, safe_top, m_loc, tail_cap, C)
        tail_ids = jnp.clip(tail_ids, 0, m_loc - 1)
        trows = Q[tail_ids]
        tscore = jax.lax.psum(trows @ v, "model")
        tx = jnp.abs(tscore) * scale
        tg = truncated_gumbel(kg2, (tail_cap,), B)
        pert_tail = jnp.where(active, tx + tg, -jnp.inf)

        local_ids = jnp.concatenate([jnp.clip(top_ids, 0), tail_ids])
        cand_gids = shard_id * m_loc + local_ids.astype(jnp.int32)
        cand_pert = jnp.concatenate([pert_top, pert_tail])
        # scored work: centroid scan + *valid* probed rows (padded -1 slots
        # are masked — they cost no FLOPs) + live tail draws
        n_scored = n_probe_scored + jnp.sum(active).astype(jnp.float32)
        return cand_gids, cand_pert, n_scored, overflow

    def body(Q, cents, cells, cell_rows, h, logw, p_sum, k_sel, k_meas):
        p = _global_softmax(logw)
        v = h - p                                          # (U_loc,)
        shard_id = _shard_id()

        if mode == "exhaustive":
            cand_gids, cand_pert, n_loc = _exhaustive_candidates(
                Q, v, k_sel, shard_id)
            overflow = jnp.bool_(False)
        elif mode == "lazy":
            lazy = _lazy_candidates(Q, cents, cells, cell_rows, v, k_sel,
                                    shard_id)
            # any shard overflowing redoes the *whole* iteration
            # exhaustively (the fallback must cover every shard's rows, and
            # the predicate must be replicated for the collectives inside
            # the branches) — same exactness contract as the fused driver.
            # ``fallback=False`` drops the redo branch: for HLO wire/FLOP
            # analysis of the hot path only — the Θ(m) branch would be
            # counted at full weight by the static analyzer even though it
            # executes with probability e^{-Ω(√m)}. The driver always runs
            # with the fallback on.
            overflow = jax.lax.psum(
                lazy[3].astype(jnp.int32), data_axes) > 0
            if fallback:
                # the redo draws fresh Gumbels under `lazy_em.fallback_key`
                # (the lazy pass already consumed k_sel's) — same fold the
                # host/fused drivers apply on their overflow branch
                cand_gids, cand_pert, n_loc = jax.lax.cond(
                    overflow,
                    lambda _: _exhaustive_candidates(
                        Q, v, fallback_key(k_sel), shard_id),
                    lambda _: lazy[:3],
                    operand=None,
                )
            else:
                cand_gids, cand_pert, n_loc = lazy[:3]
        else:
            raise ValueError(f"unknown distributed mode {mode!r}")
        n_scored = jax.lax.psum(n_loc, data_axes)

        # ---- global argmax over all shards' candidates ----
        all_ids = jax.lax.all_gather(cand_gids, data_axes, tiled=True)
        all_pert = jax.lax.all_gather(cand_pert, data_axes, tiled=True)
        winner_pos = jnp.argmax(all_pert)
        winner_gid = all_ids[winner_pos]

        # ---- broadcast the winning row via one-hot psum ----
        local_row = winner_gid - shard_id * m_loc
        is_owner = (local_row >= 0) & (local_row < m_loc)
        row = jnp.where(is_owner,
                        Q[jnp.clip(local_row, 0, m_loc - 1)],
                        jnp.zeros((Q.shape[1],), Q.dtype))
        row = jax.lax.psum(row, data_axes)                 # (U_loc,)

        # ---- MW update ----
        if use_pallas and mwem_step_supported(U):
            # Megakernel seam (DESIGN.md §7): model extent is 1 whenever
            # ``use_pallas`` is live (`run_mwem_sharded` gates it), so the
            # psum/pmax collectives in the XLA tail below are identities —
            # hand the one-hot-psum'd winner row straight to the fused
            # measure→MWU→renorm kernel, the same `kernels.mwem_step` seam
            # the fused drivers run.
            noise = _measure_noise(k_meas, rule, lap_scale)
            logw_new, p_new, ps_new = mwu_apply(
                logw, p, p_sum, row, h, noise, rule=rule, eta=eta,
                interpret=interpret)
            stats = {"winner": winner_gid, "n_scored": n_scored,
                     "overflow": overflow}
            return logw_new, ps_new, stats
        # the host `_mwu_step` math on the model-sharded state
        if rule == "paper":
            logw_new = logw - eta * row
        else:
            true_ans = jax.lax.psum(jnp.dot(row, h), "model")
            noise = lap_scale * jax.random.laplace(k_meas)
            measured = true_ans + noise
            est = jax.lax.psum(jnp.dot(row, p), "model")
            if rule == "signed":
                logw_new = logw + eta * jnp.sign(measured - est) * row
            elif rule == "hardt":
                logw_new = logw + row * (measured - est) / 2.0
            else:
                raise ValueError(f"unknown update rule {rule!r}")
        logw_new = logw_new - jax.lax.pmax(jnp.max(logw_new), "model")
        p_new = _global_softmax(logw_new)
        stats = {"winner": winner_gid, "n_scored": n_scored,
                 "overflow": overflow}
        return logw_new, p_sum + p_new, stats

    return body, data_axes


_STAT_SPECS = {"winner": P(), "n_scored": P(), "overflow": P()}


def _cell_grouped_rows(Q, cells, use_pallas: bool):
    """Per-shard (nlist, cap⌈8⌉, U_loc) cell-grouped copy of the local Q
    rows — the contiguous HBM blocks the fused probe kernel streams, cap
    pre-padded to the sublane multiple so the kernel wrapper's pad is a
    no-op inside the scan body. Gathered once per dispatch (amortized over
    the T-iteration scan); a (1, 8, U_loc) dummy when the XLA probe runs."""
    if not use_pallas:
        return jnp.zeros((1, 8, Q.shape[1]), Q.dtype)
    local = cells[0]
    rows = Q[jnp.clip(local, 0)] * (local >= 0)[..., None].astype(Q.dtype)
    pad = (-rows.shape[1]) % 8
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0)))
    return rows


def make_mwem_iteration(mesh, *, m: int, U: int, nlist: int, cap: int,
                        nprobe: int, k_loc: int, tail_cap: int,
                        scale: float, eta: float, mode: str,
                        multi_pod: bool, rule: str = "hardt",
                        lap_scale: float = 0.0, fallback: bool = True,
                        use_pallas: bool = False, interpret: bool = True):
    """One shard-mapped iteration ``(Q, cents, cells, logw, h, key) →
    (logw', stats)`` — the scan body of `run_mwem_sharded` exposed on its
    own for HLO/roofline analysis (dry-run cells) and per-iteration tests.
    All arrays are the *global* logical views; shard_map splits them.
    ``fallback=False`` lowers the lazy hot path without the overflow-redo
    branch (static analyzers weigh the rare branch at 1×).
    """
    body, data_axes = _make_iteration_body(
        mesh, m=m, U=U, nlist=nlist, cap=cap, nprobe=nprobe, k_loc=k_loc,
        tail_cap=tail_cap, scale=scale, eta=eta, lap_scale=lap_scale,
        rule=rule, mode=mode, multi_pod=multi_pod, fallback=fallback,
        use_pallas=use_pallas, interpret=interpret)

    q_spec = P(data_axes, "model")
    cent_spec = P(data_axes, None, "model")   # (shards, nlist, U_loc)
    cell_spec = P(data_axes, None, None)      # (shards, nlist, cap)
    w_spec = P("model")

    def iteration(Q, cents, cells, logw, h, key):
        _, k_sel, k_meas = jax.random.split(key, 3)
        cell_rows = _cell_grouped_rows(Q, cells, use_pallas)
        logw_new, _, stats = body(Q, cents, cells, cell_rows, h, logw,
                                  jnp.zeros_like(logw),
                                  _raw_key(k_sel), _raw_key(k_meas))
        return logw_new, stats

    return shard_map(
        iteration, mesh=mesh,
        in_specs=(q_spec, cent_spec, cell_spec, w_spec, w_spec, P()),
        out_specs=(w_spec, _STAT_SPECS),
        check_rep=False,
    )


def make_mwem_scan(mesh, *, T: int, m: int, U: int, nlist: int, cap: int,
                   nprobe: int, k_loc: int, tail_cap: int, scale: float,
                   eta: float, lap_scale: float, rule: str, mode: str,
                   multi_pod: bool, eval_every: int = 0,
                   fallback: bool = True, use_pallas: bool = False,
                   interpret: bool = True):
    """The full T-iteration sharded driver: one shard_map around one
    `lax.scan` — a single dispatch per run, traces as stacked scan outputs.

    Signature of the returned function (global logical views):
      ``(Q, cents, cells, h, logw0, p_sum0, sel_keys, meas_keys)
        → (logw_T, p_sum_T, traces)``
    with ``sel_keys``/``meas_keys`` the (T, 2) pre-split raw key chain
    (`_split_chain`) and traces a dict of (T,)-stacked per-iteration
    ``winner`` / ``n_scored`` / ``overflow`` (plus ``error`` when
    ``eval_every`` is set, NaN off-schedule like the fused driver).
    ``fallback=False`` drops the overflow-redo branch — analysis lowers
    only; the driver always runs with the fallback on.
    """
    body, data_axes = _make_iteration_body(
        mesh, m=m, U=U, nlist=nlist, cap=cap, nprobe=nprobe, k_loc=k_loc,
        tail_cap=tail_cap, scale=scale, eta=eta, lap_scale=lap_scale,
        rule=rule, mode=mode, multi_pod=multi_pod, fallback=fallback,
        use_pallas=use_pallas, interpret=interpret)

    q_spec = P(data_axes, "model")
    cent_spec = P(data_axes, None, "model")
    cell_spec = P(data_axes, None, None)
    w_spec = P("model")

    def scan_fn(Q, cents, cells, h, logw0, p_sum0, sel_keys, meas_keys):
        # one cell-grouped gather per dispatch, amortized over the T scan
        # iterations (kernel route only)
        cell_rows = _cell_grouped_rows(Q, cells, use_pallas)

        def step(carry, xs):
            logw, p_sum = carry
            t, k_sel, k_meas = xs
            logw2, p_sum2, stats = body(Q, cents, cells, cell_rows, h,
                                        logw, p_sum, k_sel, k_meas)
            if eval_every:
                # gated: the Θ(m_loc · U_loc) error matmul only runs on the
                # eval schedule, mirroring the fused driver
                def _err(_):
                    v_err = h - p_sum2 / t.astype(jnp.float32)
                    s = jax.lax.psum(Q @ v_err, "model")
                    return jax.lax.pmax(jnp.max(jnp.abs(s)), data_axes)

                stats = dict(stats, error=jax.lax.cond(
                    t % eval_every == 0, _err,
                    lambda _: jnp.float32(jnp.nan), operand=None))
            return (logw2, p_sum2), stats

        ts = jnp.arange(1, T + 1)
        (logw, p_sum), traces = jax.lax.scan(
            step, (logw0, p_sum0), (ts, sel_keys, meas_keys))
        return logw, p_sum, traces

    stat_specs = dict(_STAT_SPECS)
    if eval_every:
        stat_specs["error"] = P()
    return shard_map(
        scan_fn, mesh=mesh,
        in_specs=(q_spec, cent_spec, cell_spec, w_spec, w_spec, w_spec,
                  P(), P()),
        out_specs=(w_spec, w_spec, stat_specs),
        check_rep=False,
    )


_SCAN_CACHE: dict = {}


def _jitted_scan(mesh, statics: dict):
    """(jitted fn, AOT-executable cache) per (mesh, statics) — the same
    entry shape `_compiled_driver` consumes, so trace+compile stay out of
    the timed region exactly like the fused driver."""
    ck = (mesh, tuple(sorted(statics.items())))
    entry = _SCAN_CACHE.get(ck)
    if entry is None:
        entry = (jax.jit(make_mwem_scan(mesh, **statics)), {})
        _SCAN_CACHE[ck] = entry
    return entry


def _data_shards(mesh) -> tuple[tuple, int]:
    multi_pod = "pod" in mesh.axis_names
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return data_axes, math.prod(mesh.shape[a] for a in data_axes)


def shard_selection_params(m_loc: int, index, k: Optional[int] = None,
                           tail_cap: Optional[int] = None) -> tuple[int, int]:
    """Per-shard top-k size and tail buffer capacity — the driver's own
    derivation (cfg overrides, √m_loc defaults, probe-width/buffer clamps),
    exposed so benchmarks and analysis cells lower exactly the program
    `run_mwem_sharded` executes."""
    k_loc = min(m_loc, index.nprobe * index.cap,
                k or max(1, math.ceil(math.sqrt(m_loc))))
    return k_loc, max(1, min(m_loc, tail_cap or default_tail_cap(m_loc)))


def run_mwem_sharded(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    key: jax.Array,
    mesh=None,
    index=None,
    ledger: Optional[PrivacyLedger] = None,
) -> MWEMResult:
    """Run (Fast-)MWEM on a device mesh as one shard-mapped scan dispatch.

    Args:
      mesh: a ("data", "model") (optionally + "pod") mesh; defaults to
        `repro.launch.mesh.make_driver_mesh()` over all visible devices.
        ``m`` must divide over the data axes and ``U`` over "model".
      index: a `repro.mips.ShardedIVFIndex` whose shard count matches the
        mesh's data extent (``mode="fast"``). ``None`` builds one on the
        fly (per-shard k-means — the sharded build path; reuse the index
        across runs to amortize it).

    Selections and ledger totals reproduce the host driver: ``mode="exact"``
    is bitwise host-parity (global-sliced Gumbels, same key chain), and
    privacy events flow through the same `_record_iteration`/`_calibrate`
    path, so sharded runs compose to identical (ε, δ).

    Workload note: this driver shards explicit rows over the data axes, so
    factored workloads take the documented densify-fallback —
    `Workload.require_dense` materializes the (m, U) table or raises past
    the densify limit (auto-routing never sends such workloads here).
    """
    from repro.core.workload import as_workload
    from repro.launch.mesh import make_driver_mesh
    from repro.mips.ivf import ShardedIVFIndex

    Q = as_workload(Q).require_dense("run_mwem_sharded")
    m, U = Q.shape
    if mesh is None:
        mesh = make_driver_mesh()
    data_axes, n_data = _data_shards(mesh)
    n_model = mesh.shape["model"]
    if m % n_data:
        raise ValueError(f"m={m} must divide over {n_data} data shards")
    if U % n_model:
        raise ValueError(f"U={U} must divide over {n_model} model shards")
    m_loc = m // n_data

    if cfg.mode == "fast" and index is None:
        index = ShardedIVFIndex(Q, n_shards=n_data)
    cal = _calibrate(cfg, m, U)
    c_idx = _check_fast_index(cfg, index, fused=False)

    use_pallas = False
    if cfg.mode == "fast":
        if not getattr(index, "supports_sharded", False):
            raise ValueError(
                f"{type(index).__name__} has no per-shard structure "
                "(supports_sharded=False); pass a ShardedIVFIndex or None")
        if index.n_shards != n_data:
            raise ValueError(f"index built for {index.n_shards} shards, "
                             f"mesh has {n_data}")
        cents, cells = index.cents, index.cells
        nlist, cap, nprobe = index.nlist, index.cap, index.nprobe
        k_loc, tail_cap = shard_selection_params(m_loc, index,
                                                 k=cfg.k,
                                                 tail_cap=cfg.tail_cap)
        # the fused probe kernel replaces the gather→matvec→top_k only when
        # "model" has extent 1 (it fuses dot+top-k, so the partial-dot psum
        # of a model-sharded probe cannot interpose) — automatic fallback
        # to the XLA probe otherwise
        try:
            use_pallas = index._resolve_pallas() and n_model == 1
        except AttributeError:
            use_pallas = False
    else:
        # dummy per-shard structure: the exhaustive body never reads it
        cents = jnp.zeros((n_data, 1, U), jnp.float32)
        cells = jnp.full((n_data, 1, 1), -1, jnp.int32)
        nlist, cap, nprobe, k_loc, tail_cap = 1, 1, 1, 1, 1

    statics = dict(T=cfg.T, m=m, U=U, nlist=nlist, cap=cap, nprobe=nprobe,
                   k_loc=k_loc, tail_cap=tail_cap, scale=cal.scale,
                   eta=cal.eta, lap_scale=cal.lap_scale,
                   rule=cfg.update_rule,
                   mode="exhaustive" if cfg.mode == "exact" else "lazy",
                   multi_pod="pod" in mesh.axis_names,
                   eval_every=cfg.eval_every,
                   use_pallas=use_pallas,
                   interpret=jax.default_backend() != "tpu")
    entry = _jitted_scan(mesh, statics)

    # device_put is a no-op for arrays already placed with the target
    # sharding, so repeat runs (and batch lanes) re-transfer nothing;
    # writing the placed index structure back makes that stick for the
    # index too.
    ns = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731
    Qd = jax.device_put(jnp.asarray(Q, jnp.float32), ns(data_axes, "model"))
    cents_d = jax.device_put(jnp.asarray(cents, jnp.float32),
                             ns(data_axes, None, "model"))
    cells_d = jax.device_put(jnp.asarray(cells, jnp.int32),
                             ns(data_axes, None, None))
    if cfg.mode == "fast":
        index.cents, index.cells = cents_d, cells_d
    h_d = jax.device_put(jnp.asarray(h, jnp.float32), ns("model"))
    logw0 = jax.device_put(jnp.zeros((U,), jnp.float32), ns("model"))
    p_sum0 = jax.device_put(jnp.zeros((U,), jnp.float32), ns("model"))
    sel_keys, meas_keys = _split_chain(jnp.asarray(key), cfg.T)
    sel_keys = jax.device_put(sel_keys, ns())
    meas_keys = jax.device_put(meas_keys, ns())

    res = MWEMResult(p_hat=None, final_error=float("nan"),
                     ledger=ledger if ledger is not None else PrivacyLedger())
    if cfg.mode == "fast":
        res.ledger.record_index_failure(getattr(index, "failure_mass", 1.0 / m))

    args = (Qd, cents_d, cells_d, h_d, logw0, p_sum0, sel_keys, meas_keys)
    driver = _compiled_driver(entry, *args)
    t0 = perf_counter()
    with obs_annotate("mwem/sharded"):
        logw, p_sum, traces = driver(*args)
        jax.block_until_ready(p_sum)
    total = perf_counter() - t0

    traces = jax.device_get(traces)
    res.selected = [int(w) for w in traces["winner"]]
    res.n_scored = [int(s) for s in traces["n_scored"]]
    res.overflow_count = int(np.sum(traces["overflow"]))
    res.iter_seconds = [total / cfg.T] * cfg.T
    for _ in range(cfg.T):
        _record_iteration(res.ledger, cfg.mode, cfg.update_rule, cal,
                          c_idx, cfg.margin_slack)
    if cfg.eval_every:
        errs = traces["error"]
        res.errors = [(t, float(errs[t - 1]))
                      for t in range(cfg.eval_every, cfg.T + 1,
                                     cfg.eval_every)]
    res.p_hat = jnp.asarray(jax.device_get(p_sum)) / cfg.T
    res.final_error = float(max_error(jnp.asarray(Q, jnp.float32),
                                      jnp.asarray(h, jnp.float32),
                                      res.p_hat))
    res.telemetry = record_run(
        workload="mwem", driver="sharded", mode=cfg.mode, m=m,
        n_scored=res.n_scored, overflow_count=res.overflow_count,
        total_seconds=total, amortized=True)
    return res


def run_mwem_sharded_batch(
    Q: jax.Array,
    h: jax.Array,
    cfg: MWEMConfig,
    keys: jax.Array,
    mesh=None,
    index=None,
    ledgers: Optional[list] = None,
) -> MWEMBatchResult:
    """B releases through the sharded driver — the mesh counterpart of
    `run_mwem_batch` for the release service's waves.

    Lanes run sequentially, each as one mesh-wide scan dispatch (vmapping a
    shard_map would replicate the whole mesh program per lane); the
    compiled executable is shared across lanes, and per-lane ``ledgers``
    charge each tenant exactly as `run_mwem_batch` does. The result's
    per-run ledger carries one lane's event bundle (the B× composition is
    the caller's contract, DESIGN.md §2).
    """
    from repro.core.workload import as_workload
    from repro.mips.ivf import ShardedIVFIndex

    Q = as_workload(Q).require_dense("run_mwem_sharded_batch")
    m, U = Q.shape
    keys = jnp.asarray(keys)
    B = keys.shape[0]
    if ledgers is not None and len(ledgers) != B:
        raise ValueError(f"ledgers must have one entry per lane "
                         f"({len(ledgers)} != {B})")
    h = jnp.asarray(h, jnp.float32)
    batched_h = h.ndim == 2
    if mesh is None:
        from repro.launch.mesh import make_driver_mesh
        mesh = make_driver_mesh()
    if cfg.mode == "fast" and index is None:
        index = ShardedIVFIndex(Q, n_shards=_data_shards(mesh)[1])
    # place Q on the mesh once — the per-lane device_put then no-ops
    data_axes = _data_shards(mesh)[0]
    Q = jax.device_put(jnp.asarray(Q, jnp.float32),
                       NamedSharding(mesh, P(data_axes, "model")))

    results = []
    t0 = perf_counter()
    for b in range(B):
        lane_ledger = ledgers[b] if ledgers is not None else None
        if ledgers is not None and lane_ledger is None:
            lane_ledger = PrivacyLedger()  # pad lane: charged nowhere
        results.append(run_mwem_sharded(
            Q, h[b] if batched_h else h, cfg, keys[b], mesh=mesh,
            index=index, ledger=lane_ledger))
    total = perf_counter() - t0

    per_run = PrivacyLedger()
    per_run.record_events(*release_cost(cfg, m, U, index=index))
    errors = None
    if cfg.eval_every:
        errors = np.asarray([[e for _, e in r.errors] for r in results])
    # aggregate only (no publish): each lane's run_mwem_sharded already
    # published its own record — re-publishing here would double-count
    telemetry = aggregate_traces(
        workload="mwem", driver="sharded", mode=cfg.mode, m=m,
        n_scored=np.asarray([r.n_scored for r in results]),
        overflow_count=int(sum(r.overflow_count for r in results)),
        total_seconds=total, amortized=True, lanes=B)
    return MWEMBatchResult(
        p_hat=jnp.stack([r.p_hat for r in results]),
        final_errors=np.asarray([r.final_error for r in results]),
        selected=np.asarray([r.selected for r in results]),
        n_scored=np.asarray([r.n_scored for r in results]),
        overflow_counts=np.asarray([r.overflow_count for r in results]),
        errors=errors,
        eval_every=cfg.eval_every,
        total_seconds=total,
        ledger=per_run,
        ledgers=list(ledgers) if ledgers is not None else None,
        telemetry=telemetry,
    )


def build_distributed_mwem_cell(mesh, multi_pod: bool, *, mode: str = "lazy",
                                m: int = 2 ** 24, U: int = 2 ** 14,
                                T: int = 1, fallback: bool = False):
    """Dry-run cell: allocation-free specs for the sharded driver.

    Built on the *real* driver — the cell's fn is `make_mwem_scan` with the
    same body `run_mwem_sharded` executes, so the lowered specs cannot
    drift from what production runs (T=1 keeps the recorded per-device
    numbers per-iteration comparable). The one analysis-only deviation:
    ``fallback`` defaults to False here, dropping the e^{-Ω(√m)}-rare
    overflow-redo branch that a static HLO analyzer would weigh at 1× —
    with it on, the recorded "lazy" FLOPs/wire would be dominated by the
    Θ(m) branch and the exhaustive-vs-lazy §Perf comparison this cell
    exists for would be meaningless. Pass ``fallback=True`` to lower
    exactly what production dispatches."""
    data_axes = ("pod", "data") if multi_pod else ("data",)
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    m_loc = m // n_data
    nlist = 2 * int(math.sqrt(m_loc))
    cap = max(8, math.ceil(2.0 * m_loc / nlist))
    nprobe = 10
    k_loc = max(32, int(math.sqrt(m_loc)))
    tail_cap = 4 * int(math.sqrt(m_loc))
    scale = 50.0
    eta = 0.05

    fn = make_mwem_scan(
        mesh, T=T, m=m, U=U, nlist=nlist, cap=cap, nprobe=nprobe,
        k_loc=k_loc, tail_cap=tail_cap, scale=scale, eta=eta,
        lap_scale=0.01, rule="hardt", mode=mode, multi_pod=multi_pod,
        fallback=fallback)

    ns = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731
    Q = jax.ShapeDtypeStruct((m, U), jnp.float32,
                             sharding=ns(data_axes, "model"))
    cents = jax.ShapeDtypeStruct((n_data, nlist, U), jnp.float32,
                                 sharding=ns(data_axes, None, "model"))
    cells = jax.ShapeDtypeStruct((n_data, nlist, cap), jnp.int32,
                                 sharding=ns(data_axes, None, None))
    logw = jax.ShapeDtypeStruct((U,), jnp.float32, sharding=ns("model"))
    h = jax.ShapeDtypeStruct((U,), jnp.float32, sharding=ns("model"))
    keys = jax.ShapeDtypeStruct((T, 2), jnp.uint32, sharding=ns())

    meta = {"arch": "fastmwem-dist", "shape": f"m{m}_U{U}_{mode}",
            "kind": "mwem_iteration", "mode": mode, "m": m, "U": U,
            "m_loc": m_loc, "nlist": nlist, "cap": cap, "nprobe": nprobe,
            "k_loc": k_loc, "tail_cap": tail_cap, "T": T,
            "fallback": fallback,
            "tokens_per_step": 0, "n_params": m * U, "n_active_params": m * U,
            "multi_pod": multi_pod}
    return fn, (Q, cents, cells, h, logw, logw, keys, keys), meta
