"""Distributed Fast-MWEM: one MWEM iteration on the production mesh.

Layout (DESIGN.md §4):
  * Q (m × U):   rows over the batch axes ("pod","data"), cols over "model"
  * log-weights (U,): sharded over "model", replicated over data
  * per-data-shard IVF structure: centroids (nlist_loc × U_loc, model-sharded
    cols) + padded cell tables (nlist_loc × cap, local row ids)

Two iteration flavours, same interface:
  * ``exhaustive``: every shard scores all its rows; the partial inner
    products are psum-ed over "model" (m_loc floats of wire per iteration) —
    the distributed Θ(m) baseline.
  * ``lazy`` (the paper): centroid scores (psum of nlist_loc floats) pick
    nprobe cells; only nprobe·cap + tail rows are scored and psum-ed —
    Θ(√m)-ish wire and FLOPs. The Gumbel tail uses *binomial thinning*:
    C ~ Bin(m−k, p) splits exactly into independent per-shard
    Bin(m_loc, p) draws, so no coordination is needed beyond the final
    all-gather of (k + C) candidates.

Selection is reproduced exactly: every shard computes the same global
argmax from the all-gathered (id, score+Gumbel) candidates, then the
winning query row is broadcast by a one-hot psum and applied to the
model-sharded MWU state.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.gumbel import tail_prob, truncated_gumbel


def _fold_axes(key, axes):
    for ax in axes:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    return key


def make_mwem_iteration(mesh, *, m: int, U: int, nlist: int, cap: int,
                        nprobe: int, k_loc: int, tail_cap: int,
                        scale: float, eta: float, mode: str,
                        multi_pod: bool):
    """Returns a jittable ``(Q, cents, cells, logw, h, key) → (logw', stats)``.

    All arrays are the *global* logical views; shard_map splits them.
    """
    data_axes = ("pod", "data") if multi_pod else ("data",)
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    m_loc = m // n_data

    q_spec = P(data_axes, "model")
    cent_spec = P(data_axes, None, "model")   # (shards, nlist, U_loc)
    cell_spec = P(data_axes, None, None)      # (shards, nlist, cap)
    w_spec = P("model")
    rep = P()

    def iteration(Q, cents, cells, logw, h, key):
        # ---- p = softmax(logw) over the model-sharded domain ----
        lmax = jax.lax.pmax(jnp.max(logw), "model")
        ex = jnp.exp(logw - lmax)
        Z = jax.lax.psum(jnp.sum(ex), "model")
        p = ex / Z
        v = h - p                                      # (U_loc,)

        key = _fold_axes(key, data_axes)
        k1, k2, k3 = jax.random.split(key, 3)

        if mode == "exhaustive":
            scores = jax.lax.psum(Q @ v, "model")      # (m_loc,) full scores
            x = jnp.abs(scores) * scale
            g = jax.random.gumbel(k1, x.shape)
            pert = x + g
            best = jnp.argmax(pert)
            cand_ids = best[None]
            cand_pert = pert[best][None]
            cand_x = x[best][None]
            n_scored = jnp.float32(m_loc)
        else:
            # ---- IVF pruning: pick nprobe cells by centroid score ----
            cscores = jax.lax.psum(cents[0] @ v, "model")     # (nlist,)
            _, probe = jax.lax.top_k(jnp.abs(cscores), nprobe)
            cand = cells[0][probe].reshape(-1)                # (nprobe·cap,)
            valid = cand >= 0
            rows = Q[jnp.clip(cand, 0)]                       # (cand, U_loc)
            cscore = jax.lax.psum(rows @ v, "model")
            x_cand = jnp.where(valid, jnp.abs(cscore) * scale, -jnp.inf)
            top_x, top_pos = jax.lax.top_k(x_cand, k_loc)
            top_ids = cand[top_pos]

            # ---- lazy Gumbel over the shard's top-k ----
            g = jax.random.gumbel(k1, (k_loc,))
            pert_top = top_x + g
            M = jnp.max(pert_top)
            mmin = jnp.min(top_x)
            B = M - mmin
            # binomial thinning of the global tail across shards
            pt = tail_prob(B)
            C = jax.random.binomial(k2, m_loc - k_loc, pt).astype(jnp.int32)
            c_eff = jnp.minimum(C, tail_cap)
            tail_ids = jax.random.randint(k3, (tail_cap,), 0, m_loc)
            trows = Q[tail_ids]
            tscore = jax.lax.psum(trows @ v, "model")
            tx = jnp.abs(tscore) * scale
            tg = truncated_gumbel(jax.random.fold_in(k3, 7), (tail_cap,), B)
            active = jnp.arange(tail_cap) < c_eff
            pert_tail = jnp.where(active, tx + tg, -jnp.inf)

            cand_ids = jnp.concatenate([top_ids, tail_ids])
            cand_pert = jnp.concatenate([pert_top, pert_tail])
            cand_x = jnp.concatenate([top_x, tx])
            n_scored = (jnp.float32(nprobe * cap + nlist)
                        + jnp.sum(active).astype(jnp.float32))

        # ---- global argmax over all shards' candidates ----
        shard_id = jnp.int32(0)
        for ax in data_axes:
            shard_id = shard_id * mesh.shape[ax] + jax.lax.axis_index(ax)
        gids = shard_id * m_loc + cand_ids.astype(jnp.int32)
        all_ids = jax.lax.all_gather(gids, data_axes, tiled=True)
        all_pert = jax.lax.all_gather(cand_pert, data_axes, tiled=True)
        winner_pos = jnp.argmax(all_pert)
        winner_gid = all_ids[winner_pos]

        # ---- broadcast the winning row via one-hot psum ----
        local_row = winner_gid - shard_id * m_loc
        is_owner = (local_row >= 0) & (local_row < m_loc)
        row = jnp.where(is_owner,
                        Q[jnp.clip(local_row, 0, m_loc - 1)],
                        jnp.zeros((Q.shape[1],), Q.dtype))
        row = jax.lax.psum(row, data_axes)                    # (U_loc,)

        # ---- MWU update (signed rule: w *= exp(η·sign(⟨q,v⟩)·q)) ----
        score_full = jax.lax.psum(jnp.dot(row, v), "model")
        sgn = jnp.sign(score_full)
        logw_new = logw + eta * sgn * row
        logw_new = logw_new - jax.lax.pmax(jnp.max(logw_new), "model")
        stats = {"winner": winner_gid, "n_scored": n_scored,
                 "margin_used": jnp.float32(0.0)}
        return logw_new, stats

    shard_fn = shard_map(
        iteration, mesh=mesh,
        in_specs=(q_spec, cent_spec, cell_spec, w_spec, w_spec, rep),
        out_specs=(w_spec, {"winner": rep, "n_scored": rep,
                            "margin_used": rep}),
        check_rep=False,
    )
    return shard_fn


def build_distributed_mwem_cell(mesh, multi_pod: bool, *, mode: str = "lazy",
                                m: int = 2 ** 24, U: int = 2 ** 14):
    """Dry-run cell: allocation-free specs for one distributed iteration."""
    data_axes = ("pod", "data") if multi_pod else ("data",)
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    m_loc = m // n_data
    nlist = 2 * int(math.sqrt(m_loc))
    cap = max(8, math.ceil(2.0 * m_loc / nlist))
    nprobe = 10
    k_loc = max(32, int(math.sqrt(m_loc)))
    tail_cap = 4 * int(math.sqrt(m_loc))
    scale = 50.0
    eta = 0.05

    fn = make_mwem_iteration(
        mesh, m=m, U=U, nlist=nlist, cap=cap, nprobe=nprobe, k_loc=k_loc,
        tail_cap=tail_cap, scale=scale, eta=eta, mode=mode,
        multi_pod=multi_pod)

    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    Q = jax.ShapeDtypeStruct((m, U), jnp.float32,
                             sharding=ns(data_axes, "model"))
    cents = jax.ShapeDtypeStruct((n_data, nlist, U), jnp.float32,
                                 sharding=ns(data_axes, None, "model"))
    cells = jax.ShapeDtypeStruct((n_data, nlist, cap), jnp.int32,
                                 sharding=ns(data_axes, None, None))
    logw = jax.ShapeDtypeStruct((U,), jnp.float32, sharding=ns("model"))
    h = jax.ShapeDtypeStruct((U,), jnp.float32, sharding=ns("model"))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=ns())

    meta = {"arch": "fastmwem-dist", "shape": f"m{m}_U{U}_{mode}",
            "kind": "mwem_iteration", "mode": mode, "m": m, "U": U,
            "m_loc": m_loc, "nlist": nlist, "cap": cap, "nprobe": nprobe,
            "k_loc": k_loc, "tail_cap": tail_cap,
            "tokens_per_step": 0, "n_params": m * U, "n_active_params": m * U,
            "multi_pod": multi_pod}
    return fn, (Q, cents, cells, logw, h, key), meta
