"""Compiled-HLO cost parsing + TPU v5e roofline model."""

from repro.analysis.hlo import analyze_hlo, HLOAnalysis
from repro.analysis.roofline import roofline_terms, V5E

__all__ = ["analyze_hlo", "HLOAnalysis", "roofline_terms", "V5E"]
