"""Compiled-HLO cost parsing + TPU v5e roofline model."""

from repro.analysis.hlo import analyze_hlo, HLOAnalysis
from repro.analysis.roofline import (ivf_probe_roofline, mwem_step_roofline,
                                     roofline_terms, V5E)

__all__ = ["analyze_hlo", "HLOAnalysis", "ivf_probe_roofline",
           "mwem_step_roofline", "roofline_terms", "V5E"]
