"""HLO text analysis with while-loop trip-count awareness.

XLA's ``compiled.cost_analysis()`` counts a while-loop body **once**
(verified empirically — see DESIGN.md §6), which makes it useless for
scan-over-layers programs. This parser rebuilds the cost model from the
optimized HLO text:

  1. split the module into computations and their op lines;
  2. extract while-loop trip counts from the loop-condition compare
     constants;
  3. propagate execution multipliers through the call graph
     (body/condition/calls/to_apply/branches);
  4. count dot/convolution FLOPs, fusion-boundary HBM traffic, and
     collective wire bytes (ring-algorithm factors × replica-group size)
     per computation, scaled by its multiplier.

Validated against XLA's own cost analysis on unrolled (loop-free) modules
in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
# `%name = <type> opcode(...)` — the type may be a tuple; the opcode is the
# first `word(` token (tuple-opening parens are preceded by whitespace).
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that don't touch HBM as fusion boundaries
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dtype, dims = m.groups()
    dims = tuple(int(d) for d in dims.split(",") if d) if dims else ()
    return dtype, dims


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # operand list + attributes (raw)


@dataclass
class HLOAnalysis:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = field(default_factory=dict)
    while_trip_counts: dict = field(default_factory=dict)
    n_collectives: int = 0
    num_partitions: int = 1
    flops_by_multiplier: dict = field(default_factory=dict)


def _parse_computations(text: str):
    comps: dict[str, list[Op]] = {}
    entry = None
    current = None
    for line in text.splitlines():
        stripped = _COMMENT_RE.sub("", line).strip()
        if not stripped:
            continue
        # computation headers end with "{", contain "->", and are not ops
        if stripped.endswith("{") and "->" in stripped and " = " not in stripped:
            m = _COMP_NAME_RE.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                if stripped.startswith("ENTRY"):
                    entry = current
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        om = _OP_RE.match(stripped)
        if om:
            name, type_str, opcode, rest = om.groups()
            comps[current].append(Op(name, type_str.strip(), opcode, rest))
    return comps, entry


def _callees(op: Op):
    """(attr, computation) references made by this op."""
    out = []
    for attr in ("body", "condition", "to_apply", "calls"):
        m = re.search(attr + r"=%?([\w.\-]+)", op.rest)
        if m:
            out.append((attr, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        for c in m.group(1).split(","):
            out.append(("branch", c.strip().lstrip("%")))
    return out


def _trip_count(cond_ops: list[Op]) -> int:
    """Trip count from the condition computation: the compare constant."""
    consts = {}
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.match(r"\(?(-?\d+)\)?", op.rest)
            if m and op.type_str.strip().startswith(("s32", "s64", "u32", "u64")):
                consts[op.name] = int(m.group(1))
    best = 0
    for op in cond_ops:
        if op.opcode == "compare":
            for operand in re.findall(r"%([\w.\-]+)", op.rest):
                if operand in consts:
                    best = max(best, consts[operand])
    return max(best, 1)


def _group_size(op: Op, num_partitions: int) -> int:
    """Participant count per replica group."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", op.rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,\s]*)\}", op.rest)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    m = re.search(r"source_target_pairs=", op.rest)
    if m:
        return 2  # permute: pairwise
    return num_partitions


def _operand_names(op: Op):
    """Operand %names appearing before the first attribute comma group."""
    # operands are inside the leading parenthesized list before '), attr=...'
    depth = 0
    end = len(op.rest)
    for i, ch in enumerate(op.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    head = op.rest[:end]
    return re.findall(r"%([\w.\-]+)", head)


def _dot_flops(op: Op, shapes: dict) -> float:
    _, result_dims = _shape_dims(op.type_str)
    operands = _operand_names(op)
    if not operands:
        return 0.0
    lhs_shape = shapes.get(operands[0])
    if lhs_shape is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,\s]*)\}", op.rest)
    contract = 1
    if m:
        for d in m.group(1).split(","):
            if d.strip():
                idx = int(d)
                if idx < len(lhs_shape):
                    contract *= lhs_shape[idx]
    n_out = 1
    for d in result_dims:
        n_out *= d
    return 2.0 * n_out * contract


def _op_map(ops):
    return {o.name: o for o in ops}


def _op_traffic(op: Op, ops, shapes, comps) -> float:
    """HBM traffic estimate for one op (fusion-boundary model).

    Slicing ops read only the slice, not the sliced operand;
    dynamic-update-slice writes in place (≈ 2× the update bytes); a fusion
    whose parameters are consumed only by slicing ops inside the fusion body
    reads slices, not full parameters.
    """
    out_b = _shape_bytes(op.type_str)
    om = _op_map(ops)
    operands = _operand_names(op)

    if op.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b  # read the slice + write it
    if op.opcode == "dynamic-update-slice":
        upd = om.get(operands[1]) if len(operands) > 1 else None
        upd_b = _shape_bytes(upd.type_str) if upd else out_b
        return 2.0 * upd_b

    if op.opcode == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", op.rest)
        body = comps.get(m.group(1)) if m else None
        if body is not None:
            body_map = _op_map(body)
            params = {}
            for bop in body:
                if bop.opcode == "parameter":
                    idx = int(re.match(r"\(?(\d+)\)?", bop.rest).group(1))
                    params[bop.name] = idx
            # per-parameter: sliced-only consumption → slice bytes
            in_b = 0.0
            consumed = {name: [] for name in params}
            for bop in body:
                for nm in _operand_names(bop):
                    if nm in consumed:
                        consumed[nm].append(bop)
            for pname, users in consumed.items():
                idx = params[pname]
                full = (_shape_bytes(om[operands[idx]].type_str)
                        if idx < len(operands) and operands[idx] in om
                        else _shape_bytes(body_map[pname].type_str))
                if users and all(u.opcode in ("dynamic-slice", "slice", "gather")
                                 for u in users):
                    in_b += sum(_shape_bytes(u.type_str) for u in users)
                elif users and all(
                        u.opcode == "dynamic-update-slice"
                        and _operand_names(u)[:1] == [pname]
                        for u in users):
                    in_b += 0.0  # in-place updated buffer: aliased, not read
                else:
                    in_b += full
            root = body[-1] if body else None
            root_dus = [b for b in body if b.opcode == "dynamic-update-slice"]
            if root_dus and root is not None and \
                    root.opcode in ("dynamic-update-slice", "bitcast", "tuple"):
                out_b = sum(2.0 * _shape_bytes(
                    body_map[_operand_names(d)[1]].type_str)
                    for d in root_dus
                    if len(_operand_names(d)) > 1
                    and _operand_names(d)[1] in body_map)
            return in_b + out_b

    in_b = 0.0
    for nm in operands:
        src = om.get(nm)
        if src is not None:
            in_b += _shape_bytes(src.type_str)
    return in_b + out_b


def analyze_hlo(text: str) -> HLOAnalysis:
    res = HLOAnalysis()
    m = re.search(r"num_partitions=(\d+)", text)
    res.num_partitions = int(m.group(1)) if m else 1

    comps, entry = _parse_computations(text)
    if entry is None:
        return res

    # per-computation operand shape tables
    shape_tables = {}
    for cname, ops in comps.items():
        shape_tables[cname] = {op.name: _shape_dims(op.type_str)[1] for op in ops}

    # Two multiplier maps over the call graph:
    #  * flop_mult — every edge (body/cond × trip, calls/to_apply × 1):
    #    dots inside fusion bodies execute and must be counted.
    #  * exec_mult — control-flow edges only (ENTRY, while body/cond,
    #    branches): HBM traffic happens at *schedule level*; ops inside
    #    fusion/reduce bodies live in registers and are free.
    flop_mult: dict[str, float] = {entry: 1.0}
    exec_mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop(0)
        fmult = flop_mult.get(cname, 0.0)
        emult = exec_mult.get(cname, 0.0)
        for op in comps.get(cname, []):
            callees = _callees(op)
            trip = 1.0
            if op.opcode == "while":
                m = _TRIP_RE.search(op.rest)
                if m:  # XLA annotates known trip counts in backend_config
                    trip = float(m.group(1))
                else:  # fall back to the loop-condition compare constant
                    cond_name = dict(callees).get("condition")
                    if cond_name in comps:
                        trip = float(_trip_count(comps[cond_name]))
                res.while_trip_counts[op.name] = int(trip)
            for attr, callee in callees:
                if callee not in comps:
                    continue
                control = attr in ("body", "condition", "branch")
                scale = trip if attr in ("body", "condition") else 1.0
                flop_mult[callee] = flop_mult.get(callee, 0.0) + fmult * scale
                if control:
                    exec_mult[callee] = exec_mult.get(callee, 0.0) \
                        + emult * scale
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # cost accumulation
    for cname, ops in comps.items():
        fmult = flop_mult.get(cname, 0.0)
        emult = exec_mult.get(cname, 0.0)
        if fmult <= 0 and emult <= 0:
            continue
        shapes = shape_tables[cname]
        for op in ops:
            if op.opcode in ("dot", "convolution") and fmult > 0:
                f = _dot_flops(op, shapes)
                res.flops += fmult * f
                key = int(fmult)
                res.flops_by_multiplier[key] = \
                    res.flops_by_multiplier.get(key, 0) + f
            if emult > 0 and op.opcode not in _FREE_OPS \
                    and op.opcode != "while":
                res.bytes_hbm += emult * _op_traffic(op, ops, shapes, comps)
            if emult > 0:
                for coll in COLLECTIVES:
                    if op.opcode == coll or op.opcode == coll + "-start":
                        g = _group_size(op, res.num_partitions)
                        out_b = _shape_bytes(op.type_str)
                        if coll == "all-reduce":
                            wire = 2.0 * (g - 1) / g * out_b
                        elif coll == "all-gather":
                            wire = (g - 1) / g * out_b
                        elif coll == "reduce-scatter":
                            wire = (g - 1) * out_b
                        elif coll == "all-to-all":
                            wire = (g - 1) / g * out_b
                        else:  # collective-permute
                            wire = out_b
                        res.collective_bytes += emult * wire
                        res.n_collectives += 1
                        res.collective_breakdown[coll] = \
                            res.collective_breakdown.get(coll, 0.0) \
                            + emult * wire
                        break
    return res
