"""TPU v5e roofline model (per DESIGN.md §6 / assignment constants).

    compute term    = FLOPs_per_device / peak_FLOPs
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

All terms in seconds; the max is the step-time lower bound and the largest
term is the bottleneck the §Perf loop attacks.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    peak_flops: float    # FLOP/s (bf16)
    hbm_bw: float        # bytes/s
    ici_bw: float        # bytes/s per link
    hbm_bytes: float     # capacity


V5E = Chip(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9, hbm_bytes=16e9)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   wire_bytes_per_dev: float, chip: Chip = V5E) -> dict:
    t_compute = flops_per_dev / chip.peak_flops
    t_memory = hbm_bytes_per_dev / chip.hbm_bw
    t_coll = wire_bytes_per_dev / chip.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    terms.update({
        "bottleneck": bottleneck.replace("_s", ""),
        "step_lower_bound_s": bound,
        # fraction of peak compute achievable at this op mix
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
    })
    return terms


def model_flops(n_params: int, n_tokens: int, active_params: int | None = None,
                kind: str = "train") -> float:
    """6·N·D (training) or 2·N·D (inference fwd) with MoE active-param N."""
    n = active_params if active_params is not None else n_params
    per_tok = 6.0 * n if kind == "train" else 2.0 * n
    return per_tok * n_tokens


def ivf_probe_roofline(*, nlist: int, nprobe: int, cap: int, dim: int,
                       batch: int = 1, unique_cells: int | None = None,
                       dtype_bytes: int = 4, kernelized: bool = True,
                       chip: Chip = V5E) -> dict:
    """Roofline of one IVF probe (wave) — the per-iteration kNN hot path.

    The kernelized probe (`repro.kernels.ivf_probe`) touches exactly
    ``nlist·dim`` centroid bytes plus each streamed cell's ``cap·dim`` rows
    once (HBM→VMEM, double-buffered; the gathered candidate matrix never
    exists in HBM). A batched wave streams the ``unique_cells`` of the
    lanes' union (default: no overlap, ``batch·nprobe``; the masked
    duplicate tail revisits the resident block). The XLA lowering instead
    materializes the per-lane (nprobe·cap, dim) gather: rows cross the HBM
    bus ~3× (gather read + gather write + matvec read), per lane.

    FLOPs are the routes' real op counts, not the useful per-lane work:
    the batched kernel scores *every* streamed tile against the whole wave
    (lanes that did not probe the cell are masked after the matmul), so
    its compute term carries the full B× — the dedup shares HBM reads, not
    MXU work, and the trade only pays while the probe stays
    bandwidth-bound.

    Returns the `roofline_terms` dict extended with ``hbm_bytes`` /
    ``flops`` / ``rows_scored`` (valid per-lane candidates, the useful
    work) so benches can report bytes-touched directly.
    """
    if unique_cells is None:
        unique_cells = batch * nprobe
    unique_cells = min(unique_cells, nlist, batch * nprobe)
    row_bytes = cap * dim * dtype_bytes
    id_bytes = cap * 4
    rows_scored = batch * nprobe * cap
    if kernelized:
        hbm = (nlist * dim * dtype_bytes            # centroids, streamed once
               + unique_cells * (row_bytes + id_bytes))
        # every grid slot (B·nprobe of them) matmuls against all B lanes
        flops = 2.0 * dim * batch * (nlist + batch * nprobe * cap)
    else:
        hbm = (nlist * dim * dtype_bytes
               + batch * nprobe * (3 * row_bytes + id_bytes))
        flops = 2.0 * dim * (batch * nlist + rows_scored)
    out = roofline_terms(flops, float(hbm), 0.0, chip)
    out.update({"hbm_bytes": float(hbm), "flops": float(flops),
                "rows_scored": rows_scored, "unique_cells": unique_cells,
                "kernelized": kernelized})
    return out


def mwem_step_roofline(*, m: int, U: int, nlist: int | None = None,
                       nprobe: int | None = None, cap: int | None = None,
                       tail_cap: int | None = None, dtype_bytes: int = 4,
                       megakernel: bool = True, chip: Chip = V5E) -> dict:
    """Roofline of one fast-mode MWEM iteration (single lane, IVF probe).

    Models the per-iteration HBM traffic of the fused scan body in
    U-vector *passes* (each pass = ``U · dtype_bytes`` across the bus),
    honest per sub-op — the quantity the megakernel attacks (DESIGN.md §7).

    ``megakernel=False`` — the classic body (``use_pallas="never"``), every
    sub-op its own HBM round-trip:

    * ``p = softmax(log_w)``: 3 reads (max pass, sum pass, exp/Z pass) +
      1 write = 4 passes.
    * ``v = h − p``: 3 passes.
    * XLA probe: centroids once, then the gathered (nprobe·cap, U)
      candidate matrix crosses the bus ~3× (gather read + materialize +
      matvec read).
    * XLA tail scoring: same gather shape over ``tail_cap`` rows, 3×.
    * MWU tail: winner-row gather ~4 row passes (gather R/W, dot read,
      update read) + 14 state passes (measure/estimate reads, log-weight
      update, max-shift, renormalizing softmax, output accumulation).

    ``megakernel=True`` — the `kernels.mwem_step` route: the probe rows
    stream once (`kernels.ivf_probe`), the tail candidates stream once
    (scalar-prefetched gather-score), the whole measure→MWU→renorm tail is
    one VMEM-resident pass (5 reads: log_w, p, p_sum, h, prefetched winner
    row; 3 writes), and the carried density deletes the per-step softmax
    entirely. Only ``v = h − p`` (3 passes) stays in XLA.

    Index defaults mirror `mips.IVFIndex` over the complement-augmented
    n = 2m rows and `lazy_em.default_tail_cap`. Returns the
    `roofline_terms` dict extended with ``hbm_bytes`` / ``flops`` /
    ``state_passes``; call once per route and compare ``hbm_bytes`` for
    the before/after ratio (CI gates on mega ≤ classic).
    """
    n_aug = 2 * m
    if nlist is None:
        nlist = min(max(int(2 * math.sqrt(n_aug)), 20), n_aug)
    if nprobe is None:
        nprobe = max(1, min(nlist // 4, 10))
    if cap is None:
        cap = max(4, math.ceil(2.0 * n_aug / nlist))
    if tail_cap is None:
        tail_cap = min(n_aug, max(64, 4 * math.ceil(math.sqrt(n_aug))))
    probe_rows = nprobe * cap
    if megakernel:
        state_passes = 3 + 8                  # v = h − p, fused step kernel
        row_passes = probe_rows + tail_cap    # each candidate streams once
        id_bytes = (probe_rows + tail_cap) * 4
    else:
        state_passes = 4 + 3 + 14 + 4         # softmax, v, MWU tail, winner
        row_passes = 3 * (probe_rows + tail_cap)
        id_bytes = 2 * (probe_rows + tail_cap) * 4
    hbm = (state_passes + nlist + row_passes) * U * dtype_bytes + id_bytes
    # useful op counts, route-independent: candidate + tail + centroid dots
    # and the ~10 elementwise/reduction passes of the MWU tail
    flops = 2.0 * U * (nlist + probe_rows + tail_cap) + 10.0 * U
    out = roofline_terms(flops, float(hbm), 0.0, chip)
    out.update({"hbm_bytes": float(hbm), "flops": float(flops),
                "state_passes": state_passes, "nlist": nlist,
                "nprobe": nprobe, "cap": cap, "tail_cap": tail_cap,
                "megakernel": megakernel})
    return out
