"""TPU v5e roofline model (per DESIGN.md §6 / assignment constants).

    compute term    = FLOPs_per_device / peak_FLOPs
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

All terms in seconds; the max is the step-time lower bound and the largest
term is the bottleneck the §Perf loop attacks.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    peak_flops: float    # FLOP/s (bf16)
    hbm_bw: float        # bytes/s
    ici_bw: float        # bytes/s per link
    hbm_bytes: float     # capacity


V5E = Chip(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9, hbm_bytes=16e9)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   wire_bytes_per_dev: float, chip: Chip = V5E) -> dict:
    t_compute = flops_per_dev / chip.peak_flops
    t_memory = hbm_bytes_per_dev / chip.hbm_bw
    t_coll = wire_bytes_per_dev / chip.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    terms.update({
        "bottleneck": bottleneck.replace("_s", ""),
        "step_lower_bound_s": bound,
        # fraction of peak compute achievable at this op mix
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
    })
    return terms


def model_flops(n_params: int, n_tokens: int, active_params: int | None = None,
                kind: str = "train") -> float:
    """6·N·D (training) or 2·N·D (inference fwd) with MoE active-param N."""
    n = active_params if active_params is not None else n_params
    per_tok = 6.0 * n if kind == "train" else 2.0 * n
    return per_tok * n_tokens
