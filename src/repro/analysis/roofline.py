"""TPU v5e roofline model (per DESIGN.md §6 / assignment constants).

    compute term    = FLOPs_per_device / peak_FLOPs
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

All terms in seconds; the max is the step-time lower bound and the largest
term is the bottleneck the §Perf loop attacks.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    peak_flops: float    # FLOP/s (bf16)
    hbm_bw: float        # bytes/s
    ici_bw: float        # bytes/s per link
    hbm_bytes: float     # capacity


V5E = Chip(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9, hbm_bytes=16e9)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   wire_bytes_per_dev: float, chip: Chip = V5E) -> dict:
    t_compute = flops_per_dev / chip.peak_flops
    t_memory = hbm_bytes_per_dev / chip.hbm_bw
    t_coll = wire_bytes_per_dev / chip.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    terms.update({
        "bottleneck": bottleneck.replace("_s", ""),
        "step_lower_bound_s": bound,
        # fraction of peak compute achievable at this op mix
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
    })
    return terms


def model_flops(n_params: int, n_tokens: int, active_params: int | None = None,
                kind: str = "train") -> float:
    """6·N·D (training) or 2·N·D (inference fwd) with MoE active-param N."""
    n = active_params if active_params is not None else n_params
    per_tok = 6.0 * n if kind == "train" else 2.0 * n
    return per_tok * n_tokens


def ivf_probe_roofline(*, nlist: int, nprobe: int, cap: int, dim: int,
                       batch: int = 1, unique_cells: int | None = None,
                       dtype_bytes: int = 4, kernelized: bool = True,
                       chip: Chip = V5E) -> dict:
    """Roofline of one IVF probe (wave) — the per-iteration kNN hot path.

    The kernelized probe (`repro.kernels.ivf_probe`) touches exactly
    ``nlist·dim`` centroid bytes plus each streamed cell's ``cap·dim`` rows
    once (HBM→VMEM, double-buffered; the gathered candidate matrix never
    exists in HBM). A batched wave streams the ``unique_cells`` of the
    lanes' union (default: no overlap, ``batch·nprobe``; the masked
    duplicate tail revisits the resident block). The XLA lowering instead
    materializes the per-lane (nprobe·cap, dim) gather: rows cross the HBM
    bus ~3× (gather read + gather write + matvec read), per lane.

    FLOPs are the routes' real op counts, not the useful per-lane work:
    the batched kernel scores *every* streamed tile against the whole wave
    (lanes that did not probe the cell are masked after the matmul), so
    its compute term carries the full B× — the dedup shares HBM reads, not
    MXU work, and the trade only pays while the probe stays
    bandwidth-bound.

    Returns the `roofline_terms` dict extended with ``hbm_bytes`` /
    ``flops`` / ``rows_scored`` (valid per-lane candidates, the useful
    work) so benches can report bytes-touched directly.
    """
    if unique_cells is None:
        unique_cells = batch * nprobe
    unique_cells = min(unique_cells, nlist, batch * nprobe)
    row_bytes = cap * dim * dtype_bytes
    id_bytes = cap * 4
    rows_scored = batch * nprobe * cap
    if kernelized:
        hbm = (nlist * dim * dtype_bytes            # centroids, streamed once
               + unique_cells * (row_bytes + id_bytes))
        # every grid slot (B·nprobe of them) matmuls against all B lanes
        flops = 2.0 * dim * batch * (nlist + batch * nprobe * cap)
    else:
        hbm = (nlist * dim * dtype_bytes
               + batch * nprobe * (3 * row_bytes + id_bytes))
        flops = 2.0 * dim * (batch * nlist + rows_scored)
    out = roofline_terms(flops, float(hbm), 0.0, chip)
    out.update({"hbm_bytes": float(hbm), "flops": float(flops),
                "rows_scored": rows_scored, "unique_cells": unique_cells,
                "kernelized": kernelized})
    return out
