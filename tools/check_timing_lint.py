#!/usr/bin/env python
"""Lint: all timing in src/, benchmarks/ and examples/ must go through
repro.obs.clock.

Raw ``time.time()`` stamps break event ordering under wall-clock (NTP)
skew, and scattered ``perf_counter`` imports make it impossible to fake
or audit timing from one place. `repro/obs/clock.py` is the single
sanctioned seam — everything else must import from it. Benchmarks and
examples are held to the same rule: the fault-injection harness drives
latency through `clock.sleep`, so a bench that times through a side
channel would silently miss injected delays.

Rejected in ``{src,benchmarks,examples}/**/*.py`` outside
``src/repro/obs/``:

* ``import time`` / ``from time import ...``
* ``time.time(`` / ``time.perf_counter(`` / ``time.monotonic(`` /
  ``time.sleep(`` / ``time.strftime(``

Exit 0 when clean; exit 1 printing ``path:line: offending text``.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_ROOTS = [ROOT / "src", ROOT / "benchmarks", ROOT / "examples"]
EXEMPT = ROOT / "src" / "repro" / "obs"

PATTERNS = [
    re.compile(r"^\s*import\s+time\b"),
    re.compile(r"^\s*from\s+time\s+import\b"),
    re.compile(r"\btime\.time\("),
    re.compile(r"\btime\.perf_counter\("),
    re.compile(r"\btime\.monotonic\("),
    re.compile(r"\btime\.sleep\("),
    re.compile(r"\btime\.strftime\("),
]


def check(path: Path) -> list[tuple[int, str]]:
    hits = []
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        stripped = line.split("#", 1)[0]
        for pat in PATTERNS:
            if pat.search(stripped):
                hits.append((lineno, line.strip()))
                break
    return hits


def main() -> int:
    bad = 0
    for root in SCAN_ROOTS:
        if not root.is_dir():
            continue
        for path in sorted(root.rglob("*.py")):
            if EXEMPT in path.parents:
                continue
            for lineno, text in check(path):
                print(f"{path.relative_to(ROOT)}:{lineno}: {text}")
                bad += 1
    if bad:
        print(f"timing lint: {bad} raw `time` use(s) — "
              "route them through repro.obs.clock", file=sys.stderr)
        return 1
    print("timing lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
