"""Factored-vs-dense marginal workloads (DESIGN.md §9).

Two claims, measured:

* **bytes** — a `MarginalWorkload` carries O(m + n_cliques·kmax) int32s
  where the dense (m, U) table carries 4·m·U bytes; the rows report both
  and their ratio at matched shapes, ending at a *dense-infeasible* shape
  (15 binary attributes) where the dense table would cross the 2 GiB
  densify limit and the factored run must complete inside a hard memory
  budget (asserted, not just printed — CI's bench-smoke lane runs this).
* **runtime** — per-iteration Fast-MWEM time, dense `FlatAbsIndex` vs the
  factored flat probe vs the clique-structured `MarginalIVFIndex`, on the
  same fused driver.
"""

from __future__ import annotations

from repro.obs import clock
import tracemalloc

import jax
import numpy as np

from benchmarks.common import med_us, row
from repro.core import MWEMConfig, run_mwem
from repro.core.workload import MarginalWorkload, _DENSIFY_LIMIT_BYTES
from repro.mips import FlatAbsIndex, MarginalIVFIndex


def _workload_nbytes(W: MarginalWorkload) -> int:
    return int(sum(np.asarray(leaf).nbytes
                   for leaf in jax.tree_util.tree_leaves(W)))


def _iter_us(W_or_Q, h, index, T: int, reps: int) -> float:
    cfg = MWEMConfig(eps=1.0, delta=1e-3, T=T, mode="fast", n_records=10_000,
                     k=32, use_pallas="never")
    times = []
    for r in range(reps):
        t0 = clock.perf_counter()
        res = run_mwem(W_or_Q, h, cfg, jax.random.PRNGKey(r), index=index)
        jax.block_until_ready(res.p_hat)
        times.append((clock.perf_counter() - t0) / T)
    return med_us(times, skip=1)


def run(quick: bool = True):
    rows = []
    T = 8 if quick else 30
    reps = 3 if quick else 6

    # -- matched-shape runtime + bytes: dense table vs factored ----------
    n_attr = 8 if quick else 10
    W = MarginalWorkload.all_kway((2,) * n_attr, 3)
    key = jax.random.PRNGKey(0)
    h = jax.nn.softmax(jax.random.normal(key, (W.U,)) * 2.0)
    Qd = W.densify()
    dense_b = int(Qd.size * 4)
    fact_b = _workload_nbytes(W)
    rows.append(row(f"marginals/bytes_m{W.m}_U{W.U}", 0.0,
                    {"dense_bytes": dense_b, "factored_bytes": fact_b,
                     "ratio": round(dense_b / fact_b, 1)}))

    dense_us = _iter_us(Qd, h, FlatAbsIndex(Qd, use_pallas="never"), T, reps)
    rows.append(row("marginals/dense_flat", dense_us,
                    {"m": W.m, "U": W.U}))
    fact_us = _iter_us(W, h, FlatAbsIndex(W, use_pallas="never"), T, reps)
    rows.append(row("marginals/factored_flat", fact_us,
                    {"m": W.m, "U": W.U,
                     "vs_dense": round(fact_us / dense_us, 2)}))
    mivf_us = _iter_us(W, h, MarginalIVFIndex(W), T, reps)
    rows.append(row("marginals/factored_marginal_ivf", mivf_us,
                    {"m": W.m, "U": W.U,
                     "vs_dense": round(mivf_us / dense_us, 2)}))

    # -- dense-infeasible shape: 15 binary attrs, all 4-way cliques ------
    # capped to keep quick mode fast, but always past the densify limit
    Wb = MarginalWorkload.all_kway((2,) * 15, 4,
                                   max_cliques=1100 if quick else None)
    assert Wb.dense_nbytes > _DENSIFY_LIMIT_BYTES, Wb.dense_nbytes
    hb = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1),
                                          (Wb.U,)) * 2.0)
    # memory-budget assert: the factored release must stay far below the
    # dense table it replaces — host-side allocations under 1/4 of it
    budget = _DENSIFY_LIMIT_BYTES // 4
    tracemalloc.start()
    big_us = _iter_us(Wb, hb, MarginalIVFIndex(Wb), 3 if quick else T, 2)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    if peak > budget:
        raise MemoryError(
            f"factored run peaked at {peak} host bytes > budget {budget} "
            f"(dense table would be {Wb.dense_nbytes})")
    rows.append(row("marginals/dense_infeasible", big_us,
                    {"m": Wb.m, "U": Wb.U,
                     "dense_bytes_avoided": Wb.dense_nbytes,
                     "factored_bytes": _workload_nbytes(Wb),
                     "host_peak_bytes": int(peak),
                     "budget_bytes": int(budget)}))
    return rows
