"""Fig. 7 (§I.2): final error vs dataset size n.

More samples → lower sensitivity-driven noise → lower error; MWEM and
Fast-MWEM behave identically across n.
"""

from __future__ import annotations

import jax

from benchmarks.common import med_us, row
from repro.core import MWEMConfig, run_mwem
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.mips import FlatAbsIndex


def run(quick: bool = True):
    U, m = 128, 100
    ns = [100, 400, 1600] if quick else [100, 400, 1600, 6400]
    T = 150 if quick else 400
    rows = []
    kq = jax.random.PRNGKey(7)
    Q = random_binary_queries(kq, m, U)
    for n in ns:
        h = gaussian_histogram(jax.random.PRNGKey(n), n, U)
        exact = run_mwem(Q, h, MWEMConfig(T=T, mode="exact", n_records=n),
                         jax.random.PRNGKey(1))
        fast = run_mwem(Q, h, MWEMConfig(T=T, mode="fast", n_records=n),
                        jax.random.PRNGKey(1), index=FlatAbsIndex(Q))
        rows.append(row(f"n_ablation/n{n}", med_us(fast.iter_seconds),
                        f"exact_err={exact.final_error:.4f}"
                        f";fast_err={fast.final_error:.4f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
