"""§Roofline report: aggregate the dry-run JSONs into the per-cell table.

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits both
CSV rows for benchmarks.run and a markdown table (results/roofline.md) that
EXPERIMENTS.md references.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_records(mesh: str = "pod16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = True):
    rows = []
    recs = load_records()
    if not recs:
        rows.append(row("roofline/NO_DRYRUN_RESULTS", 0.0,
                        "run repro.launch.dryrun --all first"))
        return rows
    for r in recs:
        rf = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}"
        rows.append(row(
            name, rf["step_lower_bound_s"] * 1e6,
            f"bottleneck={rf['bottleneck']}"
            f";compute_s={rf['compute_s']:.4g}"
            f";memory_s={rf['memory_s']:.4g}"
            f";collective_s={rf['collective_s']:.4g}"
            f";roofline_frac={rf['roofline_fraction']:.3f}"
            f";useful_flops={r.get('useful_flop_fraction', 0):.3f}"
            f";fits={r['memory']['fits'] if 'fits' in r.get('memory', {}) else '-'}"))
    write_markdown(recs)
    return rows


def _note(r) -> str:
    """One sentence: what would move the dominant term down."""
    rf = r["roofline"]
    b = rf["bottleneck"]
    kind = r.get("kind", "")
    arch = r.get("arch", "")
    if arch.startswith("fastmwem"):
        return ("tighten the IVF probe width (nprobe·cap) toward √m_loc — "
                "recall-vs-wire tradeoff" if "lazy" in r.get("shape", "")
                else "replace the Θ(m) score psum with the LazyEM path "
                     "(the paper's contribution — see the lazy twin row)")
    if b == "memory":
        if kind == "decode":
            return ("KV/state-cache streaming floor — quantize the cache "
                    "(int8/int4 KV) or grow batch to amortize reads")
        if kind == "prefill":
            return ("O(S²) f32 logit traffic of the XLA attention path — "
                    "the Pallas flash kernel keeps tiles in VMEM on TPU")
        return ("f32 attention/SSD intermediates at CPU-HLO fusion "
                "granularity — flash/ssd Pallas kernels + bf16 partials "
                "on TPU")
    if b == "collective":
        return ("TP activation psums + FSDP weight gathers — overlap with "
                "compute (latency-hiding scheduler), bf16 psums, or int8 "
                "EF compression on the pod axis")
    return ("MXU-bound — increase per-device batch or improve the op mix "
            "(fused kernels)")


def write_markdown(recs, out="results/roofline.md"):
    os.makedirs(os.path.dirname(out), exist_ok=True)
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| roofline frac | useful FLOP frac | fits | what moves the "
        "dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rf = r["roofline"]
        fits = r.get("memory", {}).get("fits", "-")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4g} "
            f"| {rf['memory_s']:.4g} | {rf['collective_s']:.4g} "
            f"| {rf['bottleneck']} | {rf['roofline_fraction']:.3f} "
            f"| {r.get('useful_flop_fraction', 0):.3f} | {fits} "
            f"| {_note(r)} |")
    lines.append("")
    lines.append(
        "Memory terms reflect CPU-lowered fusion boundaries (conservative "
        "for TPU); `MODEL_FLOPS/HLO_FLOPs` = 6·N·D (or 2·N·D inference) "
        "over trip-count-corrected HLO dot FLOPs.")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
