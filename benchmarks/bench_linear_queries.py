"""Fig. 1 / Fig. 4: Fast-MWEM per-iteration runtime and speedup vs m.

Sweeps the query-set size with each index (flat exhaustive baseline vs
IVF / LSH / NSW) and reports median per-iteration time plus the observed
speedup factor over the flat scan.

The flat path is measured under both drivers (DESIGN.md §2):
``flat_host`` is the seed per-dispatch Python loop, ``flat`` is the fused
`lax.scan` driver — their ratio (``fused_speedup``) isolates the dispatch
overhead the fused driver removes. All other per-index speedups are
reported relative to the fused flat scan so they measure selection work,
not dispatch latency.

The IVF probe is measured under both routes (DESIGN.md §3): ``ivf`` pins
``use_pallas="never"`` (the XLA gather probe), ``ivf_pallas`` lets
``use_pallas="auto"`` resolve — the fused `kernels.ivf_probe` stream on
TPU, the same XLA probe off-TPU (recorded either way; the derived column
carries the resolved path and the ratio against the pinned-XLA row).

Every kind except ``megakernel`` pins ``cfg.use_pallas="never"`` — the
classic pre-fusion scan body is the baseline these rows have always
measured. ``megakernel`` reruns the IVF workload with
``cfg.use_pallas="auto"`` (the DESIGN.md §7 carried-density step) and
reports the iteration-time ratio against the classic ``ivf`` row plus the
modeled HBM-bytes ratio from `analysis.roofline.mwem_step_roofline` — the
bandwidth headroom the fusion buys on TPU.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import med_us, row
from repro.analysis.roofline import mwem_step_roofline
from repro.core import MWEMConfig, run_mwem
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.mips import FlatAbsIndex, IVFIndex, LSHIndex, NSWIndex, augment_complement


def run(quick: bool = True):
    U = 256 if quick else 512
    ms = [2048, 8192, 32768] if quick else [4096, 16384, 65536, 131072]
    T = 12 if quick else 30
    n = 500
    rows = []
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    h = gaussian_histogram(kh, n, U)

    for m in ms:
        Q = random_binary_queries(kq, m, U)
        Qnp = np.asarray(Q)
        aug = augment_complement(Qnp)
        flat_us = None
        ivf_us = None
        for kind in ("flat_host", "flat", "ivf", "ivf_pallas", "megakernel",
                     "lsh", "nsw"):
            if kind in ("flat_host", "flat"):
                index = FlatAbsIndex(Q)
            elif kind == "ivf":
                index = IVFIndex(aug, seed=0, train_iters=4,
                                 use_pallas="never")
            elif kind in ("ivf_pallas", "megakernel"):
                # identical structure (the numpy k-means build is
                # seed-deterministic), kernel-routed probe
                index = IVFIndex(aug, seed=0, train_iters=4,
                                 use_pallas="auto")
            elif kind == "lsh":
                index = LSHIndex(aug, n_tables=8, seed=0)
            else:
                index = NSWIndex(aug, deg=16, ef=48,
                                 rounds=3 if quick else 5, seed=0)
            cfg = MWEMConfig(T=T, mode="fast", n_records=n,
                             driver="host" if kind == "flat_host" else "auto",
                             use_pallas="auto" if kind == "megakernel"
                             else "never")
            # First run traces + compiles (the fused driver amortizes that
            # into every iter_seconds entry); measure the second, which
            # re-dispatches the cached executable.
            run_mwem(Q, h, cfg, jax.random.PRNGKey(1), index=index)
            res = run_mwem(Q, h, cfg, jax.random.PRNGKey(1), index=index)
            if kind == "flat_host":
                host_us = med_us(res.iter_seconds)
                rows.append(row(f"linear_queries/m{m}/flat_host", host_us,
                                f"err={res.final_error:.4f}"
                                f";scored={int(np.mean(res.n_scored))}"))
                continue
            us = med_us(res.iter_seconds)
            if kind == "flat":
                flat_us = us
                derived = (f"fused_speedup={host_us / us:.2f}x"
                           f";err={res.final_error:.4f}"
                           f";scored={int(np.mean(res.n_scored))}")
            else:
                speedup = flat_us / us if us > 0 else float("nan")
                derived = (f"speedup={speedup:.2f}x"
                           f";err={res.final_error:.4f}"
                           f";scored={int(np.mean(res.n_scored))}")
            if kind == "ivf":
                ivf_us = us
            elif kind == "ivf_pallas":
                path = "pallas" if index._resolve_pallas() else "xla_ref"
                derived += (f";path={path}"
                            f";vs_ivf_xla={ivf_us / us:.2f}x")
            elif kind == "megakernel":
                mega = mwem_step_roofline(m=m, U=U, megakernel=True)
                classic = mwem_step_roofline(m=m, U=U, megakernel=False)
                path = ("kernel" if index._resolve_pallas() else "mega_ref")
                derived += (f";path={path}"
                            f";vs_classic_ivf={ivf_us / us:.2f}x"
                            f";hbm_bytes_ratio="
                            f"{classic['hbm_bytes'] / mega['hbm_bytes']:.2f}x")
            rows.append(row(f"linear_queries/m{m}/{kind}", us, derived))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
