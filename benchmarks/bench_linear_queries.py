"""Fig. 1 / Fig. 4: Fast-MWEM per-iteration runtime and speedup vs m.

Sweeps the query-set size with each index (flat exhaustive baseline vs
IVF / LSH / NSW) and reports median per-iteration time plus the observed
speedup factor over the flat scan.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import med_us, row
from repro.core import MWEMConfig, run_mwem
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.mips import FlatAbsIndex, IVFIndex, LSHIndex, NSWIndex, augment_complement


def run(quick: bool = True):
    U = 256 if quick else 512
    ms = [2048, 8192, 32768] if quick else [4096, 16384, 65536, 131072]
    T = 12 if quick else 30
    n = 500
    rows = []
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    h = gaussian_histogram(kh, n, U)

    for m in ms:
        Q = random_binary_queries(kq, m, U)
        Qnp = np.asarray(Q)
        aug = augment_complement(Qnp)
        flat_us = None
        for kind in ("flat", "ivf", "lsh", "nsw"):
            if kind == "flat":
                index = FlatAbsIndex(Q)
            elif kind == "ivf":
                index = IVFIndex(aug, seed=0, train_iters=4)
            elif kind == "lsh":
                index = LSHIndex(aug, n_tables=8, seed=0)
            else:
                index = NSWIndex(aug, deg=16, ef=48,
                                 rounds=3 if quick else 5, seed=0)
            cfg = MWEMConfig(T=T, mode="fast", n_records=n)
            res = run_mwem(Q, h, cfg, jax.random.PRNGKey(1), index=index)
            us = med_us(res.iter_seconds)
            if kind == "flat":
                flat_us = us
            speedup = flat_us / us if us > 0 else float("nan")
            rows.append(row(f"linear_queries/m{m}/{kind}", us,
                            f"speedup={speedup:.2f}x"
                            f";err={res.final_error:.4f}"
                            f";scored={int(np.mean(res.n_scored))}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
