"""Benchmark harness — one module per paper table/figure.

  bench_linear_queries — Fig. 1/4: per-iteration runtime + speedup vs m
  bench_error_parity   — Fig. 2/3: MWEM vs Fast-MWEM error (flat/ivf/nsw)
  bench_lp             — Fig. 5/8/9: scalar-private LP violations + runtime
  bench_margin         — Fig. 6 (§I.1): tail count C vs m
  bench_n_ablation     — Fig. 7 (§I.2): error vs dataset size n
  roofline_report      — §Roofline table from the dry-run JSONs
"""
