"""Fig. 5 / Fig. 8 / Fig. 9: scalar-private LP solving.

Violated-constraint parity (exact vs fast) and per-iteration runtime
scaling with the number of constraints m for flat vs IVF vs NSW indices.
Paper fixes d=20, Δ∞=0.1, α=0.5.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import med_us, row
from repro.core import ScalarLPConfig, solve_scalar_lp
from repro.core.queries import random_feasible_lp
from repro.mips import FlatIndex, IVFIndex, NSWIndex


def run(quick: bool = True):
    d = 20
    ms = [2048, 16384] if quick else [4096, 32768, 131072, 262144]
    T = 60 if quick else 200
    rows = []
    for m in ms:
        A, b, _ = random_feasible_lp(jax.random.PRNGKey(0), m=m, d=d)
        Ab = np.concatenate([np.asarray(A), np.asarray(b)[:, None]], axis=1)
        exact = solve_scalar_lp(A, b, ScalarLPConfig(T=T, mode="exact"),
                                jax.random.PRNGKey(1))
        rows.append(row(f"lp/m{m}/exact", med_us(exact.iter_seconds),
                        f"violated={exact.violated_frac:.4f}"))
        for kind in ("flat", "ivf", "nsw"):
            if kind == "flat":
                index = FlatIndex(Ab, use_pallas="never")
            elif kind == "ivf":
                index = IVFIndex(Ab, seed=0, train_iters=4)
            else:
                index = NSWIndex(Ab, deg=16, ef=48, rounds=3, seed=0)
            res = solve_scalar_lp(A, b, ScalarLPConfig(T=T, mode="fast"),
                                  jax.random.PRNGKey(1), index=index)
            rows.append(row(
                f"lp/m{m}/{kind}", med_us(res.iter_seconds),
                f"violated={res.violated_frac:.4f}"
                f";scored={int(np.mean(res.n_scored))}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
