"""Fig. 5 / Fig. 8 / Fig. 9: scalar-private LP solving, plus the fused-
driver comparison (DESIGN.md §6).

Violated-constraint parity (exact vs fast) and per-iteration runtime
scaling with the number of constraints m for flat vs IVF indices — each
measured on both drivers, with the host-loop/fused-scan speedup recorded
in the derived column (``fused_speedup``) so BENCH_results.json tracks the
dispatch-amortization win across PRs. A fixed-size dual-solver pair rides
along. Paper fixes d=20, Δ∞=0.1, α=0.5. NSW (host-only) runs in ``--full``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import med_us, row
from repro.core import (DualLPConfig, ScalarLPConfig,
                        solve_constraint_private_lp, solve_scalar_lp)
from repro.core.queries import random_feasible_lp, random_packing_lp
from repro.mips import FlatIndex, IVFIndex, NSWIndex, lp_dual_rows, lp_scalar_rows


def _pair_rows(name: str, host_res, fused_res, detail: str) -> list:
    host_us = med_us(host_res.iter_seconds)
    fused_us = med_us(fused_res.iter_seconds)
    speedup = host_us / max(fused_us, 1e-9)
    return [
        row(f"{name}/host", host_us, detail.format(res=host_res)),
        row(f"{name}/fused", fused_us,
            detail.format(res=fused_res) + f";fused_speedup={speedup:.2f}"),
    ]


def run(quick: bool = True):
    d = 20
    ms = [2048, 16384] if quick else [4096, 32768, 131072, 262144]
    T = 60 if quick else 200
    rows = []
    sc_detail = "violated={res.violated_frac:.4f}"
    for m in ms:
        A, b, _ = random_feasible_lp(jax.random.PRNGKey(0), m=m, d=d)
        Ab = lp_scalar_rows(np.asarray(A), np.asarray(b))
        rows += _pair_rows(
            f"lp/m{m}/exact",
            solve_scalar_lp(A, b, ScalarLPConfig(T=T, mode="exact",
                                                 driver="host"),
                            jax.random.PRNGKey(1)),
            solve_scalar_lp(A, b, ScalarLPConfig(T=T, mode="exact",
                                                 driver="fused"),
                            jax.random.PRNGKey(1)),
            sc_detail)
        kinds = ("flat", "ivf") if quick else ("flat", "ivf", "nsw")
        for kind in kinds:
            if kind == "flat":
                index = FlatIndex(Ab, use_pallas="never")
            elif kind == "ivf":
                index = IVFIndex(Ab, seed=0, train_iters=4)
            else:
                index = NSWIndex(Ab, deg=16, ef=48, rounds=3, seed=0)
            cfg_host = ScalarLPConfig(T=T, mode="fast", driver="host")
            host = solve_scalar_lp(A, b, cfg_host, jax.random.PRNGKey(1),
                                   index=index)
            detail = (sc_detail
                      + f";scored={int(np.mean(host.n_scored))}")
            if getattr(index, "supports_in_graph", False):
                cfg_fused = ScalarLPConfig(T=T, mode="fast", driver="fused")
                fused = solve_scalar_lp(A, b, cfg_fused, jax.random.PRNGKey(1),
                                        index=index)
                rows += _pair_rows(f"lp/m{m}/{kind}", host, fused, detail)
            else:
                rows.append(row(f"lp/m{m}/{kind}/host",
                                med_us(host.iter_seconds),
                                detail.format(res=host)))

    # constraint-private dual solver, fixed size (§4.2)
    m2, d2 = (150, 256) if quick else (300, 1024)
    A2, b2, c2 = random_packing_lp(jax.random.PRNGKey(2), m=m2, d=d2)
    opt = float(c2 @ jnp.full((d2,), 1.0 / d2)) * 0.5
    index = FlatIndex(lp_dual_rows(np.asarray(A2), np.asarray(c2), opt),
                      use_pallas="never")
    dual_detail = "n_violated={res.n_violated}"
    rows += _pair_rows(
        f"lp_dual/d{d2}",
        solve_constraint_private_lp(
            A2, b2, c2, opt, DualLPConfig(T=T, s=12, mode="fast",
                                          driver="host"),
            jax.random.PRNGKey(3), index=index),
        solve_constraint_private_lp(
            A2, b2, c2, opt, DualLPConfig(T=T, s=12, mode="fast",
                                          driver="fused"),
            jax.random.PRNGKey(3), index=index),
        dual_detail)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
