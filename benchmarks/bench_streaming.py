"""Streaming release serving under open-loop load: latency distribution
and sustained throughput.

The fixed-wave drain measures *batch* throughput; this bench measures the
serving claim — what a tenant actually waits between admission and
answer when requests arrive as live traffic. An open-loop Poisson
generator (`repro.serve.loadgen`) offers a mixed blend of histogram
releases, LP solves, and cached-answer reads across many tenants against
a ``streaming=True`` service: the deadline/occupancy coalescer cuts
adaptive-size waves from the AOT ladder, dispatch is pipelined
launch/finish, and the generator reports per-kind p50/p95/p99
admission→answer latency plus sustained QPS into BENCH_results.json.

The ``adaptive_waves`` row holds the acceptance gate: under partial
occupancy the ladder must run short waves on smaller executables
(``pad_slots_saved > 0``) instead of padding every wave to ``wave_size``
by slot replication.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row
from repro.core import MWEMConfig, ScalarLPConfig
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.serve import LoadSpec, ReleaseService, run_open_loop


def _lat_row(name: str, rep, kind: str):
    q = rep.quantiles[kind]
    n = rep.latencies[kind].size
    return row(name, q["p50"] * 1e6,
               f"p50_s={q['p50']:.4f};p95_s={q['p95']:.4f}"
               f";p99_s={q['p99']:.4f};count={n}")


def run(quick: bool = True):
    U = 128 if quick else 512
    m = 512 if quick else 4096
    T = 6 if quick else 30
    B = 4 if quick else 8
    n_tenants = 6 if quick else 24
    n = 500
    duration = 0.8 if quick else 5.0
    rate = 25.0 if quick else 150.0
    # half-budget deadline triggers fire well inside the run, so the
    # coalescer cuts short waves mid-traffic instead of always waiting
    # for a full one
    deadline = 0.4 if quick else 1.0

    key = jax.random.PRNGKey(0)
    kh, kq, ka = jax.random.split(key, 3)
    h = np.asarray(gaussian_histogram(kh, n, U))
    Q = random_binary_queries(kq, m, U)

    cfg = MWEMConfig(eps=0.5, delta=1e-3, T=T, mode="fast")
    svc = ReleaseService(Q, cfg, wave_size=B, streaming=True,
                         default_deadline=10.0)
    for i in range(n_tenants):
        svc.create_session(f"t{i}", eps_budget=200.0, delta_budget=0.9,
                           h=h, n_records=n)
    A = np.asarray(jax.random.normal(ka, (m, U)), np.float32)
    b = (A @ (np.ones(U, np.float32) / U) + 0.1).astype(np.float32)
    svc.attach_lp(A, b, ScalarLPConfig(eps=0.4, delta=1e-3, T=T,
                                       mode="exact"))

    # AOT-compile the whole wave-size ladder before traffic starts, so the
    # measured latencies are pure serving (no trace+compile spikes)
    svc.prewarm(n_records=n)
    svc.prewarm(lp=True)

    spec = LoadSpec(duration=duration, rate=rate, seed=7,
                    mix={"mwem": 0.5, "lp": 0.25, "answer": 0.25},
                    deadline=deadline)
    rep = run_open_loop(svc, spec)

    rows = [
        _lat_row("streaming/latency_mwem", rep, "mwem"),
        _lat_row("streaming/latency_lp", rep, "lp"),
        _lat_row("streaming/latency_answer", rep, "answer"),
        row("streaming/sustained_qps", 1e6 / max(rep.sustained_qps, 1e-9),
            f"sustained_qps={rep.sustained_qps:.1f}"
            f";offered_qps={rep.offered_qps:.1f}"
            f";done={rep.counts['done']};answers={rep.counts['answers']}"
            f";expired={rep.counts['expired']}"),
    ]

    # acceptance gate: a short wave must run on the smaller fitting AOT
    # executable instead of being padded to wave_size by slot replication.
    # The deterministic probe (2 tickets, flushed alone) makes the gate
    # independent of how the stochastic load happened to coalesce.
    stats = svc.stats
    before = stats.pad_slots_saved
    svc.submit("t0")
    svc.submit("t1")
    svc.flush()
    assert stats.pad_slots_saved >= before + (B - 2), (
        "adaptive wave sizing never engaged: a 2-ticket wave was padded "
        f"to wave_size ({stats.as_dict()})")
    trig = {d.reason for d in svc.wave_log}
    rows.append(row("streaming/adaptive_waves", 0.0,
                    f"pad_slots_saved={stats.pad_slots_saved}"
                    f";padded_slots={stats.padded_slots}"
                    f";refilled_slots={stats.refilled_slots}"
                    f";dispatches={stats.dispatches}"
                    f";triggers={'|'.join(sorted(trig))}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
