"""MWEM iteration megakernel microbench + roofline HBM-bytes budget gate.

Times one fast-mode iteration through the fused scan under both step
bodies (DESIGN.md §7):

* ``classic`` — ``cfg.use_pallas="never"``: every sub-op of
  softmax → probe → select → measure → MWU → renorm is its own HBM
  round-trip (the pre-fusion baseline).
* ``mega``    — ``cfg.use_pallas="auto"``: the carried-density scan body,
  the `kernels.mwem_step` Pallas route on TPU and its bitwise XLA ref
  off-TPU (the resolved path lands in the derived column).

Also times the raw `kernels.mwem_step.ops.mwem_step` dispatch against the
jit'd oracle, and prints the analytic `analysis.roofline.
mwem_step_roofline` rows for both routes. The bytes ratio is the speedup
ceiling on a bandwidth-bound part — and it is a *budget*: this bench
raises (failing `run.py` and the CI bench-smoke lane) if the megakernel's
modeled per-iteration HBM bytes ever creep above the classic body's.
"""

from __future__ import annotations

from repro.obs import clock

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import med_us, row
from repro.analysis.roofline import mwem_step_roofline
from repro.core import MWEMConfig, run_mwem_fused
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.kernels.mwem_step import ops as step_ops
from repro.kernels.mwem_step.ref import mwem_step_ref
from repro.mips import IVFIndex, augment_complement


def _time_call(fn, reps: int) -> float:
    fn()  # warm-up: trace + compile
    samples = []
    for _ in range(reps):
        t0 = clock.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        samples.append(clock.perf_counter() - t0)
    return float(np.median(samples) * 1e6)


def run(quick: bool = True):
    U = 256 if quick else 512
    ms = [4096] if quick else [8192, 32768]
    T = 12 if quick else 30
    n = 500
    reps = 20 if quick else 50
    rows = []
    kh, kq = jax.random.split(jax.random.PRNGKey(0))
    h = gaussian_histogram(kh, n, U)

    # raw step dispatch: kernel route vs the jit'd oracle
    rng = np.random.default_rng(0)
    lw = jnp.asarray(rng.standard_normal(U).astype(np.float32))
    lw = lw - jnp.max(lw)
    p = jax.nn.softmax(lw)
    ps = jnp.zeros((U,), jnp.float32)
    rows_tbl = jnp.asarray(rng.integers(0, 2, (1024, U)).astype(np.float32))
    hv = jnp.asarray(rng.uniform(0, 1, U).astype(np.float32))
    ref = jax.jit(lambda *a: mwem_step_ref(*a, rule="hardt", eta=0.5))
    us_ref = _time_call(lambda: ref(lw, p, ps, rows_tbl[3], hv,
                                    jnp.float32(0.1)), reps)
    us_step = _time_call(lambda: step_ops.mwem_step(
        lw, p, ps, rows_tbl, jnp.int32(3), hv, jnp.float32(0.1),
        rule="hardt", eta=0.5), reps)
    path = ("pallas" if jax.default_backend() == "tpu" else "interpret")
    rows.append(row(f"mwem_step/U{U}/step_ref", us_ref, ""))
    rows.append(row(f"mwem_step/U{U}/step_kernel", us_step,
                    f"path={path};vs_ref={us_ref / us_step:.2f}x"))

    for m in ms:
        Q = random_binary_queries(kq, m, U)
        aug = augment_complement(np.asarray(Q))
        results = {}
        for route in ("never", "auto"):
            ix = IVFIndex(aug, seed=0, train_iters=4, use_pallas=route)
            cfg = MWEMConfig(T=T, mode="fast", n_records=n, use_pallas=route)
            run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(1), index=ix)
            res = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(1), index=ix)
            results[route] = (med_us(res.iter_seconds), res, ix)
        us_classic = results["never"][0]
        us_mega, res_mega, ix_mega = results["auto"]
        mega_path = "kernel" if ix_mega._resolve_pallas() else "mega_ref"
        rows.append(row(f"mwem_step/m{m}/iter_classic", us_classic,
                        f"err={results['never'][1].final_error:.4f}"))
        rows.append(row(f"mwem_step/m{m}/iter_mega", us_mega,
                        f"path={mega_path}"
                        f";err={res_mega.final_error:.4f}"
                        f";vs_classic={us_classic / us_mega:.2f}x"))

        rf = {}
        for megakernel in (True, False):
            rf[megakernel] = mwem_step_roofline(m=m, U=U,
                                                megakernel=megakernel)
            tag = "mega" if megakernel else "classic"
            r = rf[megakernel]
            rows.append(row(
                f"mwem_step/m{m}/roofline_{tag}",
                r["step_lower_bound_s"] * 1e6,
                f"hbm_bytes={r['hbm_bytes']:.3g}"
                f";state_passes={r['state_passes']}"
                f";bottleneck={r['bottleneck']}"))
        ratio = rf[False]["hbm_bytes"] / rf[True]["hbm_bytes"]
        rows.append(row(f"mwem_step/m{m}/hbm_bytes_ratio", 0.0,
                        f"classic_over_mega={ratio:.2f}x"))
        # the roofline budget gate: fusing must never *add* modeled bytes
        if rf[True]["hbm_bytes"] > rf[False]["hbm_bytes"]:
            raise RuntimeError(
                f"megakernel HBM bytes above the pre-fusion baseline at "
                f"m={m}: {rf[True]['hbm_bytes']:.3g} > "
                f"{rf[False]['hbm_bytes']:.3g}")
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
