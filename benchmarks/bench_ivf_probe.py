"""IVF probe microbench: the per-iteration kNN hot path, single and waved.

Times one probe dispatch through each route of `mips.IVFIndex`:

* ``xla``         — gather → dense matvec → top_k (the old path; the
                    gathered (nprobe·cap, dim) matrix round-trips HBM).
* ``kernel``      — the fused `kernels.ivf_probe` route as `use_pallas=
                    "auto"` resolves it: the Pallas stream on TPU, the
                    same XLA probe off-TPU (the automatic fallback —
                    recorded either way, with the resolved path in the
                    derived column).
* ``batch``       — a wave of B probes through `query_in_graph_batch`
                    (cells probed by several lanes read once on the kernel
                    route) vs B sequential single probes.

Also prints the analytic roofline rows (`analysis.roofline.
ivf_probe_roofline`): HBM bytes touched by the kernelized stream vs the
full-gather lowering — the bytes ratio is the speedup ceiling on a
bandwidth-bound part.
"""

from __future__ import annotations

from repro.obs import clock

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.analysis.roofline import ivf_probe_roofline
from repro.core.queries import random_binary_queries
from repro.mips import IVFIndex, augment_complement


def _time_call(fn, reps: int) -> float:
    fn()  # warm-up: trace + compile
    samples = []
    for _ in range(reps):
        t0 = clock.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        samples.append(clock.perf_counter() - t0)
    return float(np.median(samples) * 1e6)


def run(quick: bool = True):
    U = 128 if quick else 256
    ms = [4096] if quick else [8192, 32768]
    B = 8
    reps = 20 if quick else 50
    rows = []
    kq = jax.random.PRNGKey(0)
    for m in ms:
        Q = random_binary_queries(kq, m, U)
        aug = augment_complement(np.asarray(Q))
        k = int(np.ceil(np.sqrt(m)))
        ix_xla = IVFIndex(aug, seed=0, train_iters=4, use_pallas="never")
        ix_ker = IVFIndex(aug, seed=0, train_iters=4, use_pallas="auto")
        path = "pallas" if ix_ker._resolve_pallas() else "xla_ref"
        v = jax.random.normal(jax.random.PRNGKey(1), (U,), jnp.float32)
        v = v - v.mean()  # zero-sum probe, the histogram-difference regime
        Vb = jax.random.normal(jax.random.PRNGKey(2), (B, U), jnp.float32)
        Vb = Vb - Vb.mean(axis=1, keepdims=True)

        us_xla = _time_call(lambda: ix_xla.query_in_graph(v, k), reps)
        us_ker = _time_call(lambda: ix_ker.query_in_graph(v, k), reps)
        rows.append(row(f"ivf_probe/m{m}/single_xla", us_xla,
                        f"rows_scored={ix_xla.query_cost(k)}"))
        rows.append(row(f"ivf_probe/m{m}/single_kernel", us_ker,
                        f"path={path};vs_xla={us_xla / us_ker:.2f}x"))

        us_seq = _time_call(
            lambda: [ix_xla.query_in_graph(Vb[b], k) for b in range(B)], reps)
        us_wave = _time_call(lambda: ix_ker.query_in_graph_batch(Vb, k), reps)
        rows.append(row(f"ivf_probe/m{m}/wave_B{B}", us_wave,
                        f"path={path};per_lane_us={us_wave / B:.1f}"
                        f";vs_sequential={us_seq / us_wave:.2f}x"
                        f";waves_per_s={1e6 / us_wave:.1f}"))

        for kernelized in (True, False):
            rf = ivf_probe_roofline(nlist=ix_ker.nlist, nprobe=ix_ker.nprobe,
                                    cap=ix_ker.cap, dim=U, batch=B,
                                    kernelized=kernelized)
            tag = "kernel" if kernelized else "full_gather"
            rows.append(row(
                f"ivf_probe/m{m}/roofline_{tag}",
                rf["step_lower_bound_s"] * 1e6,
                f"hbm_bytes={rf['hbm_bytes']:.3g}"
                f";rows_scored={rf['rows_scored']}"
                f";bottleneck={rf['bottleneck']}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
