"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes a ``BENCH_results.json``
artifact (per-bench rows — iter/call microseconds plus the derived column
carrying rows_scored / wave-throughput / speedup metrics — and wall-clock),
which CI uploads so the perf trajectory is tracked across PRs. ``--full``
runs the paper-scale sweeps; the default quick mode keeps the whole suite
CPU-friendly.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys

from repro.obs import clock


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated bench names to run")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="path of the results artifact ('' disables)")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (bench_distributed, bench_error_parity,
                            bench_ivf_probe, bench_linear_queries, bench_lp,
                            bench_margin, bench_marginals, bench_mwem_step,
                            bench_n_ablation, bench_release_service,
                            bench_streaming, roofline_report)
    from benchmarks.common import print_rows

    benches = {
        "linear_queries": bench_linear_queries,
        "error_parity": bench_error_parity,
        "lp": bench_lp,
        "margin": bench_margin,
        "n_ablation": bench_n_ablation,
        "release_service": bench_release_service,
        "streaming": bench_streaming,
        "distributed": bench_distributed,
        "ivf_probe": bench_ivf_probe,
        "marginals": bench_marginals,
        "mwem_step": bench_mwem_step,
        "roofline": roofline_report,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)

    results: dict = {}
    print("name,us_per_call,derived")
    for name in selected:
        mod = benches[name]
        t0 = clock.perf_counter()
        try:
            rows = mod.run(quick=quick)
            print_rows(rows)
            dt = clock.perf_counter() - t0
            results[name] = {"rows": rows, "seconds": round(dt, 2)}
            print(f"# {name}: {len(rows)} rows in {dt:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the suite running; fail at the end
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            results[name] = {"rows": [], "seconds": round(clock.perf_counter() - t0, 2),
                             "error": f"{type(e).__name__}: {e}"}

    if args.json:
        import jax

        artifact = {
            "schema": 1,
            "quick": quick,
            "generated_at": clock.timestamp(),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            "benches": results,
        }
        try:  # obs snapshot: mechanism telemetry + serving latencies the
            # benches accumulated in the default registry during this run
            from repro.obs.metrics import default_registry

            artifact["metrics"] = default_registry().snapshot()
        except Exception as e:  # never let obs break the artifact
            artifact["metrics"] = {"error": f"{type(e).__name__}: {e}"}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)

    failed = [n for n, r in results.items() if "error" in r]
    if failed:
        # every selected bench ran (errors don't stop the suite), but a
        # crashed bench must still fail the invocation — CI would otherwise
        # go green with zero coverage of the section it smoke-tests
        print(f"# FAILED benches: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
