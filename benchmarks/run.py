"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` runs the paper-scale
sweeps; the default quick mode keeps the whole suite CPU-friendly.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated bench names to run")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (bench_distributed, bench_error_parity,
                            bench_linear_queries, bench_lp, bench_margin,
                            bench_n_ablation, bench_release_service,
                            roofline_report)
    from benchmarks.common import print_rows

    benches = {
        "linear_queries": bench_linear_queries,
        "error_parity": bench_error_parity,
        "lp": bench_lp,
        "margin": bench_margin,
        "n_ablation": bench_n_ablation,
        "release_service": bench_release_service,
        "distributed": bench_distributed,
        "roofline": roofline_report,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)

    print("name,us_per_call,derived")
    for name in selected:
        mod = benches[name]
        t0 = time.time()
        try:
            rows = mod.run(quick=quick)
            print_rows(rows)
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
