"""Fig. 6 (§I.1): the margin B and the tail count C.

Verifies E[C] = O(√m): the extra samples beyond the top-k are a vanishing
fraction of m, which is what preserves sublinearity.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.lazy_em import lazy_em


def run(quick: bool = True):
    ms = [512, 2048] if quick else [512, 2048, 20000]
    trials = 50 if quick else 300
    rows = []
    for m in ms:
        k = max(1, int(math.isqrt(m)))
        key = jax.random.PRNGKey(0)
        scores = jax.random.normal(key, (m,)) * 2.0
        cs = []
        for i in range(trials):
            out = lazy_em(jax.random.PRNGKey(i + 1), scores, k=k,
                          tail_cap=min(m, 8 * k))
            cs.append(int(out.tail_count))
        mean_c = float(np.mean(cs))
        rows.append(row(f"margin/m{m}", 0.0,
                        f"E[C]={mean_c:.1f};bound_m_over_k={m/k:.1f}"
                        f";frac_of_m={mean_c/m:.4f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
