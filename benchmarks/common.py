"""Shared benchmark plumbing."""

from __future__ import annotations

import jax
import numpy as np


def med_us(seconds_list, skip: int = 3) -> float:
    """Median per-iteration microseconds, skipping jit warm-up iterations."""
    xs = np.asarray(seconds_list[skip:] if len(seconds_list) > skip
                    else seconds_list)
    return float(np.median(xs) * 1e6)


def row(name: str, us_per_call: float, derived) -> dict:
    return {"name": name, "us_per_call": round(us_per_call, 1),
            "derived": derived}


def print_rows(rows):
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
