"""Sharded driver benchmark: forced host device counts 1/2/4/8,
exhaustive-vs-lazy scored rows, wire, and time per iteration.

Each device count runs in a subprocess (``XLA_FLAGS=--xla_force_host_
platform_device_count=N`` must be set before JAX initializes) that times
`run_mwem_sharded` in both modes on the same workload and lowers the
single-iteration cell for the HLO collective-byte (wire) count. The paper's
claim at this tier: lazy mode scores strictly fewer rows per iteration than
the exhaustive Θ(m) baseline, at less collective wire on a model-sharded
mesh.

Rows: ``distributed/d{N}/{mode}`` with per-iteration execution µs;
derived packs ``rows=<scored rows/iter>;wire=<collective bytes/iter>;
sublinear=<lazy rows < exhaustive rows>``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import row

_REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
    import json, time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import MWEMConfig, run_mwem_sharded
    from repro.core.distributed import (make_mwem_iteration,
                                        shard_selection_params)
    from repro.core.queries import gaussian_histogram, random_binary_queries
    from repro.mips import ShardedIVFIndex
    from repro.launch.mesh import make_mesh_compat
    from repro.analysis.hlo import analyze_hlo

    d, m, U, T = {devices}, {m}, {U}, {T}
    model = 2 if d >= 2 else 1
    n_data = d // model
    mesh = make_mesh_compat((n_data, model), ("data", "model"))
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    n_records = 3000
    h = gaussian_histogram(kh, n_records, U)
    Q = random_binary_queries(kq, m, U)
    idx = ShardedIVFIndex(Q, n_shards=n_data, seed=0)

    out = {{}}
    for mode, cfg in (
        ("exhaustive", MWEMConfig(T=T, mode="exact", n_records=n_records)),
        ("lazy", MWEMConfig(T=T, mode="fast", n_records=n_records)),
    ):
        index = idx if mode == "lazy" else None
        run_mwem_sharded(Q, h, cfg, key, mesh=mesh, index=index)  # compile
        t0 = clock.perf_counter()
        res = run_mwem_sharded(Q, h, cfg, key, mesh=mesh, index=index)
        dt = clock.perf_counter() - t0
        m_loc = m // n_data
        k_loc, tail_cap = shard_selection_params(m_loc, idx)  # == the run's
        fn = make_mwem_iteration(
            mesh, m=m, U=U, nlist=idx.nlist, cap=idx.cap, nprobe=idx.nprobe,
            k_loc=k_loc, tail_cap=tail_cap,
            scale=20.0, eta=0.05, mode=mode, multi_pod=False,
            fallback=False)  # hot-path wire; the redo branch is e^-sqrt(m) rare
        args = (
            jax.ShapeDtypeStruct((m, U), jnp.float32),
            jax.ShapeDtypeStruct((n_data, idx.nlist, U), jnp.float32),
            jax.ShapeDtypeStruct((n_data, idx.nlist, idx.cap), jnp.int32),
            jax.ShapeDtypeStruct((U,), jnp.float32),
            jax.ShapeDtypeStruct((U,), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        with mesh:
            compiled = jax.jit(fn).lower(*args).compile()
        out[mode] = dict(
            iter_us=dt / T * 1e6,
            rows=float(np.mean(res.n_scored)),
            wire=analyze_hlo(compiled.as_text()).collective_bytes,
            err=res.final_error,
        )
    print("BENCH" + json.dumps(out))
"""


def _probe(devices: int, m: int, U: int, T: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _REPO_SRC
    script = textwrap.dedent(_SCRIPT.format(devices=devices, m=m, U=U, T=T))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"d={devices}: {out.stderr[-2000:]}")
    line = [l for l in out.stdout.splitlines() if l.startswith("BENCH")][-1]
    return json.loads(line[len("BENCH"):])


def run(quick: bool = True):
    m, U, T = (2048, 64, 6) if quick else (32768, 128, 10)
    rows = []
    for devices in (1, 2, 4, 8):
        r = _probe(devices, m, U, T)
        sublinear = r["lazy"]["rows"] < r["exhaustive"]["rows"]
        for mode in ("exhaustive", "lazy"):
            rows.append(row(
                f"distributed/d{devices}/{mode}", r[mode]["iter_us"],
                f"rows={r[mode]['rows']:.0f};wire={r[mode]['wire']:.0f};"
                f"sublinear={sublinear}"))
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
