"""Fig. 2 / Fig. 3: error parity — Fast-MWEM tracks MWEM's error.

Fig. 2: |err(MWEM) − err(FastMWEM-flat)| ≈ 0 across m.
Fig. 3: per-index error over iterations (all indices ≈ flat).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import med_us, row
from repro.core import MWEMConfig, run_mwem
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.mips import FlatAbsIndex, IVFIndex, NSWIndex, augment_complement


def run(quick: bool = True):
    U = 128
    n = 500
    ms = [200, 500] if quick else [200, 500, 1000]
    T = 200 if quick else 800
    rows = []
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    h = gaussian_histogram(kh, n, U)

    for m in ms:
        Q = random_binary_queries(kq, m, U)
        exact = run_mwem(Q, h, MWEMConfig(T=T, mode="exact", n_records=n),
                         jax.random.PRNGKey(2))
        fast = run_mwem(Q, h, MWEMConfig(T=T, mode="fast", n_records=n),
                        jax.random.PRNGKey(2), index=FlatAbsIndex(Q))
        diff = abs(exact.final_error - fast.final_error)
        rows.append(row(f"error_parity/m{m}/flat", med_us(fast.iter_seconds),
                        f"err_diff={diff:.5f};exact={exact.final_error:.4f}"))
        aug = augment_complement(np.asarray(Q))
        for kind, index in (("ivf", IVFIndex(aug, seed=0, train_iters=4)),
                            ("nsw", NSWIndex(aug, deg=16, ef=48, rounds=3,
                                             seed=0))):
            res = run_mwem(Q, h, MWEMConfig(T=T, mode="fast", n_records=n),
                           jax.random.PRNGKey(2), index=index)
            rows.append(row(f"error_parity/m{m}/{kind}",
                            med_us(res.iter_seconds),
                            f"err={res.final_error:.4f}"
                            f";exact={exact.final_error:.4f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
