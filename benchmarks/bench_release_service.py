"""Release-service serving metrics: sustained answer QPS, wave throughput,
and budget-rejection latency.

Three regimes matter for a read-heavy private release tier:

* ``answer_hot``   — repeat queries served from the zero-ε cache (dict
  lookup, no histogram read): the hot path that post-processing makes free.
* ``answer_cold``  — first-touch linear queries (one (U,) dot product).
* ``reject``       — admission turning away an over-budget request: pure
  ledger preview, no device work; its latency bounds how cheaply abusive
  traffic is shed.
* ``wave``         — release throughput: N admitted requests drained in
  ⌈N/B⌉ fused `run_mwem_batch` dispatches.
* ``wave_degraded`` — the same drain with the fault harness armed at a 10%
  dispatch-failure rate: measures what retry waves (re-dispatch + backoff)
  cost relative to the clean path. Retried lanes are keyed by the same
  ``PRNGKey(ticket.seed)``, so degraded throughput buys bitwise-identical
  releases at zero extra ε.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row
from repro.core import MWEMConfig
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.faults import Schedule, inject
from repro.obs import clock
from repro.serve import ReleaseService


def _med_us(samples) -> float:
    return float(np.median(np.asarray(samples)) * 1e6)


def run(quick: bool = True):
    U = 256 if quick else 512
    m = 1024 if quick else 8192
    T = 10 if quick else 40
    B = 4 if quick else 8
    n_tenants = 8 if quick else 32
    n_answers = 200 if quick else 2000
    n = 500

    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    h = np.asarray(gaussian_histogram(kh, n, U))
    Q = random_binary_queries(kq, m, U)
    Qnp = np.asarray(Q)

    cfg = MWEMConfig(eps=0.5, delta=1e-3, T=T, mode="fast")
    svc = ReleaseService(Q, cfg, wave_size=B, auto_flush=False)
    rows = []

    # --- wave throughput: N tenants, ⌈N/B⌉ dispatches -----------------------
    for i in range(n_tenants):
        svc.create_session(f"t{i}", eps_budget=100.0, delta_budget=0.5,
                           h=h, n_records=n)
        svc.submit(f"t{i}")
    svc.flush()  # warm-up: trace + compile the wave executable
    for i in range(n_tenants):
        svc.submit(f"t{i}")
    t0 = clock.perf_counter()
    svc.flush()
    wave_dt = clock.perf_counter() - t0
    rows.append(row(f"release_service/wave_B{B}",
                    wave_dt / n_tenants * 1e6,
                    f"releases_per_s={n_tenants / wave_dt:.1f}"
                    f";dispatches={svc.stats.dispatches}"))

    # --- degraded mode: 10% dispatch-failure rate, retry waves --------------
    # fail_n=1 forces at least one retry even in the quick lane's handful of
    # dispatches, so the retry-overhead figure is never vacuous
    for i in range(n_tenants):
        svc.submit(f"t{i}")
    with inject({"wave.dispatch": Schedule(fail_n=1, fail_rate=0.10,
                                           seed=0)}) as plan:
        t0 = clock.perf_counter()
        svc.flush()
        deg_dt = clock.perf_counter() - t0
    rows.append(row("release_service/wave_degraded",
                    deg_dt / n_tenants * 1e6,
                    f"releases_per_s={n_tenants / deg_dt:.1f}"
                    f";retries={svc.stats.retries}"
                    f";failures={plan.failures['wave.dispatch']}"
                    f";retry_overhead={deg_dt / wave_dt:.2f}x"))

    # --- answer path: cold (histogram dot) vs hot (zero-ε cache) ------------
    qidx = np.arange(n_answers) % m
    t0 = clock.perf_counter()
    for j in qidx:
        svc.answer("t0", Qnp[j])
    cold_dt = clock.perf_counter() - t0
    t0 = clock.perf_counter()
    for j in qidx:
        svc.answer("t0", Qnp[j])
    hot_dt = clock.perf_counter() - t0
    sess = svc.session("t0")
    rows.append(row("release_service/answer_cold", cold_dt / n_answers * 1e6,
                    f"qps={n_answers / cold_dt:.0f}"))
    rows.append(row("release_service/answer_hot", hot_dt / n_answers * 1e6,
                    f"qps={n_answers / hot_dt:.0f}"
                    f";hit_rate={sess.cache.hits / (sess.cache.hits + sess.cache.misses):.2f}"))

    # --- budget-rejection latency ------------------------------------------
    svc.create_session("broke", eps_budget=1e-6, delta_budget=0.5,
                       h=h, n_records=n)
    lat = []
    for _ in range(50 if quick else 500):
        t0 = clock.perf_counter()
        ticket = svc.submit("broke")
        lat.append(clock.perf_counter() - t0)
        assert ticket.status == "rejected"
    rows.append(row("release_service/reject", _med_us(lat),
                    f"rejected={svc.stats.rejected}"))

    # --- obs: admission→answer latency quantiles from the service registry --
    snap = svc.metrics_snapshot()
    hist = snap["histograms"].get('admission_to_answer_seconds{kind=mwem}')
    if hist is not None:
        rows.append(row("release_service/obs_latency_mwem",
                        hist["p50"] * 1e6,
                        f"p95_us={hist['p95'] * 1e6:.0f}"
                        f";count={hist['count']}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run(quick=True))
