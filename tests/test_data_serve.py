"""Data pipeline (incl. DP release) + serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.private import PrivateDataPipeline
from repro.data.synthetic import SyntheticCorpus, batch_for_step
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


class TestSyntheticData:
    def test_deterministic_across_calls(self):
        c = SyntheticCorpus(vocab_size=512, seed=3)
        a = batch_for_step(c, 5, 2, 8, 4, 32)
        b = batch_for_step(c, 5, 2, 8, 4, 32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shards_differ(self):
        c = SyntheticCorpus(vocab_size=512, seed=3)
        a = batch_for_step(c, 5, 0, 8, 4, 32)
        b = batch_for_step(c, 5, 1, 8, 4, 32)
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_tokens_in_range(self):
        c = SyntheticCorpus(vocab_size=100)
        t = np.asarray(batch_for_step(c, 0, 0, 1, 16, 64))
        assert t.min() >= 0 and t.max() < 100


class TestPrivatePipeline:
    def test_fit_and_sample(self):
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, 256, size=20_000)
        pipe = PrivateDataPipeline(vocab_size=256, eps=2.0, n_queries=64,
                                   T=30, seed=0)
        pipe.fit(tokens)
        eps, delta = pipe.privacy_spent()
        assert 0 < eps < 10 and 0 < delta < 0.1
        batch = pipe.sample_batch(0, 0, 4, 32)
        assert batch.shape == (4, 32)
        assert int(batch.max()) < 256

    def test_release_tracks_distribution(self):
        """The DP histogram should be closer to the truth than uniform."""
        rng = np.random.default_rng(1)
        # concentrated corpus
        tokens = rng.integers(0, 32, size=50_000)
        pipe = PrivateDataPipeline(vocab_size=256, eps=3.0, n_queries=256,
                                   T=200, seed=1)
        pipe.fit(tokens)
        p = np.asarray(pipe.p_hat)
        mass_low = p[:32].sum()
        assert mass_low > 0.2  # uniform would give 0.125; measured ≈ 0.28


class TestServeEngine:
    def test_batched_waves(self):
        cfg = get_smoke_config("llama3.2-3b").with_(dtype="float32")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=3, max_len=32)
        reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5) for _ in range(5)]
        engine.run(reqs)
        assert all(r.done for r in reqs)
        assert all(len(r.out_tokens) == 5 for r in reqs)
        assert all(0 <= t < cfg.padded_vocab for r in reqs for t in r.out_tokens)

    def test_greedy_deterministic(self):
        cfg = get_smoke_config("mamba2-130m").with_(dtype="float32")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=2, max_len=24)
        r1 = [Request(prompt=[5, 6, 7], max_new_tokens=6)]
        r2 = [Request(prompt=[5, 6, 7], max_new_tokens=6)]
        engine.run(r1)
        engine.run(r2)
        assert r1[0].out_tokens == r2[0].out_tokens
