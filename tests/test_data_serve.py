"""Data pipeline (incl. DP release) + serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.private import PrivateDataPipeline
from repro.data.synthetic import SyntheticCorpus, batch_for_step
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


class TestSyntheticData:
    def test_deterministic_across_calls(self):
        c = SyntheticCorpus(vocab_size=512, seed=3)
        a = batch_for_step(c, 5, 2, 8, 4, 32)
        b = batch_for_step(c, 5, 2, 8, 4, 32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shards_differ(self):
        c = SyntheticCorpus(vocab_size=512, seed=3)
        a = batch_for_step(c, 5, 0, 8, 4, 32)
        b = batch_for_step(c, 5, 1, 8, 4, 32)
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_tokens_in_range(self):
        c = SyntheticCorpus(vocab_size=100)
        t = np.asarray(batch_for_step(c, 0, 0, 1, 16, 64))
        assert t.min() >= 0 and t.max() < 100


class TestPrivatePipeline:
    def test_fit_and_sample(self):
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, 256, size=20_000)
        pipe = PrivateDataPipeline(vocab_size=256, eps=2.0, n_queries=64,
                                   T=30, seed=0)
        pipe.fit(tokens)
        eps, delta = pipe.privacy_spent()
        assert 0 < eps < 10 and 0 < delta < 0.1
        batch = pipe.sample_batch(0, 0, 4, 32)
        assert batch.shape == (4, 32)
        assert int(batch.max()) < 256

    def test_release_tracks_distribution(self):
        """The DP histogram should be closer to the truth than uniform."""
        rng = np.random.default_rng(1)
        # concentrated corpus
        tokens = rng.integers(0, 32, size=50_000)
        pipe = PrivateDataPipeline(vocab_size=256, eps=3.0, n_queries=256,
                                   T=200, seed=1)
        pipe.fit(tokens)
        p = np.asarray(pipe.p_hat)
        mass_low = p[:32].sum()
        assert mass_low > 0.2  # uniform would give 0.125; measured ≈ 0.28


class TestPipelineViaService:
    def test_fit_via_service_and_sample(self):
        """The LM data pipeline can source its DP histogram from a shared
        multi-tenant ReleaseService (sampling is post-processing)."""
        from repro.core import MWEMConfig
        from repro.core.queries import ngram_marginal_queries
        from repro.serve import ReleaseService

        V = 128
        Q = ngram_marginal_queries(jax.random.PRNGKey(0), 64, V, arity=32)
        svc = ReleaseService(Q, MWEMConfig(eps=2.0, delta=1e-3, T=20,
                                           mode="fast"), wave_size=2)
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, V, size=10_000)
        pipe = PrivateDataPipeline(vocab_size=V, eps=2.0, T=20, seed=0)
        pipe.fit_via_service(tokens, svc)
        assert pipe.p_hat is not None
        eps, delta = pipe.privacy_spent()
        assert 0 < eps < 10 and 0 < delta < 0.1
        # the pipeline's ledger IS the tenant session's ledger
        assert pipe.ledger is svc.session("pipeline").ledger
        batch = pipe.sample_batch(0, 0, 4, 16)
        assert batch.shape == (4, 16)
        assert int(batch.max()) < V

    def test_fit_via_service_domain_mismatch(self):
        from repro.core import MWEMConfig
        from repro.core.queries import ngram_marginal_queries
        from repro.serve import ReleaseService

        Q = ngram_marginal_queries(jax.random.PRNGKey(0), 32, 64, arity=16)
        svc = ReleaseService(Q, MWEMConfig(eps=1.0, T=5, mode="fast"))
        pipe = PrivateDataPipeline(vocab_size=256)
        with pytest.raises(ValueError, match="vocab_size"):
            pipe.fit_via_service(np.zeros(100, np.int64), svc)


class TestServeEngine:
    def test_batched_waves(self):
        cfg = get_smoke_config("llama3.2-3b").with_(dtype="float32")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=3, max_len=32)
        reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5) for _ in range(5)]
        engine.run(reqs)
        assert all(r.done for r in reqs)
        assert all(len(r.out_tokens) == 5 for r in reqs)
        assert all(0 <= t < cfg.padded_vocab for r in reqs for t in r.out_tokens)

    def test_mid_wave_slot_refill(self):
        """A short request frees its slot mid-wave and a queued request
        refills it (`free_slots`) instead of waiting for a fresh wave."""
        cfg = get_smoke_config("llama3.2-3b").with_(dtype="float32")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=2, max_len=48)
        reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2),   # frees early
                Request(prompt=[4, 5, 6], max_new_tokens=10),
                Request(prompt=[7, 8], max_new_tokens=4)]      # refills slot 0
        engine.run(reqs)
        assert all(r.done for r in reqs)
        assert [len(r.out_tokens) for r in reqs] == [2, 10, 4]
        assert engine.refill_count == 1  # req 3 rode the running wave

    def test_refill_on_recurrent_cache(self):
        """Slot refill also scatters correctly into SSM recurrent caches
        (leaves with no sequence axis)."""
        cfg = get_smoke_config("mamba2-130m").with_(dtype="float32")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=1, max_len=32)
        first = Request(prompt=[2, 4, 6], max_new_tokens=3)
        refilled = Request(prompt=[9, 3, 1], max_new_tokens=4)
        engine.run([first, refilled])
        assert engine.refill_count == 1
        assert first.done and len(first.out_tokens) == 3
        assert refilled.done and len(refilled.out_tokens) == 4
        assert all(0 <= t < cfg.padded_vocab for t in refilled.out_tokens)

    def test_greedy_deterministic(self):
        cfg = get_smoke_config("mamba2-130m").with_(dtype="float32")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=2, max_len=24)
        r1 = [Request(prompt=[5, 6, 7], max_new_tokens=6)]
        r2 = [Request(prompt=[5, 6, 7], max_new_tokens=6)]
        engine.run(r1)
        engine.run(r2)
        assert r1[0].out_tokens == r2[0].out_tokens
