"""Multi-device behaviour (run in subprocesses with forced host devices):
int8 error-feedback all-reduce, distributed Fast-MWEM iteration, dry-run
machinery on a small mesh."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestCompression:
    def test_ring_allreduce_int8_matches_mean(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.train.compression import ring_allreduce_int8
            mesh = jax.make_mesh((8,), ("pod",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            n = 4096
            xs = jax.random.normal(jax.random.PRNGKey(0), (8, n))
            f = shard_map(lambda x: ring_allreduce_int8(x[0], "pod")[None],
                          mesh=mesh, in_specs=P("pod", None),
                          out_specs=P("pod", None), check_rep=False)
            got = np.asarray(f(xs))
            want = np.asarray(xs.mean(0))
            for i in range(8):
                err = np.abs(got[i] - want)
                rel = err.max() / (np.abs(want).max() + 1e-9)
                assert rel < 0.02, rel   # int8 quantization noise only
            print("OK")
        """)
        assert "OK" in out

    def test_error_feedback_reduces_bias(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.train.compression import ef_allreduce_grads
            mesh = jax.make_mesh((4,), ("pod",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 1000))}
            def step(g, err):
                out, st = ef_allreduce_grads({"w": g["w"][0]},
                                             {"ef_error": err[0]}, "pod")
                return out["w"][None], st["ef_error"][None]
            f = shard_map(step, mesh=mesh,
                          in_specs=(P("pod", None), P("pod", None)),
                          out_specs=(P("pod", None), P("pod", None)),
                          check_rep=False)
            err = jnp.zeros((4, 1000))
            acc_true = np.zeros(1000)
            acc_comp = np.zeros(1000)
            for t in range(20):
                g = {"w": jax.random.normal(jax.random.PRNGKey(t), (4, 1000))}
                out, err = f(g, err)
                acc_true += np.asarray(g["w"]).mean(0)
                acc_comp += np.asarray(out)[0]
            # error feedback keeps the *accumulated* signal nearly unbiased
            denom = np.abs(acc_true).mean() + 1e-9
            assert np.abs(acc_comp - acc_true).mean() / denom < 0.05
            print("OK")
        """)
        assert "OK" in out


class TestDistributedMWEM:
    def test_lazy_iteration_runs_and_selects(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np, math
            from repro.core.distributed import (build_distributed_mwem_cell,
                                                make_mwem_iteration)
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            m, U = 1024, 64
            n_data, m_loc = 4, 256
            fn = make_mwem_iteration(mesh, m=m, U=U, nlist=32, cap=16,
                                     nprobe=4, k_loc=16, tail_cap=64,
                                     scale=20.0, eta=0.05, mode="lazy",
                                     multi_pod=False)
            rng = np.random.default_rng(0)
            Q = jnp.asarray(rng.uniform(0, 1, (m, U)), jnp.float32)
            # per-shard IVF stand-in: random centroids + cells
            cents = jnp.asarray(rng.standard_normal((n_data, 32, U)), jnp.float32)
            cells = jnp.asarray(rng.integers(0, m_loc, (n_data, 32, 16)), jnp.int32)
            logw = jnp.zeros((U,))
            h = jnp.asarray(rng.dirichlet(np.ones(U)), jnp.float32)
            key = jax.random.PRNGKey(0)
            with mesh:
                logw2, stats = jax.jit(fn)(Q, cents, cells, logw, h,
                                           jax.random.key_data(key))
            assert logw2.shape == (U,)
            assert 0 <= int(stats["winner"]) < m
            assert np.isfinite(np.asarray(logw2)).all()
            print("OK", int(stats["winner"]), float(stats["n_scored"]))
        """)
        assert "OK" in out

    def test_exhaustive_vs_lazy_collective_volume(self):
        """The lazy iteration must move far fewer collective bytes."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.distributed import make_mwem_iteration
            from repro.analysis.hlo import analyze_hlo
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            # sublinearity needs m_loc ≫ √m_loc·probe width — use a scale
            # where the exhaustive psum of m_loc scores dominates
            m, U = 262144, 64
            vols = {}
            for mode in ("exhaustive", "lazy"):
                fn = make_mwem_iteration(mesh, m=m, U=U, nlist=512, cap=256,
                                         nprobe=4, k_loc=256, tail_cap=1024,
                                         scale=20.0, eta=0.05, mode=mode,
                                         multi_pod=False)
                Q = jax.ShapeDtypeStruct((m, U), jnp.float32)
                cents = jax.ShapeDtypeStruct((4, 512, U), jnp.float32)
                cells = jax.ShapeDtypeStruct((4, 512, 256), jnp.int32)
                w = jax.ShapeDtypeStruct((U,), jnp.float32)
                key = jax.ShapeDtypeStruct((2,), jnp.uint32)
                with mesh:
                    c = jax.jit(fn).lower(Q, cents, cells, w, w, key).compile()
                vols[mode] = analyze_hlo(c.as_text()).collective_bytes
            assert vols["lazy"] < vols["exhaustive"], vols
            print("OK", vols)
        """)
        assert "OK" in out


class TestDryRunMachinery:
    def test_cell_builds_and_compiles_on_small_mesh(self):
        out = _run("""
            import jax, jax.numpy as jnp
            import repro.launch.cells as C
            C.MODEL_DEGREE = 2
            from repro.configs import get_smoke_config
            import repro.launch.cells as cells_mod
            # monkeypatch get_config to the smoke config for a tiny compile
            import repro.configs as cfgs
            orig = cells_mod.get_config
            cells_mod.get_config = lambda name: cfgs.get_smoke_config(name)
            mesh = jax.make_mesh((2, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            from repro.configs.base import SHAPES, ShapeConfig
            SHAPES["train_4k"] = ShapeConfig("train_4k", 64, 8, "train")
            cell = cells_mod.build_cell("llama3-8b", "train_4k", mesh, False)
            with mesh:
                compiled = jax.jit(cell.fn).lower(*cell.args).compile()
            assert compiled.cost_analysis()["flops"] > 0
            print("OK")
        """, devices=4)
        assert "OK" in out


class TestMoEEP:
    def test_ep_matches_dense_path(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.models import mlp as M
            from repro.models.common import sharding_ctx, ParamBuilder
            from repro.configs.base import ShardingRules
            cfg = get_smoke_config("qwen3-moe-30b-a3b").with_(
                dtype="float32", moe_capacity_factor=8.0)
            pb = ParamBuilder(jax.random.PRNGKey(0), dtype=jnp.float32)
            M.init_mlp(pb, cfg, "mlp")
            p = pb.params["mlp"]
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
            y_dense = M.moe_mlp_dense(p, x, cfg)
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            rules = ShardingRules(batch="data", experts="model")
            with mesh:
                y_ep = jax.jit(lambda p, x: M.moe_mlp_ep(p, x, cfg, mesh,
                                                         rules))(p, x)
            # routing identical; combine order differs → fp tolerance
            np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                                       rtol=2e-4, atol=2e-4)
            print("OK")
        """, devices=8)
        assert "OK" in out
