"""Multi-device behaviour (run in subprocesses with forced host devices):
int8 error-feedback all-reduce, the sharded Fast-MWEM driver (host-parity
selections, overflow fallback, ledger totals, service waves on a mesh),
dry-run machinery on a small mesh.

All inline scripts build meshes through `repro.launch.mesh.make_mesh_compat`
— constructing them with ``axis_types=`` directly crashes on JAX versions
without `jax.sharding.AxisType` (the seed-suite failure this file used to
reproduce)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestMeshCompat:
    def test_make_mesh_compat_no_axis_type_attribute_error(self):
        """`make_mesh_compat` must work whether or not the installed JAX
        exposes jax.sharding.AxisType (the seed crash)."""
        out = _run("""
            from repro.launch.mesh import make_mesh_compat, make_driver_mesh
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            assert mesh.shape == {"data": 4, "model": 2}
            mesh2 = make_driver_mesh(8, model_degree=2)
            assert mesh2.shape == {"data": 4, "model": 2}
            print("OK")
        """)
        assert "OK" in out


class TestCompression:
    def test_ring_allreduce_int8_matches_mean(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.train.compression import ring_allreduce_int8
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((8,), ("pod",))
            n = 4096
            xs = jax.random.normal(jax.random.PRNGKey(0), (8, n))
            f = shard_map(lambda x: ring_allreduce_int8(x[0], "pod")[None],
                          mesh=mesh, in_specs=P("pod", None),
                          out_specs=P("pod", None), check_rep=False)
            got = np.asarray(f(xs))
            want = np.asarray(xs.mean(0))
            for i in range(8):
                err = np.abs(got[i] - want)
                rel = err.max() / (np.abs(want).max() + 1e-9)
                assert rel < 0.02, rel   # int8 quantization noise only
            print("OK")
        """)
        assert "OK" in out

    def test_error_feedback_reduces_bias(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.train.compression import ef_allreduce_grads
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((4,), ("pod",))
            grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 1000))}
            def step(g, err):
                out, st = ef_allreduce_grads({"w": g["w"][0]},
                                             {"ef_error": err[0]}, "pod")
                return out["w"][None], st["ef_error"][None]
            f = shard_map(step, mesh=mesh,
                          in_specs=(P("pod", None), P("pod", None)),
                          out_specs=(P("pod", None), P("pod", None)),
                          check_rep=False)
            err = jnp.zeros((4, 1000))
            acc_true = np.zeros(1000)
            acc_comp = np.zeros(1000)
            for t in range(20):
                g = {"w": jax.random.normal(jax.random.PRNGKey(t), (4, 1000))}
                out, err = f(g, err)
                acc_true += np.asarray(g["w"]).mean(0)
                acc_comp += np.asarray(out)[0]
            # error feedback keeps the *accumulated* signal nearly unbiased
            denom = np.abs(acc_true).mean() + 1e-9
            assert np.abs(acc_comp - acc_true).mean() / denom < 0.05
            print("OK")
        """)
        assert "OK" in out


class TestDistributedMWEM:
    def test_lazy_iteration_runs_and_selects(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np, math
            from repro.core.distributed import make_mwem_iteration
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            m, U = 1024, 64
            n_data, m_loc = 4, 256
            fn = make_mwem_iteration(mesh, m=m, U=U, nlist=32, cap=16,
                                     nprobe=4, k_loc=16, tail_cap=64,
                                     scale=20.0, eta=0.05, mode="lazy",
                                     multi_pod=False)
            rng = np.random.default_rng(0)
            Q = jnp.asarray(rng.uniform(0, 1, (m, U)), jnp.float32)
            # per-shard IVF stand-in: random centroids + cells
            cents = jnp.asarray(rng.standard_normal((n_data, 32, U)), jnp.float32)
            cells = jnp.asarray(rng.integers(0, m_loc, (n_data, 32, 16)), jnp.int32)
            logw = jnp.zeros((U,))
            h = jnp.asarray(rng.dirichlet(np.ones(U)), jnp.float32)
            key = jax.random.PRNGKey(0)
            with mesh:
                logw2, stats = jax.jit(fn)(Q, cents, cells, logw, h,
                                           jax.random.key_data(key))
            assert logw2.shape == (U,)
            assert 0 <= int(stats["winner"]) < m
            assert not bool(stats["overflow"])
            # scored work excludes nothing here (all cell slots valid) but
            # must stay well below m
            assert float(stats["n_scored"]) < m
            assert np.isfinite(np.asarray(logw2)).all()
            print("OK", int(stats["winner"]), float(stats["n_scored"]))
        """)
        assert "OK" in out

    def test_invalid_cell_slots_not_counted_as_scored(self):
        """Padded (-1) cell slots cost no FLOPs and must not inflate
        n_scored (the overcount bug)."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.distributed import make_mwem_iteration
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((2, 2), ("data", "model"))
            m, U, nlist, cap, nprobe = 256, 32, 16, 16, 4
            n_data, m_loc = 2, 128
            # scale ≫ Gumbel spread → the tail margin B is huge, C = 0, and
            # the scored-row count is deterministic
            fn = make_mwem_iteration(mesh, m=m, U=U, nlist=nlist, cap=cap,
                                     nprobe=nprobe, k_loc=8, tail_cap=32,
                                     scale=1000.0, eta=0.05, mode="lazy",
                                     multi_pod=False)
            rng = np.random.default_rng(0)
            Q = jnp.asarray(rng.uniform(0, 1, (m, U)), jnp.float32)
            cents = jnp.asarray(rng.standard_normal((n_data, nlist, U)),
                                jnp.float32)
            # half of every cell is padding (-1)
            cells = np.full((n_data, nlist, cap), -1, np.int32)
            cells[:, :, :cap // 2] = rng.integers(
                0, m_loc, (n_data, nlist, cap // 2))
            cells = jnp.asarray(cells)
            logw = jnp.zeros((U,))
            h = jnp.asarray(rng.dirichlet(np.ones(U)), jnp.float32)
            with mesh:
                _, stats = jax.jit(fn)(Q, cents, cells, logw, h,
                    jax.random.key_data(jax.random.PRNGKey(0)))
            # per shard: nlist centroids + exactly the valid half of the
            # probed slots, no tail; the old code charged the full
            # nprobe·cap regardless of padding
            expected = n_data * (nlist + nprobe * (cap // 2))
            assert float(stats["n_scored"]) == expected, \\
                (float(stats["n_scored"]), expected)
            print("OK", float(stats["n_scored"]))
        """, devices=4)
        assert "OK" in out

    def test_exhaustive_vs_lazy_collective_volume(self):
        """The lazy iteration must move far fewer collective bytes."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.distributed import make_mwem_iteration
            from repro.analysis.hlo import analyze_hlo
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            # sublinearity needs m_loc ≫ √m_loc·probe width — use a scale
            # where the exhaustive psum of m_loc scores dominates
            m, U = 262144, 64
            vols = {}
            for mode in ("exhaustive", "lazy"):
                # fallback=False: measure the hot path — the static
                # analyzer would otherwise weigh the e^{-Ω(√m)}-rare
                # overflow branch (a full Θ(m) psum) at 1×
                fn = make_mwem_iteration(mesh, m=m, U=U, nlist=512, cap=256,
                                         nprobe=4, k_loc=256, tail_cap=1024,
                                         scale=20.0, eta=0.05, mode=mode,
                                         multi_pod=False, fallback=False)
                Q = jax.ShapeDtypeStruct((m, U), jnp.float32)
                cents = jax.ShapeDtypeStruct((4, 512, U), jnp.float32)
                cells = jax.ShapeDtypeStruct((4, 512, 256), jnp.int32)
                w = jax.ShapeDtypeStruct((U,), jnp.float32)
                key = jax.ShapeDtypeStruct((2,), jnp.uint32)
                with mesh:
                    c = jax.jit(fn).lower(Q, cents, cells, w, w, key).compile()
                vols[mode] = analyze_hlo(c.as_text()).collective_bytes
            assert vols["lazy"] < vols["exhaustive"], vols
            print("OK", vols)
        """)
        assert "OK" in out


class TestShardedDriver:
    def test_exact_mode_matches_host_selections_and_ledger(self):
        """Acceptance: on a forced 8-device mesh the sharded driver makes
        the same selections and charges the same ledger totals as the host
        driver on identical inputs."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import MWEMConfig, run_mwem, run_mwem_sharded
            from repro.core.queries import (gaussian_histogram,
                                            random_binary_queries)
            from repro.launch.mesh import make_mesh_compat
            kh, kq = jax.random.split(jax.random.PRNGKey(0))
            U, m, n = 64, 512, 300
            h = gaussian_histogram(kh, n, U)
            Q = random_binary_queries(kq, m, U)
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            cfg = MWEMConfig(T=8, mode="exact", n_records=n)
            cfg_host = MWEMConfig(T=8, mode="exact", n_records=n,
                                  driver="host")
            rs = run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(3), mesh=mesh)
            rh = run_mwem(Q, h, cfg_host, jax.random.PRNGKey(3))
            assert rs.selected == rh.selected, (rs.selected, rh.selected)
            assert rs.n_scored == rh.n_scored
            assert rs.ledger.composed() == rh.ledger.composed()
            assert rs.ledger.basic() == rh.ledger.basic()
            assert len(rs.ledger.events) == len(rh.ledger.events)
            np.testing.assert_allclose(np.asarray(rs.p_hat),
                                       np.asarray(rh.p_hat), atol=1e-5)
            assert abs(rs.final_error - rh.final_error) < 1e-5
            print("OK")
        """)
        assert "OK" in out

    def test_lazy_mode_sublinear_scoring_and_ledger_parity(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import (MWEMConfig, release_cost, run_mwem,
                                    run_mwem_sharded)
            from repro.core.accountant import PrivacyLedger
            from repro.core.queries import (gaussian_histogram,
                                            random_binary_queries)
            from repro.mips import (IVFIndex, ShardedIVFIndex,
                                    augment_complement)
            from repro.launch.mesh import make_mesh_compat
            kh, kq = jax.random.split(jax.random.PRNGKey(0))
            U, m, n = 64, 512, 300
            h = gaussian_histogram(kh, n, U)
            Q = random_binary_queries(kq, m, U)
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            idx = ShardedIVFIndex(Q, n_shards=4, seed=0)
            cfg = MWEMConfig(T=10, mode="fast", n_records=n)
            rs = run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(5),
                                  mesh=mesh, index=idx)
            assert all(0 <= s < m for s in rs.selected)
            assert rs.overflow_count == 0
            # Θ(√m)-ish scoring: every iteration touches far fewer rows
            assert max(rs.n_scored) < m * 0.75, rs.n_scored
            # ledger totals: exactly the previewed release cost, and equal
            # to the host driver's totals with a same-γ index
            exp = PrivacyLedger().preview(*release_cost(cfg, m, U, index=idx))
            assert rs.ledger.composed() == exp
            host_idx = IVFIndex(augment_complement(np.asarray(Q)), seed=0,
                                failure_mass=idx.failure_mass)
            cfg_host = MWEMConfig(T=10, mode="fast", n_records=n,
                                  driver="host")
            rh = run_mwem(Q, h, cfg_host, jax.random.PRNGKey(5),
                          index=host_idx)
            assert rs.ledger.composed() == rh.ledger.composed()
            assert rs.ledger.basic() == rh.ledger.basic()
            # both drivers beat the uniform baseline on the same workload
            from repro.core.queries import max_error
            uniform = float(max_error(Q, h, jnp.full_like(h, 1 / U)))
            assert rs.final_error < uniform
            print("OK", rs.n_scored)
        """)
        assert "OK" in out

    def test_kernelized_probe_matches_xla_probe(self):
        """The fused `kernels.ivf_probe` per-shard probe (use_pallas,
        interpret mode off-TPU, valid only at model extent 1) must leave
        the sharded driver's selections and scored-rows traces unchanged
        vs the XLA gather probe."""
        out = _run("""
            import jax
            from repro.core import MWEMConfig, run_mwem_sharded
            from repro.core.queries import (gaussian_histogram,
                                            random_binary_queries)
            from repro.mips import ShardedIVFIndex
            from repro.launch.mesh import make_mesh_compat
            kh, kq = jax.random.split(jax.random.PRNGKey(0))
            U, m, n = 32, 128, 300
            h = gaussian_histogram(kh, n, U)
            Q = random_binary_queries(kq, m, U)
            mesh = make_mesh_compat((2, 1), ("data", "model"))
            cfg = MWEMConfig(T=5, mode="fast", n_records=n)
            ix_x = ShardedIVFIndex(Q, n_shards=2, seed=0, train_iters=3,
                                   use_pallas="never")
            ix_p = ShardedIVFIndex(Q, n_shards=2, seed=0, train_iters=3,
                                   use_pallas="always")
            rx = run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(3),
                                  mesh=mesh, index=ix_x)
            rp = run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(3),
                                  mesh=mesh, index=ix_p)
            assert rx.selected == rp.selected, (rx.selected, rp.selected)
            assert rx.n_scored == rp.n_scored
            assert abs(rx.final_error - rp.final_error) < 1e-6
            # model-sharded meshes silently fall back to the XLA probe
            mesh2 = make_mesh_compat((2, 2), ("data", "model"))
            r2 = run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(3),
                                  mesh=mesh2, index=ShardedIVFIndex(
                                      Q, n_shards=2, seed=0, train_iters=3,
                                      use_pallas="always"))
            assert all(0 <= s < m for s in r2.selected)
            print("OK")
        """, devices=4)
        assert "OK" in out

    def test_overflow_falls_back_to_exhaustive_exactly(self):
        """tail_cap=1 forces every shard's binomial past the buffer; the
        iteration must lax.cond into the exhaustive per-shard scan — which
        redraws under `lazy_em.fallback_key(k_sel)` (the lazy pass already
        consumed k_sel's stream), the same fold the host driver applies.
        With every step overflowing, the index never decides anything, so
        the sharded run matches the host fast-mode driver selection-for-
        selection."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import MWEMConfig, run_mwem, run_mwem_sharded
            from repro.core.queries import (gaussian_histogram,
                                            random_binary_queries)
            from repro.mips import (IVFIndex, ShardedIVFIndex,
                                    augment_complement)
            from repro.launch.mesh import make_mesh_compat
            kh, kq = jax.random.split(jax.random.PRNGKey(0))
            U, m, n = 64, 512, 300
            h = gaussian_histogram(kh, n, U)
            Q = random_binary_queries(kq, m, U)
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            idx = ShardedIVFIndex(Q, n_shards=4, seed=0)
            T = 6
            cfg = MWEMConfig(T=T, mode="fast", n_records=n, tail_cap=1)
            rs = run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(7),
                                  mesh=mesh, index=idx)
            assert rs.overflow_count == T
            assert rs.n_scored == [m] * T  # fallback scores every row
            hidx = IVFIndex(augment_complement(np.asarray(Q)), seed=0,
                            train_iters=4)
            cfg_h = MWEMConfig(T=T, mode="fast", n_records=n, tail_cap=1,
                               driver="host")
            rh = run_mwem(Q, h, cfg_h, jax.random.PRNGKey(7), index=hidx)
            assert rh.overflow_count == T  # same all-overflow regime
            assert rs.selected == rh.selected, (rs.selected, rh.selected)
            print("OK")
        """)
        assert "OK" in out

    def test_routing_and_batch(self):
        """driver="auto" picks the sharded driver on a multi-device mesh;
        `run_mwem_sharded_batch` lanes match standalone runs."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import (MWEMConfig, run_mwem, run_mwem_sharded,
                                    run_mwem_sharded_batch)
            from repro.core.accountant import PrivacyLedger
            from repro.core.mwem import _resolve_driver
            from repro.core.queries import (gaussian_histogram,
                                            random_binary_queries)
            from repro.mips import FlatAbsIndex, ShardedIVFIndex
            from repro.launch.mesh import make_mesh_compat
            kh, kq = jax.random.split(jax.random.PRNGKey(0))
            U, m, n = 32, 256, 300
            h = gaussian_histogram(kh, n, U)
            Q = random_binary_queries(kq, m, U)
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            idx = ShardedIVFIndex(Q, n_shards=4, seed=0)
            # auto routing: >1 device + shardable workload → sharded
            assert _resolve_driver(MWEMConfig(mode="exact", n_records=n),
                                   None) == "sharded"
            assert _resolve_driver(MWEMConfig(n_records=n), idx) == "sharded"
            # a non-sharded index keeps the fused driver even multi-device
            flat = FlatAbsIndex(Q)
            assert _resolve_driver(MWEMConfig(n_records=n), flat) == "fused"
            cfg = MWEMConfig(T=4, mode="fast", n_records=n)
            r = run_mwem(Q, h, cfg, jax.random.PRNGKey(1), index=idx,
                         mesh=mesh)
            assert all(0 <= s < m for s in r.selected)
            # batch: lanes reproduce standalone runs and charge per-lane
            keys = jnp.stack([jax.random.PRNGKey(s) for s in range(3)])
            lanes = [PrivacyLedger(), None, PrivacyLedger()]
            batch = run_mwem_sharded_batch(Q, h, cfg, keys, mesh=mesh,
                                           index=idx, ledgers=lanes)
            solo = run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(1),
                                    mesh=mesh, index=idx)
            assert list(batch.selected[1]) == solo.selected
            np.testing.assert_allclose(np.asarray(batch.p_hat[1]),
                                       np.asarray(solo.p_hat), atol=1e-6)
            assert lanes[0].composed() == batch.ledger.composed()
            assert lanes[2].composed() == batch.ledger.composed()
            print("OK")
        """)
        assert "OK" in out

    def test_service_waves_dispatch_on_mesh(self):
        """ReleaseService with a mesh: wave lanes run the sharded driver,
        tenants are charged per lane, and releases match standalone sharded
        runs with the same seed."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import MWEMConfig, run_mwem_sharded
            from repro.core.accountant import PrivacyLedger
            from repro.core.mwem import release_cost
            from repro.serve import ReleaseService
            from repro.launch.mesh import make_mesh_compat
            kh, kq = jax.random.split(jax.random.PRNGKey(0))
            U, m, n = 32, 256, 300
            from repro.core.queries import (gaussian_histogram,
                                            random_binary_queries)
            h = np.asarray(gaussian_histogram(kh, n, U))
            Q = random_binary_queries(kq, m, U)
            mesh = make_mesh_compat((4, 2), ("data", "model"))
            cfg = MWEMConfig(eps=0.5, delta=1e-3, T=4, mode="fast")
            svc = ReleaseService(Q, cfg, wave_size=2, mesh=mesh,
                                 auto_flush=False)
            for name in ("a", "b"):
                svc.create_session(name, eps_budget=50.0, delta_budget=0.5,
                                   h=h, n_records=n)
            ta = svc.submit("a", seed=11)
            tb = svc.submit("b", seed=12)
            svc.flush()
            assert ta.status == tb.status == "done"
            assert svc.stats.dispatches == 1
            gcfg = svc._group_cfg(n)
            for name, seed in (("a", 11), ("b", 12)):
                solo = run_mwem_sharded(Q, jnp.asarray(h), gcfg,
                                        jax.random.PRNGKey(seed), mesh=mesh,
                                        index=svc.index)
                rel = svc.session(name).latest
                np.testing.assert_allclose(np.asarray(rel.p_hat),
                                           np.asarray(solo.p_hat), atol=1e-6)
                # charged exactly the previewed bundle
                exp = PrivacyLedger().preview(
                    *release_cost(gcfg, m, U, index=svc.index))
                assert svc.session(name).ledger.composed() == exp
                assert rel.eps_cost == ta.decision.eps_cost
            print("OK")
        """)
        assert "OK" in out


class TestDryRunMachinery:
    def test_cell_builds_and_compiles_on_small_mesh(self):
        out = _run("""
            import jax, jax.numpy as jnp
            import repro.launch.cells as C
            C.MODEL_DEGREE = 2
            from repro.configs import get_smoke_config
            import repro.launch.cells as cells_mod
            # monkeypatch get_config to the smoke config for a tiny compile
            import repro.configs as cfgs
            from repro.launch.mesh import make_mesh_compat
            orig = cells_mod.get_config
            cells_mod.get_config = lambda name: cfgs.get_smoke_config(name)
            mesh = make_mesh_compat((2, 2), ("data", "model"))
            from repro.configs.base import SHAPES, ShapeConfig
            SHAPES["train_4k"] = ShapeConfig("train_4k", 64, 8, "train")
            cell = cells_mod.build_cell("llama3-8b", "train_4k", mesh, False)
            with mesh:
                compiled = jax.jit(cell.fn).lower(*cell.args).compile()
            assert compiled.cost_analysis()
            print("OK")
        """, devices=4)
        assert "OK" in out

    def test_paper_cell_lowers_both_modes(self):
        """The dry-run cell is built on the real driver (`make_mwem_scan`)
        and must lower/compile in both modes on a small mesh."""
        out = _run("""
            import jax
            from repro.core.distributed import build_distributed_mwem_cell
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((2, 2), ("data", "model"))
            for mode in ("exhaustive", "lazy"):
                fn, args, meta = build_distributed_mwem_cell(
                    mesh, False, mode=mode, m=2**14, U=2**8)
                with mesh:
                    compiled = jax.jit(fn).lower(*args).compile()
                assert meta["mode"] == mode and meta["T"] == 1
            print("OK")
        """, devices=4)
        assert "OK" in out


class TestMoEEP:
    def test_ep_matches_dense_path(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.models import mlp as M
            from repro.models.common import sharding_ctx, ParamBuilder
            from repro.configs.base import ShardingRules
            from repro.launch.mesh import make_mesh_compat
            cfg = get_smoke_config("qwen3-moe-30b-a3b").with_(
                dtype="float32", moe_capacity_factor=8.0)
            pb = ParamBuilder(jax.random.PRNGKey(0), dtype=jnp.float32)
            M.init_mlp(pb, cfg, "mlp")
            p = pb.params["mlp"]
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
            y_dense = M.moe_mlp_dense(p, x, cfg)
            mesh = make_mesh_compat((2, 4), ("data", "model"))
            rules = ShardingRules(batch="data", experts="model")
            with mesh:
                y_ep = jax.jit(lambda p, x: M.moe_mlp_ep(p, x, cfg, mesh,
                                                         rules))(p, x)
            # routing identical; combine order differs → fp tolerance
            np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                                       rtol=2e-4, atol=2e-4)
            print("OK")
        """, devices=8)
        assert "OK" in out
