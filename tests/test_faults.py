"""Chaos suite for the fault-tolerant serving tier (DESIGN.md §10).

Sweeps fault-site × schedule through the release service and pins the
invariants the two-phase budget commit promises:

* no budget leak — after a flush, every reservation is resolved (committed
  or refunded) and the ledger holds exactly the delivered releases' events;
* no double charge — a retried wave commits exactly once, and its ledger
  equals a clean (fault-free) run's bitwise;
* retry determinism — lanes are keyed by ``PRNGKey(ticket.seed)``, so a
  retried wave's released artifacts equal the clean run's bitwise (mwem
  and LP);
* journal replay — `recover()` rebuilds sessions whose ledgers equal the
  live service's, and resolves in-doubt reservations conservatively.

``CHAOS_SEED`` (CI matrix {0,1,2}) seeds the probabilistic schedules so
the sweep explores different failure interleavings per lane.
"""

import os
from contextlib import nullcontext

import numpy as np
import pytest

import jax

from repro.core import MWEMConfig
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.faults import (FaultInjected, FaultPlan, Schedule, fail_once,
                          fault_site, inject)
from repro.obs.metrics import MetricsRegistry
from repro.serve import (LoadSpec, ReleaseService, ScriptedPolicy, recover,
                         run_open_loop)
from repro.serve.journal import Journal, read_records

U, M, N_RECORDS = 64, 128, 300
CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


def make_workload():
    key = jax.random.PRNGKey(7)
    kh, kq = jax.random.split(key)
    h = gaussian_histogram(kh, N_RECORDS, U)
    Q = random_binary_queries(kq, M, U)
    return Q, np.asarray(h)


@pytest.fixture(scope="module")
def workload():
    return make_workload()


def make_service(Q, **kw):
    kw.setdefault("wave_size", 2)
    kw.setdefault("auto_flush", False)
    kw.setdefault("backoff_base", 1e-4)
    kw.setdefault("registry", MetricsRegistry())
    cfg = MWEMConfig(eps=0.5, delta=1e-3, T=6, mode="fast")
    return ReleaseService(Q, cfg, **kw)


def add_tenant(svc, h, name="t0", eps_budget=50.0, delta_budget=0.5):
    return svc.create_session(name, eps_budget=eps_budget,
                              delta_budget=delta_budget, h=h,
                              n_records=N_RECORDS)


def assert_no_budget_leak(svc):
    """Σ committed == Σ delivered lane costs, and nothing is left held:
    each session's ledger carries exactly the event schedules of its
    delivered (status == "done") tickets, with zero open reservations."""
    by_tenant = {}
    for group in list(svc._pending.values()) + (
            [svc.lp.pending] if svc.lp is not None else []):
        for t in group:
            by_tenant.setdefault(t.tenant_id, []).append(t)
    for sess in svc.sessions.values():
        assert not sess.ledger.reservations, (
            f"leaked reservations: {sess.ledger.reservations}")


def delivered_event_count(tickets, tenant_id):
    return sum(len(t.cost_bundle[0]) for t in tickets
               if t.tenant_id == tenant_id and t.status == "done")


# --------------------------------------------------------------------------
# harness unit tests
# --------------------------------------------------------------------------
class TestHarness:
    def test_disarmed_is_noop(self):
        fault_site("wave.dispatch")  # nothing armed: must not raise

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultPlan({"no.such.site": fail_once()})

    def test_fail_n_schedule(self):
        with inject({"ledger.commit": Schedule(fail_n=2)}) as plan:
            for expected in (True, True, False, False):
                if expected:
                    with pytest.raises(FaultInjected) as ei:
                        fault_site("ledger.commit")
                    assert ei.value.site == "ledger.commit"
                else:
                    fault_site("ledger.commit")
        assert plan.hits["ledger.commit"] == 4
        assert plan.failures["ledger.commit"] == 2
        # the plan is disarmed again outside the block
        fault_site("ledger.commit")

    def test_fail_rate_deterministic_across_plans(self):
        def draw(n=64):
            out = []
            with inject({"index.probe": Schedule(fail_rate=0.5,
                                                 seed=CHAOS_SEED)}):
                for _ in range(n):
                    try:
                        fault_site("index.probe")
                        out.append(False)
                    except FaultInjected:
                        out.append(True)
            return out
        a, b = draw(), draw()
        assert a == b          # same seed ⇒ same failure sequence
        assert any(a) and not all(a)

    def test_sites_draw_independently_from_one_seed(self):
        sched = Schedule(fail_rate=0.5, seed=CHAOS_SEED)
        seqs = {}
        for site in ("wave.dispatch", "index.probe"):
            with inject({site: sched}):
                seq = []
                for _ in range(64):
                    try:
                        fault_site(site)
                        seq.append(False)
                    except FaultInjected:
                        seq.append(True)
                seqs[site] = seq
        assert seqs["wave.dispatch"] != seqs["index.probe"]

    def test_latency_schedule_sleeps_through_clock(self, monkeypatch):
        slept = []
        monkeypatch.setattr("repro.obs.clock.sleep",
                            lambda s: slept.append(s))
        with inject({"journal.append": Schedule(latency=0.25)}):
            fault_site("journal.append")  # latency without failure
        assert slept == [0.25]


# --------------------------------------------------------------------------
# fault-site × schedule sweep through live waves
# --------------------------------------------------------------------------
SWEEP = [
    ("wave.dispatch", Schedule(fail_n=1)),
    ("wave.dispatch", Schedule(fail_n=2)),
    ("wave.dispatch", Schedule(fail_rate=0.5, seed=CHAOS_SEED)),
    ("wave.dispatch", Schedule(fail_n=1, latency=0.005)),
    ("ledger.commit", Schedule(fail_n=1)),
    ("journal.append", Schedule(fail_n=1)),
    ("kernel.mwem_step", Schedule(fail_n=1)),
    ("index.probe", Schedule(fail_n=1)),
]


class TestChaosSweep:
    @pytest.mark.parametrize(
        "site,sched", SWEEP,
        ids=[f"{s}-{'fail_n' + str(sc.fail_n) if sc.fail_n else 'rate'}"
             f"{'-lat' if sc.latency else ''}" for s, sc in SWEEP])
    def test_invariants_under_fault(self, workload, tmp_path, site, sched):
        Q, h = workload
        svc = make_service(Q, journal=Journal(tmp_path / "wal.jsonl"))
        add_tenant(svc, h)
        tickets = [svc.submit("t0", seed=100 + i) for i in range(4)]
        assert all(t.status == "queued" for t in tickets)
        with inject({site: sched}) as plan:
            svc.flush()
        assert plan.hits[site] >= 1, f"site {site} never exercised"
        assert_no_budget_leak(svc)
        # every ticket resolved one way or the other — none stranded
        assert all(t.status in ("done", "failed") for t in tickets)
        assert all(t.rid is None for t in tickets)
        sess = svc.session("t0")
        assert len(sess.ledger.events) == delivered_event_count(
            tickets, "t0")
        # journal replay reproduces the live ledger exactly
        rec = recover(svc.journal.path, registry=svc.metrics)
        assert rec.sessions["t0"].ledger == sess.ledger
        if any(t.status == "failed" for t in tickets):
            assert svc.stats.failed > 0
        if svc.stats.retries:
            assert svc.metrics.counter("wave_retries_total",
                                       kind="mwem").value > 0
            assert svc.metrics.counter("dispatch_failures_total",
                                       site=site).value > 0

    @pytest.mark.parametrize("tight", [False, True])
    def test_retry_wave_bitwise_equals_clean_mwem(self, workload, tight):
        Q, h = workload

        def run(schedules):
            svc = make_service(Q, tight_composition=tight)
            add_tenant(svc, h)
            for i in range(2):
                svc.submit("t0", seed=40 + i)
            with (inject(schedules) if schedules else nullcontext()):
                done = svc.flush()
            return svc, done

        svc_clean, done_clean = run(None)
        svc_retry, done_retry = run({"wave.dispatch": Schedule(fail_n=2)})
        assert svc_retry.stats.retries == 2
        assert [t.status for t in done_retry] == ["done", "done"]
        for a, b in zip(done_clean, done_retry):
            np.testing.assert_array_equal(a.release.p_hat, b.release.p_hat)
            assert a.release.eps_cost == b.release.eps_cost
        # retries are privacy-free: the ledgers are equal, not just close
        assert (svc_clean.session("t0").ledger
                == svc_retry.session("t0").ledger)
        assert (svc_clean.session("t0").ledger.composed(tight=tight)
                == svc_retry.session("t0").ledger.composed(tight=tight))

    def test_retry_wave_bitwise_equals_clean_lp(self, workload):
        Q, h = workload
        A = np.abs(np.asarray(Q[:8]))
        b = np.full(8, 0.9, np.float32)

        def run(schedules):
            svc = make_service(Q)
            svc.attach_lp(A, b)
            add_tenant(svc, h)
            for i in range(2):
                svc.submit_lp("t0", seed=60 + i)
            with (inject(schedules) if schedules else nullcontext()):
                done = svc.flush()
            return svc, done

        svc_clean, done_clean = run(None)
        svc_retry, done_retry = run({"wave.dispatch": fail_once()})
        assert svc_retry.stats.retries == 1
        for a, b_t in zip(done_clean, done_retry):
            np.testing.assert_array_equal(a.release.x_bar, b_t.release.x_bar)
        assert (svc_clean.session("t0").ledger
                == svc_retry.session("t0").ledger)

    def test_exhausted_retries_fail_and_refund(self, workload):
        Q, h = workload
        svc = make_service(Q, retry_limit=1)
        add_tenant(svc, h)
        tickets = [svc.submit("t0", seed=i) for i in range(2)]
        with inject({"wave.dispatch": Schedule(fail_n=10)}):
            done = svc.flush()
        assert done == []
        assert all(t.status == "failed" for t in tickets)
        assert all("FaultInjected" in t.error for t in tickets)
        sess = svc.session("t0")
        assert sess.ledger.events == [] and not sess.ledger.reservations
        assert svc.stats.failed == 2
        assert svc.metrics.counter("reservations_aborted_total",
                                   reason="failed").value == 2
        # the queue group is gone — the next submit starts clean
        t = svc.submit("t0")
        assert t.status == "queued"
        svc.flush()
        assert t.status == "done"

    def test_non_retryable_error_propagates(self, workload):
        Q, h = workload
        svc = make_service(Q)
        add_tenant(svc, h)
        ticket = svc.submit("t0")

        def boom(*a, **k):
            raise ValueError("shape mismatch — a bug, not a fault")

        import repro.serve.release_service as rs_mod
        orig = rs_mod.run_mwem_batch
        rs_mod.run_mwem_batch = boom
        try:
            with pytest.raises(ValueError, match="a bug"):
                svc.flush()
        finally:
            rs_mod.run_mwem_batch = orig
        assert ticket.status == "failed"
        assert svc.stats.retries == 0  # bugs never burn the retry budget
        assert not svc.session("t0").ledger.reservations


# --------------------------------------------------------------------------
# phase-two (commit/journal) failures must never strand popped tickets
# --------------------------------------------------------------------------
class TestPhaseTwoFailures:
    def test_commit_failure_resolves_rest_of_wave(self, workload):
        """A ledger-commit failure that exhausts its retries fails that
        ticket alone (reservation refunded) — the rest of the wave still
        delivers, and nothing is left holding a reservation."""
        Q, h = workload
        svc = make_service(Q, retry_limit=0)
        add_tenant(svc, h)
        t0 = svc.submit("t0", seed=1)
        t1 = svc.submit("t0", seed=2)
        with inject({"ledger.commit": Schedule(fail_n=1)}):
            svc.flush()
        assert t0.status == "failed" and t0.rid is None
        assert t0.release is None and "FaultInjected" in t0.error
        assert t1.status == "done" and t1.rid is None
        sess = svc.session("t0")
        assert not sess.ledger.reservations
        assert len(sess.ledger.events) == len(t1.cost_bundle[0])
        assert svc.stats.failed == 1 and svc.stats.released == 1
        assert svc.metrics.counter("reservations_aborted_total",
                                   reason="commit-failed").value == 1

    def test_phase_two_bug_fails_remaining_wave(self, workload):
        """A programming error in phase two resolves every remaining
        ticket (refunded) before propagating — no stranded reservations."""
        Q, h = workload
        svc = make_service(Q)
        add_tenant(svc, h)
        t0 = svc.submit("t0", seed=1)
        t1 = svc.submit("t0", seed=2)

        def boom(ticket):
            raise ValueError("phase-two bug")

        svc._commit_ticket = boom
        with pytest.raises(ValueError, match="phase-two bug"):
            svc.flush()
        assert t0.status == "failed" and t1.status == "failed"
        assert t0.rid is None and t1.rid is None
        sess = svc.session("t0")
        assert sess.ledger.events == [] and not sess.ledger.reservations
        assert svc.pending_count() == 0

    def test_journal_failure_in_phase_two_does_not_strand(
            self, workload, tmp_path):
        """The WAL dies on one ticket's ``committed`` append, after the
        ledger already moved: the charge stands (recovery's in-doubt rule
        agrees — replay equals live), the ticket fails without a release,
        and the rest of the wave still delivers."""
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        svc = make_service(Q, retry_limit=0, journal=Journal(path))
        add_tenant(svc, h)
        t0 = svc.submit("t0", seed=1)
        t1 = svc.submit("t0", seed=2)
        rid0 = t0.rid
        orig_append = svc.journal.append

        def flaky(rec_kind, **payload):
            if rec_kind == "committed" and payload["rid"] == rid0:
                raise OSError("disk full")
            return orig_append(rec_kind, **payload)

        svc.journal.append = flaky
        svc.flush()
        assert t0.status == "failed" and t0.rid is None
        assert t0.release is None
        assert t1.status == "done"
        sess = svc.session("t0")
        assert not sess.ledger.reservations
        # t0's charge stands even though its ticket failed (in-doubt rule)
        assert len(sess.ledger.events) == (len(t0.cost_bundle[0])
                                           + len(t1.cost_bundle[0]))
        svc.journal.close()
        rec = recover(path)
        assert rec.in_doubt == [("t0", rid0)]
        assert rec.sessions["t0"].ledger == sess.ledger
        assert len(rec.sessions["t0"].releases) == 1

    def test_submit_journal_failure_is_budget_neutral(
            self, workload, tmp_path):
        """A ``reserved`` append that exhausts its retries refunds the
        just-taken reservation before re-raising: the failed submit holds
        no budget and queues nothing."""
        Q, h = workload
        svc = make_service(Q, retry_limit=0,
                           journal=Journal(tmp_path / "wal.jsonl"))
        add_tenant(svc, h)
        with inject({"journal.append": Schedule(fail_n=10)}):
            with pytest.raises(FaultInjected):
                svc.submit("t0", seed=1)
        sess = svc.session("t0")
        assert not sess.ledger.reservations
        assert svc.pending_count() == 0
        # the WAL recovered: the tenant resubmits at full budget
        t = svc.submit("t0", seed=2)
        assert t.status == "queued"
        svc.flush()
        assert t.status == "done"
        rec = recover(svc.journal.path)
        assert rec.sessions["t0"].ledger == sess.ledger

    def test_submit_lp_journal_failure_is_budget_neutral(
            self, workload, tmp_path):
        Q, h = workload
        svc = make_service(Q, retry_limit=0,
                           journal=Journal(tmp_path / "wal.jsonl"))
        svc.attach_lp(np.abs(np.asarray(Q[:8])), np.full(8, 0.9, np.float32))
        add_tenant(svc, h)
        with inject({"journal.append": Schedule(fail_n=10)}):
            with pytest.raises(FaultInjected):
                svc.submit_lp("t0", seed=1)
        assert not svc.session("t0").ledger.reservations
        assert svc.pending_count() == 0
        t = svc.submit_lp("t0", seed=2)
        svc.flush()
        assert t.status == "done"

    def test_journal_failure_does_not_feed_breaker(self, workload, tmp_path):
        """A persistent WAL failure at dispatch propagates with the queue
        and reservations intact — it is not a kernel fault, so it must
        not trip the breaker into a permanent reference-path degrade."""
        Q, h = workload
        svc = make_service(Q, retry_limit=0, breaker_threshold=1,
                           journal=Journal(tmp_path / "wal.jsonl"))
        add_tenant(svc, h)
        t = svc.submit("t0", seed=9)
        with inject({"journal.append": Schedule(fail_n=10)}):
            with pytest.raises(FaultInjected):
                svc.flush()
        assert t.status == "queued" and t.rid is not None
        assert not svc.breaker.is_open and not svc.degraded
        assert svc.breaker.consecutive_failures == 0
        assert svc.pending_count() == 1
        svc.flush()                # the WAL recovered: same ticket delivers
        assert t.status == "done"


# --------------------------------------------------------------------------
# journal recovery
# --------------------------------------------------------------------------
class TestRecovery:
    @pytest.mark.parametrize("tight", [False, True])
    def test_replay_equals_live_state(self, workload, tmp_path, tight):
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        svc = make_service(Q, journal=Journal(path),
                           tight_composition=tight)
        svc.attach_lp(np.abs(np.asarray(Q[:8])), np.full(8, 0.9, np.float32))
        add_tenant(svc, h, "alice")
        add_tenant(svc, h, "bob", eps_budget=20.0)
        for i in range(3):
            svc.submit("alice", seed=10 + i)
        svc.submit("bob", seed=20)
        svc.submit_lp("alice", seed=30)
        svc.flush()
        rec = recover(path, registry=svc.metrics, tight=tight)
        assert set(rec.sessions) == {"alice", "bob"}
        for name in ("alice", "bob"):
            live, back = svc.session(name), rec.sessions[name]
            assert back.ledger == live.ledger  # bitwise: events/γ/slack
            assert (back.ledger.composed(tight=tight)
                    == live.ledger.composed(tight=tight))
            assert len(back.releases) == len(live.releases)
            assert len(back.lp_releases) == len(live.lp_releases)
            for lr, br in zip(live.releases, back.releases):
                np.testing.assert_array_equal(lr.p_hat, br.p_hat)
                assert lr.eps_cost == br.eps_cost
        assert rec.issued_seeds == {10, 11, 12, 20, 30}
        assert rec.in_doubt == [] and rec.refunded == []
        # a fresh service adopts the recovered sessions and serves on
        svc2 = make_service(Q, registry=MetricsRegistry())
        svc2.adopt(rec)
        t = svc2.submit("bob")
        assert t.seed not in rec.issued_seeds
        svc2.flush()
        assert t.status == "done"

    def test_in_doubt_resolves_as_committed(self, workload, tmp_path):
        """The conservative rule: reserved + dispatch started + no
        resolution ⇒ the noise may have been realized ⇒ charge it."""
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        svc = make_service(Q, journal=Journal(path))
        add_tenant(svc, h)
        svc.submit("t0", seed=1)
        svc.submit("t0", seed=2)
        bundle = svc.session("t0").ledger.reserved_bundle()
        # crash simulation: journal a dispatch start, then stop the world
        svc.journal.append("dispatch-started", kind="mwem", attempt=0,
                           rids=[["t0", 0]])
        svc.journal.close()
        rec = recover(path)
        # rid 0 dispatched ⇒ committed; rid 1 never dispatched ⇒ refunded
        assert rec.in_doubt == [("t0", 0)]
        assert rec.refunded == [("t0", 1)]
        per_release = len(bundle[0]) // 2
        assert len(rec.sessions["t0"].ledger.events) == per_release

    def _crash_with_in_doubt(self, Q, h, path):
        """One committed+delivered release (rid 0), then a crash with
        rid 1 reserved and dispatched but unresolved (in doubt)."""
        svc = make_service(Q, journal=Journal(path))
        add_tenant(svc, h)
        svc.submit("t0", seed=1)
        svc.flush()
        svc.submit("t0", seed=2)
        svc.journal.append("dispatch-started", workload="mwem", attempt=0,
                           rids=[["t0", 1]])
        svc.journal.close()
        return svc

    def test_adopt_fast_forwards_reservation_ids(self, workload, tmp_path):
        """A post-adopt reserve must never reuse a journaled rid: the WAL
        still holds rid 0/1 records, and a collision would let the next
        replay resolve a pre-crash in-doubt record against the new
        reservation, silently under-counting spent ε."""
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        self._crash_with_in_doubt(Q, h, path)
        rec = recover(path)
        assert rec.in_doubt == [("t0", 1)]
        assert rec.next_rids == {"t0": 2}
        assert rec.sessions["t0"].ledger.next_rid == 2
        svc2 = make_service(Q)
        svc2.adopt(rec)
        t = svc2.submit("t0")
        assert t.rid == 2

    def test_adopt_rejournals_into_fresh_wal(self, workload, tmp_path):
        """adopt() snapshots the recovered state into the new service's
        journal, so recovering the post-adopt WAL *alone* reconstructs
        everything: sessions, charges (including the crash's in-doubt
        one), releases, seeds, and the rid counter."""
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        self._crash_with_in_doubt(Q, h, path)
        rec = recover(path)
        path2 = tmp_path / "wal2.jsonl"
        svc2 = make_service(Q, journal=Journal(path2))
        svc2.adopt(rec)
        t = svc2.submit("t0", seed=3)
        svc2.flush()
        assert t.status == "done"
        live = svc2.session("t0")
        rec2 = recover(path2)
        back = rec2.sessions["t0"]
        assert back.ledger == live.ledger
        assert back.ledger.next_rid == live.ledger.next_rid
        assert rec2.in_doubt == []   # adoption markers resolved the crash
        assert len(back.releases) == len(live.releases) == 2
        for lr, br in zip(live.releases, back.releases):
            np.testing.assert_array_equal(lr.p_hat, br.p_hat)
            assert lr.eps_cost == br.eps_cost
        assert {1, 2, 3} <= rec2.issued_seeds

    def test_adopt_same_wal_second_recovery_is_consistent(
            self, workload, tmp_path):
        """Adopting while appending to the *same* WAL: the snapshot
        supersedes the pre-crash records, so a second recovery equals the
        live service — no double charge from re-resolving the old
        in-doubt reservation, no silent under-count from a reused rid."""
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        self._crash_with_in_doubt(Q, h, path)
        rec = recover(path)
        svc2 = make_service(Q, journal=Journal(path))  # append to same WAL
        svc2.adopt(rec)
        t = svc2.submit("t0", seed=3)
        assert t.rid == 2            # rid 1 is the in-doubt one — no reuse
        svc2.flush()
        live = svc2.session("t0")
        rec2 = recover(path)
        assert rec2.sessions["t0"].ledger == live.ledger
        assert rec2.in_doubt == []
        assert len(rec2.sessions["t0"].releases) == len(live.releases)
        assert rec2.sessions["t0"].ledger.next_rid == live.ledger.next_rid

    def test_torn_tail_record_is_dropped(self, tmp_path):
        path = tmp_path / "wal.jsonl"
        with Journal(path) as j:
            j.append("session-created", tenant_id="t0", h=[1.0],
                     n_records=1, eps_budget=1.0, delta_budget=1e-3)
        with open(path, "a", encoding="utf-8") as fh:
            fh.write('{"seq": 1, "kind": "reserved", "tenant')  # torn
        recs = read_records(path)
        assert [r["kind"] for r in recs] == ["session-created"]
        rec = recover(path)
        assert set(rec.sessions) == {"t0"}


# --------------------------------------------------------------------------
# deadlines, shedding, breaker
# --------------------------------------------------------------------------
class TestDegradation:
    def test_deadline_expiry_refunds_reservation(self, workload):
        Q, h = workload
        svc = make_service(Q)
        add_tenant(svc, h)
        expired = svc.submit("t0", seed=1, deadline=0.0)
        live = svc.submit("t0", seed=2)
        done = svc.flush()
        assert expired.status == "expired" and expired.rid is None
        assert live.status == "done"
        assert [t.ticket_id for t in done] == [live.ticket_id]
        assert svc.stats.expired == 1
        sess = svc.session("t0")
        assert not sess.ledger.reservations
        assert len(sess.ledger.events) == len(live.cost_bundle[0])

    def test_load_shedding_rejects_before_reservation(self, workload):
        Q, h = workload
        svc = make_service(Q, max_queue_depth=2)
        add_tenant(svc, h)
        t1, t2 = svc.submit("t0"), svc.submit("t0")
        shed = svc.submit("t0")
        assert (t1.status, t2.status) == ("queued", "queued")
        assert shed.status == "rejected"
        assert "load shed" in shed.decision.reason
        assert shed.rid is None and shed.seed == -1
        assert len(svc.session("t0").ledger.reservations) == 2
        assert svc.stats.shed == 1
        assert svc.metrics.counter("load_shed_total", kind="mwem").value == 1
        svc.flush()  # the queue drains; new submits are admitted again
        assert svc.submit("t0").status == "queued"

    def test_breaker_trips_and_degrades_to_ref(self, workload):
        Q, h = workload
        svc = make_service(Q, breaker_threshold=2, retry_limit=3)
        add_tenant(svc, h)
        ticket = svc.submit("t0", seed=9)
        assert svc.cfg.use_pallas == "auto" and not svc.degraded
        with inject({"wave.dispatch": Schedule(fail_n=2)}):
            svc.flush()
        # two consecutive failures trip the breaker; the third attempt runs
        # on the pinned reference route and delivers
        assert ticket.status == "done"
        assert svc.degraded and svc.breaker.is_open
        assert svc.cfg.use_pallas == "never"
        assert svc.index._use_pallas == "never"
        assert svc.metrics.gauge("breaker_state", seam="kernel").value == 1.0
        assert svc.metrics.counter("breaker_trips_total",
                                   seam="kernel").value == 1
        # degraded-route failures no longer feed the breaker
        assert svc.breaker.trips == 1

    def test_degraded_route_is_bitwise_equal(self, workload):
        """Breaker degradation changes throughput, never answers: a service
        pinned to the reference route releases the same bytes."""
        Q, h = workload

        def run(**kw):
            svc = make_service(Q, **kw)
            add_tenant(svc, h)
            svc.submit("t0", seed=5)
            return svc.flush()[0].release.p_hat

        np.testing.assert_array_equal(run(), run(use_pallas="never"))


# --------------------------------------------------------------------------
# streaming chaos (DESIGN.md §11): the open-loop generator under faults
# --------------------------------------------------------------------------
class TestStreamingChaos:
    """The §10 invariants must survive the streaming drain: continuous
    admission, coalesced adaptive waves, launch/finish retries, and
    mid-wave slot refills, all driven by the open-loop generator with the
    fault harness armed (the ``CHAOS_SEED`` matrix varies both the fault
    interleavings and the offered traffic)."""

    TENANTS = ("t0", "t1", "t2")

    def _streaming_service(self, Q, h, path=None, **kw):
        kw.setdefault("wave_size", 2)
        svc = make_service(Q, streaming=True,
                           journal=Journal(path) if path else None, **kw)
        for name in self.TENANTS:
            add_tenant(svc, h, name, eps_budget=200.0, delta_budget=0.9)
        return svc

    def test_open_loop_under_dispatch_fault_rate(self, workload, tmp_path):
        Q, h = workload
        svc = self._streaming_service(Q, h, tmp_path / "wal.jsonl")
        svc.attach_lp(np.abs(np.asarray(Q[:8])), np.full(8, 0.9, np.float32))
        spec = LoadSpec(duration=0.4, rate=30.0, seed=CHAOS_SEED,
                        deadline=5.0, mix={"mwem": 0.6, "lp": 0.4})
        with inject({"wave.dispatch": Schedule(fail_rate=0.3,
                                               seed=CHAOS_SEED)}) as plan:
            rep = run_open_loop(svc, spec)
        assert plan.hits["wave.dispatch"] >= 1
        assert rep.counts["done"] > 0
        assert_no_budget_leak(svc)
        # every offered ticket resolved one way or the other; none holds
        # a reservation (rid) after the final flush
        for t in rep.tickets:
            assert t.status in ("done", "failed", "expired", "rejected")
            assert t.rid is None
        # commit exactly once: despite retries, each tenant's ledger
        # carries exactly its delivered tickets' event schedules
        for name in self.TENANTS:
            assert len(svc.session(name).ledger.events) == \
                delivered_event_count(rep.tickets, name)
        # journal replay reproduces every live ledger
        rec = recover(svc.journal.path, registry=svc.metrics)
        for name in self.TENANTS:
            assert rec.sessions[name].ledger == svc.session(name).ledger

    def test_expired_under_fault_is_refunded(self, workload):
        """A ticket that expires while dispatch faults churn its wave is
        refunded in full — the failed attempts produced no output, so the
        refund leaks nothing and the budget balances exactly."""
        Q, h = workload
        svc = self._streaming_service(Q, h)
        doomed = svc.submit("t0", seed=1, deadline=0.05)
        live = svc.submit("t1", seed=2)
        with inject({"wave.dispatch": Schedule(fail_n=2, latency=0.1)}):
            svc.flush()
        assert doomed.status == "expired" and doomed.rid is None
        assert live.status == "done"
        assert svc.stats.expired == 1
        assert_no_budget_leak(svc)
        sess = svc.session("t0")
        assert sess.ledger.events == [] and not sess.ledger.reservations
        assert len(svc.session("t1").ledger.events) == \
            len(live.cost_bundle[0])

    def test_refill_promotes_queue_into_freed_slots(self, workload):
        """The serve-engine ``free_slots`` trick in the release path:
        when a retry frees a lane (the doomed ticket expired during the
        failed attempt), a queued ticket is promoted into the slot and
        the relaunched wave delivers it — no dispatch wasted on a
        half-empty retry while work is queued behind it."""
        Q, h = workload
        svc = make_service(Q, streaming=True, wave_size=2,
                           policy=ScriptedPolicy(wave_size=2, slices=[2]))
        add_tenant(svc, h, "t0", eps_budget=200.0, delta_budget=0.9)
        doomed = svc.submit("t0", seed=1, deadline=0.2)
        survivor = svc.submit("t0", seed=2)
        spare = svc.submit("t0", seed=3)
        with inject({"wave.dispatch": Schedule(fail_n=1, latency=0.3)}):
            svc.flush()
        assert doomed.status == "expired" and doomed.rid is None
        assert survivor.status == "done" and spare.status == "done"
        assert svc.stats.refilled_slots == 1
        assert svc.stats.retries == 1
        assert svc.metrics.counter("wave_slot_refills_total",
                                   kind="mwem").value == 1
        assert_no_budget_leak(svc)

    def test_streaming_retry_bitwise_equals_clean(self, workload):
        """Streaming relaunch-at-finish keeps the batch retry contract:
        lanes are keyed by ``PRNGKey(ticket.seed)``, so the retried wave
        releases the same bytes and charges the same ledger."""
        Q, h = workload

        def run(schedules):
            svc = self._streaming_service(Q, h)
            tickets = [svc.submit("t0", seed=70 + i) for i in range(2)]
            with (inject(schedules) if schedules else nullcontext()):
                svc.flush()
            return svc, tickets

        svc_clean, clean = run(None)
        svc_retry, retried = run({"wave.dispatch": Schedule(fail_n=2)})
        assert svc_retry.stats.retries == 2
        assert [t.status for t in retried] == ["done", "done"]
        for a, b in zip(clean, retried):
            np.testing.assert_array_equal(a.release.p_hat, b.release.p_hat)
            assert a.release.eps_cost == b.release.eps_cost
        assert (svc_clean.session("t0").ledger
                == svc_retry.session("t0").ledger)

    def test_journal_fail_once_retries_through_load(self, workload,
                                                    tmp_path):
        Q, h = workload
        svc = self._streaming_service(Q, h, tmp_path / "wal.jsonl")
        spec = LoadSpec(duration=0.25, rate=30.0, seed=CHAOS_SEED,
                        mix={"mwem": 1.0})
        with inject({"journal.append": fail_once()}) as plan:
            rep = run_open_loop(svc, spec)
        assert plan.failures["journal.append"] == 1
        assert rep.counts["done"] > 0 and rep.counts["submit_errors"] == 0
        assert_no_budget_leak(svc)
        rec = recover(svc.journal.path)
        for name in self.TENANTS:
            assert rec.sessions[name].ledger == svc.session(name).ledger

    def test_index_probe_latency_slows_but_completes(self, workload):
        Q, h = workload
        svc = self._streaming_service(Q, h)
        spec = LoadSpec(duration=0.2, rate=25.0, seed=CHAOS_SEED,
                        mix={"mwem": 1.0})
        with inject({"index.probe": Schedule(latency=0.002)}) as plan:
            rep = run_open_loop(svc, spec)
        assert rep.counts["done"] > 0
        assert_no_budget_leak(svc)
        for t in rep.tickets:
            assert t.status == "done" and t.rid is None
