"""Streaming release serving (DESIGN.md §11).

The headline invariant: lanes are keyed by ``PRNGKey(ticket.seed)``, so
*however* the coalescing policy slices the admitted set into waves — and
whatever ladder executable each wave runs on — every lane's release is
bitwise identical to the fixed-wave batch path, the per-tenant ledgers
end in the same state, and the admission-time preview equals the
composed cost actually charged.

Also here: the coalescing-policy property tests (pure `decide`, driven
through arbitrary clock/occupancy trajectories by hypothesis), the
expire-on-every-tick regression (PR 10 fixed deadline expiry only
running inside wave drains), the AOT wave-size ladder (prewarm compiles
once; short waves run the smaller executable instead of padding), the
coalescer observability series, WAL replay of dispatch decisions, and a
short open-loop load-generator smoke for the CI fast lane.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.core import MWEMConfig
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.obs.metrics import MetricsRegistry
from repro.serve import (DeadlineOccupancyPolicy, LoadSpec, ReleaseService,
                         ScriptedPolicy, WaveLadder, replay_decisions,
                         run_open_loop)
from repro.serve.journal import Journal, read_records

U, M, N_RECORDS, WAVE = 64, 128, 300, 4
TENANTS = ("alice", "bob", "carol")


def make_workload():
    key = jax.random.PRNGKey(11)
    kh, kq = jax.random.split(key)
    h = gaussian_histogram(kh, N_RECORDS, U)
    return random_binary_queries(kq, M, U), np.asarray(h)


@pytest.fixture(scope="module")
def workload():
    return make_workload()


def make_service(Q, **kw):
    kw.setdefault("wave_size", WAVE)
    kw.setdefault("auto_flush", False)
    kw.setdefault("registry", MetricsRegistry())
    cfg = MWEMConfig(eps=0.5, delta=1e-3, T=4, mode="fast")
    return ReleaseService(Q, cfg, **kw)


def add_tenants(svc, h, names=TENANTS):
    for name in names:
        svc.create_session(name, eps_budget=50.0, delta_budget=0.9, h=h,
                           n_records=N_RECORDS)


def lp_workload(Q):
    A = np.abs(np.asarray(Q[:8]))
    b = np.full(8, 0.9, np.float32)
    return A, b


# --------------------------------------------------------------------------
# the AOT wave-size ladder
# --------------------------------------------------------------------------
class TestWaveLadder:
    def test_powers_of_two_up_to_max(self):
        assert WaveLadder.for_wave_size(8).sizes == (2, 4, 8)
        assert WaveLadder.for_wave_size(1).sizes == (1,)
        # a non-power-of-two max still tops the ladder
        assert WaveLadder.for_wave_size(6).sizes == (2, 4, 6)

    def test_fit_picks_smallest_holding_size(self):
        ladder = WaveLadder.for_wave_size(8)
        assert [ladder.fit(n) for n in (1, 2, 3, 4, 5, 8)] == [2, 2, 4, 4,
                                                               8, 8]
        assert ladder.fit(9) == 8  # capped at max

    def test_singleton_waves_pad_to_two_lanes(self):
        """The B=1 hazard: the degenerate single-lane executable lowers
        differently under XLA and can flip near-tied EM selections, so
        the ladder floors at 2 — a 1-ticket wave pads one replica slot
        instead of running the one executable whose answers can drift."""
        assert WaveLadder.for_wave_size(8).fit(1) == 2
        # wave_size 1 shares the single-lane executable with the batch
        # path, so parity holds trivially and the floor doesn't apply
        assert WaveLadder.for_wave_size(1).fit(1) == 1

    def test_rejects_degenerate(self):
        with pytest.raises(ValueError):
            WaveLadder.for_wave_size(0)
        with pytest.raises(ValueError):
            WaveLadder.for_wave_size(4).fit(0)

    @given(max_size=st.integers(1, 64), n=st.integers(1, 128))
    @settings(max_examples=100, deadline=None)
    def test_fit_bounds_padding_waste(self, max_size, n):
        ladder = WaveLadder.for_wave_size(max_size)
        s = ladder.fit(n)
        assert s in ladder.sizes
        if n > max_size:
            assert s == max_size
        elif n == 1:
            # the 2-lane floor (B=1 lowers differently; see coalesce.py)
            assert s == (1 if max_size == 1 else 2)
        else:
            # the power-of-two ladder's guarantee: <2× padding waste
            assert n <= s < 2 * n


# --------------------------------------------------------------------------
# the deadline/occupancy coalescing policy (pure — hypothesis drives it)
# --------------------------------------------------------------------------
class TestCoalescingPolicy:
    def test_empty_never_dispatches_even_forced(self):
        pol = DeadlineOccupancyPolicy(wave_size=WAVE)
        d = pol.decide(0, now=5.0, force=True)
        assert (d.dispatch, d.reason, d.wave_size) == (False, "empty", 0)

    def test_full_dispatches_at_max(self):
        pol = DeadlineOccupancyPolicy(wave_size=WAVE)
        d = pol.decide(WAVE, now=0.0)
        assert d.dispatch and d.reason == "full" and d.wave_size == WAVE

    def test_partial_without_deadline_holds(self):
        pol = DeadlineOccupancyPolicy(wave_size=WAVE)
        d = pol.decide(2, now=1e9)
        assert not d.dispatch and d.reason == "hold"

    def test_force_flushes_partial_on_fitting_size(self):
        pol = DeadlineOccupancyPolicy(wave_size=8)
        d = pol.decide(3, now=0.0, force=True)
        assert d.dispatch and d.reason == "flush" and d.wave_size == 4

    def test_half_spent_budget_triggers(self):
        pol = DeadlineOccupancyPolicy(wave_size=WAVE)
        # budget 10s from t=100: holds before t=105, dispatches from it
        hold = pol.decide(2, now=104.9, oldest_submit=100.0,
                          oldest_deadline=110.0)
        fire = pol.decide(2, now=105.0, oldest_submit=100.0,
                          oldest_deadline=110.0)
        assert not hold.dispatch and hold.reason == "hold"
        assert fire.dispatch and fire.reason == "deadline"
        assert fire.wave_size == 2

    def test_non_positive_budget_dispatches_immediately(self):
        pol = DeadlineOccupancyPolicy(wave_size=WAVE)
        d = pol.decide(1, now=0.0, oldest_submit=7.0, oldest_deadline=7.0)
        assert d.dispatch and d.reason == "deadline"

    def test_rejects_bad_half_frac(self):
        with pytest.raises(ValueError):
            DeadlineOccupancyPolicy(wave_size=2, half_frac=0.0)

    @given(occ=st.integers(0, 32), wave=st.integers(1, 16),
           budget=st.floats(0.01, 100.0),
           frac=st.floats(0.0, 2.0), force=st.booleans())
    @settings(max_examples=200, deadline=None)
    def test_policy_invariants(self, occ, wave, budget, frac, force):
        """The satellite-4 property suite, one trajectory per example:
        never dispatch empty, never hold a full wave, the half-budget
        bound, and a chosen wave size that always fits the occupancy."""
        pol = DeadlineOccupancyPolicy(wave_size=wave)
        submit = 100.0
        d = pol.decide(occ, now=submit + frac * budget,
                       oldest_submit=submit,
                       oldest_deadline=submit + budget, force=force)
        if occ == 0:                      # never dispatch an empty wave
            assert not d.dispatch and d.reason == "empty"
            return
        if occ >= wave:                   # never hold a full wave
            assert d.dispatch and d.reason == "full"
        if d.dispatch:                    # the executable fits the wave
            assert d.wave_size >= min(occ, pol.ladder.max_size)
            assert d.wave_size in pol.ladder.sizes
        assert d.occupancy == occ
        if 0 < occ < wave and not force and abs(frac - 0.5) > 1e-6:
            # the half-budget bound, both directions (away from the
            # boundary, where float rounding could flip the comparison)
            if frac >= 0.5:
                assert d.dispatch and d.reason == "deadline"
            else:
                assert not d.dispatch and d.reason == "hold"

    @given(occ=st.integers(1, 32), wave=st.integers(1, 16))
    @settings(max_examples=100, deadline=None)
    def test_deadline_free_tickets_only_ride_full_or_flush(self, occ, wave):
        pol = DeadlineOccupancyPolicy(wave_size=wave)
        d = pol.decide(occ, now=1e12)     # no deadline info, however late
        assert d.dispatch == (occ >= wave)
        forced = pol.decide(occ, now=1e12, force=True)
        assert forced.dispatch


# --------------------------------------------------------------------------
# deadline expiry runs on every tick (PR 10 regression)
# --------------------------------------------------------------------------
class TestExpiryOnPump:
    @pytest.mark.parametrize("streaming", [True, False])
    def test_pump_expires_without_a_wave(self, workload, streaming):
        """The fix: an overdue ticket is expired and refunded by the next
        `pump` tick even though no wave ever forms around it. Before, the
        expiry check lived inside the wave drains only, so under
        continuous admission a lone ticket could sit past its deadline
        holding its reservation until some unrelated wave drained."""
        Q, h = workload
        svc = make_service(Q, streaming=streaming)
        add_tenants(svc, h, ["alice"])
        t = svc.submit("alice", deadline=0.0)
        assert t.status == "queued" and t.rid is not None
        done = svc.pump()
        assert done == []
        assert t.status == "expired" and t.rid is None
        assert not svc.session("alice").ledger.reservations
        assert svc.stats.expired == 1
        assert svc.stats.dispatches == 0     # no wave ran to expire it
        assert svc.pending_count() == 0

    def test_pump_expires_lp_queue_too(self, workload):
        Q, h = workload
        svc = make_service(Q, streaming=True)
        svc.attach_lp(*lp_workload(Q))
        add_tenants(svc, h, ["alice"])
        t = svc.submit_lp("alice", deadline=0.0)
        svc.pump()
        assert t.status == "expired" and t.rid is None
        assert not svc.session("alice").ledger.reservations

    def test_pump_holds_partial_wave(self, workload):
        Q, h = workload
        svc = make_service(Q, streaming=True)
        add_tenants(svc, h, ["alice"])
        t = svc.submit("alice")              # no deadline: holds forever
        assert svc.pump() == []
        assert t.status == "queued" and svc.stats.dispatches == 0
        svc.flush()
        assert t.status == "done"


# --------------------------------------------------------------------------
# the headline invariant: streaming ≡ fixed-wave, bitwise, any slicing
# --------------------------------------------------------------------------
SLICINGS = [[1, 1, 1, 1, 1], [2, 1, 2], [3, 2], [4, 1], [5]]


class TestStreamingParity:
    def _batch_oracle(self, Q, h, seeds, lp_seeds=()):
        svc = make_service(Q)
        if lp_seeds:
            svc.attach_lp(*lp_workload(Q))
        add_tenants(svc, h)
        tickets = [svc.submit(TENANTS[i % len(TENANTS)], seed=s)
                   for i, s in enumerate(seeds)]
        lp_tickets = [svc.submit_lp(TENANTS[i % len(TENANTS)], seed=s)
                      for i, s in enumerate(lp_seeds)]
        svc.flush()
        return svc, tickets, lp_tickets

    def _streaming(self, Q, h, seeds, slices, lp_seeds=()):
        svc = make_service(
            Q, streaming=True,
            policy=ScriptedPolicy(wave_size=WAVE, slices=slices))
        if lp_seeds:
            svc.attach_lp(*lp_workload(Q))
        add_tenants(svc, h)
        tickets = [svc.submit(TENANTS[i % len(TENANTS)], seed=s)
                   for i, s in enumerate(seeds)]
        lp_tickets = [svc.submit_lp(TENANTS[i % len(TENANTS)], seed=s)
                      for i, s in enumerate(lp_seeds)]
        svc.flush()
        return svc, tickets, lp_tickets

    @pytest.mark.parametrize("slices", SLICINGS,
                             ids=["x".join(map(str, s)) for s in SLICINGS])
    def test_mwem_bitwise_any_slicing(self, workload, slices):
        Q, h = workload
        seeds = [100 + i for i in range(5)]
        svc_b, batch, _ = self._batch_oracle(Q, h, seeds)
        svc_s, stream, _ = self._streaming(Q, h, seeds, slices)
        assert all(t.status == "done" for t in batch + stream)
        for a, b in zip(batch, stream):
            np.testing.assert_array_equal(a.release.p_hat, b.release.p_hat)
            assert a.release.eps_cost == b.release.eps_cost
            assert a.final_error == b.final_error
        for name in TENANTS:
            lb, ls = svc_b.session(name).ledger, svc_s.session(name).ledger
            assert lb == ls
            assert lb.composed() == ls.composed()
        # the coalescer actually followed the script (plus the script-dry
        # waves that drain whatever the slices left behind)
        expected, left = [], len(seeds)
        for s in slices:
            if left <= 0:
                break
            take = max(1, min(s, left, WAVE))
            expected.append(take)
            left -= take
        while left > 0:
            take = min(left, WAVE)
            expected.append(take)
            left -= take
        assert [d.occupancy for d in svc_s.wave_log] == expected

    @pytest.mark.parametrize("slices", [[1, 1, 1], [2, 1], [3]],
                             ids=["1x1x1", "2x1", "3"])
    def test_lp_bitwise_any_slicing(self, workload, slices):
        Q, h = workload
        lp_seeds = [200, 201, 202]
        svc_b, _, batch = self._batch_oracle(Q, h, [], lp_seeds=lp_seeds)
        svc_s, _, stream = self._streaming(Q, h, [], slices,
                                           lp_seeds=lp_seeds)
        assert all(t.status == "done" for t in batch + stream)
        for a, b in zip(batch, stream):
            np.testing.assert_array_equal(a.release.x_bar, b.release.x_bar)
            assert a.release.violated_frac == b.release.violated_frac
            assert a.release.eps_cost == b.release.eps_cost
        for name in TENANTS:
            assert (svc_b.session(name).ledger
                    == svc_s.session(name).ledger)

    def test_mixed_tenants_and_workloads(self, workload):
        """Both workloads in flight, tenants holding multiple lanes: the
        scripted cuts land across both queues, and every artifact and
        every ledger still matches the fixed-wave oracle bitwise."""
        Q, h = workload
        seeds, lp_seeds = [300 + i for i in range(5)], [400, 401, 402]
        svc_b, mb, lb = self._batch_oracle(Q, h, seeds, lp_seeds=lp_seeds)
        svc_s, ms, ls = self._streaming(Q, h, seeds, [2, 1, 2, 2, 1],
                                        lp_seeds=lp_seeds)
        for a, b in zip(mb, ms):
            np.testing.assert_array_equal(a.release.p_hat, b.release.p_hat)
        for a, b in zip(lb, ls):
            np.testing.assert_array_equal(a.release.x_bar, b.release.x_bar)
        for name in TENANTS:
            blg, slg = svc_b.session(name).ledger, svc_s.session(name).ledger
            assert blg == slg
            assert blg.composed() == slg.composed()

    def test_preview_equals_composed(self, workload):
        """Admission's projected (ε, δ) — previewed over the ledger plus
        every open reservation — equals the cost actually composed once
        all the previewed lanes commit, in both drain modes."""
        Q, h = workload
        seeds = [500 + i for i in range(4)]
        for streaming in (False, True):
            svc = make_service(
                Q, streaming=streaming,
                policy=(ScriptedPolicy(wave_size=WAVE, slices=[1, 2, 1])
                        if streaming else None))
            add_tenants(svc, h, ["alice"])
            tickets = [svc.submit("alice", seed=s) for s in seeds]
            svc.flush()
            last = tickets[-1].decision
            assert svc.session("alice").ledger.composed() == (
                last.eps_projected, last.delta_projected)

    def test_wave_log_replays_from_journal(self, workload, tmp_path):
        """Every streaming dispatch decision rides the WAL: rebuilding
        the decision sequence from the journal alone reproduces the live
        `wave_log` — trigger reasons, ladder sizes, occupancies."""
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        svc = make_service(
            Q, streaming=True, journal=Journal(path),
            policy=ScriptedPolicy(wave_size=WAVE, slices=[2, 1, 2]))
        add_tenants(svc, h)
        for i in range(5):
            svc.submit(TENANTS[i % len(TENANTS)], seed=600 + i)
        svc.flush()
        svc.journal.close()
        assert replay_decisions(read_records(path)) == svc.wave_log
        assert [d.reason for d in svc.wave_log] == ["scripted"] * 3

    def test_batch_journal_records_replay_empty(self, workload, tmp_path):
        """Pre-PR-10 `dispatch-started` records carry no trigger field;
        `replay_decisions` skips them instead of crashing — the WAL stays
        forward/backward compatible."""
        Q, h = workload
        path = tmp_path / "wal.jsonl"
        svc = make_service(Q, journal=Journal(path))
        add_tenants(svc, h, ["alice"])
        svc.submit("alice", seed=1)
        svc.flush()
        svc.journal.close()
        assert replay_decisions(read_records(path)) == []


# --------------------------------------------------------------------------
# the streaming service: ladder executables, prewarm, double buffer, obs
# --------------------------------------------------------------------------
class TestStreamingService:
    def test_streaming_forbids_mesh(self, workload):
        Q, _ = workload
        with pytest.raises(ValueError, match="single-device"):
            make_service(Q, streaming=True, mesh=object())

    def test_prewarm_compiles_ladder_once(self, workload):
        Q, h = workload
        svc = make_service(Q, streaming=True)
        add_tenants(svc, h, ["alice"])
        first = svc.prewarm(n_records=N_RECORDS)
        assert set(first) == {2, 4}
        # the second prewarm is a pure cache hit — nothing recompiles
        assert svc.prewarm(n_records=N_RECORDS) == {2: False, 4: False}

    def test_prewarm_lp_requires_attach(self, workload):
        Q, _ = workload
        svc = make_service(Q, streaming=True)
        with pytest.raises(ValueError, match="attach_lp"):
            svc.prewarm(lp=True)

    def test_short_wave_runs_smaller_executable(self, workload):
        """The acceptance criterion: a 2-ticket wave runs on the 2-lane
        ladder executable instead of being padded to ``wave_size`` by
        slot replication — no pad lanes burned, the saving accounted."""
        Q, h = workload
        svc = make_service(Q, streaming=True)
        add_tenants(svc, h)
        t0 = svc.submit("alice", seed=1)
        t1 = svc.submit("bob", seed=2)
        svc.flush()
        assert t0.status == t1.status == "done"
        assert svc.stats.padded_slots == 0
        assert svc.stats.pad_slots_saved == WAVE - 2
        (decision,) = svc.wave_log
        assert decision.wave_size == 2 and decision.reason == "flush"
        assert svc.metrics.counter("wave_pad_slots_saved_total",
                                   kind="mwem").value == WAVE - 2

    def test_full_wave_saves_nothing(self, workload):
        Q, h = workload
        svc = make_service(Q, streaming=True)
        add_tenants(svc, h)
        for i in range(WAVE):
            svc.submit(TENANTS[i % len(TENANTS)], seed=10 + i)
        svc.pump()
        assert svc.stats.pad_slots_saved == 0
        (decision,) = svc.wave_log
        assert decision.reason == "full" and decision.wave_size == WAVE

    def test_auto_flush_dispatches_full_wave_via_pump(self, workload):
        Q, h = workload
        svc = make_service(Q, streaming=True, auto_flush=True)
        add_tenants(svc, h)
        tickets = [svc.submit(TENANTS[i % len(TENANTS)], seed=20 + i)
                   for i in range(WAVE)]
        svc.flush()                      # collects the in-flight wave
        assert all(t.status == "done" for t in tickets)
        assert any(d.reason == "full" for d in svc.wave_log)

    def test_double_buffer_overlaps_waves(self, workload):
        """Two scripted waves in one tick: the first wave is resolved
        *after* the second is launched (the double buffer), yet delivery
        order and results are unchanged."""
        Q, h = workload
        svc = make_service(
            Q, streaming=True,
            policy=ScriptedPolicy(wave_size=WAVE, slices=[2, 2]))
        add_tenants(svc, h)
        tickets = [svc.submit(TENANTS[i % len(TENANTS)], seed=30 + i)
                   for i in range(4)]
        done = svc.flush()
        assert [t.ticket_id for t in done] == [t.ticket_id for t in tickets]
        assert len(svc.wave_log) == 2
        assert svc._inflight is None

    def test_coalescer_obs_series(self, workload):
        """Satellite 4's obs assertions: the occupancy gauge and trigger
        counter publish per kind, per-wave-size latency histograms key by
        executed lane count, and `admission_to_answer_seconds` splits by
        trigger reason on its own series — the plain per-kind series the
        batch path populates keeps its identity."""
        Q, h = workload
        svc = make_service(Q, streaming=True)
        add_tenants(svc, h)
        for i in range(WAVE):            # a full wave...
            svc.submit(TENANTS[i % len(TENANTS)], seed=40 + i)
        svc.pump()
        svc.submit("alice", seed=50)     # ...then a flushed short one
        svc.flush()
        snap = svc.metrics.snapshot()
        hists, counters = snap["histograms"], snap["counters"]
        assert "admission_to_answer_seconds{kind=mwem}" in hists
        assert "admission_to_answer_seconds{kind=mwem,trigger=full}" in hists
        assert ("admission_to_answer_seconds{kind=mwem,trigger=flush}"
                in hists)
        assert "wave_latency_seconds{kind=mwem,lanes=4}" in hists
        assert "wave_latency_seconds{kind=mwem,lanes=2}" in hists
        assert counters["wave_trigger_total{kind=mwem,reason=full}"] >= 1
        assert counters["wave_trigger_total{kind=mwem,reason=flush}"] >= 1
        assert "coalescer_occupancy{kind=mwem}" in snap["gauges"]
        # the trigger split partitions the per-kind distribution
        split = [v for k, v in hists.items()
                 if k.startswith("admission_to_answer_seconds{kind=mwem,")]
        total = hists["admission_to_answer_seconds{kind=mwem}"]
        assert sum(s["count"] for s in split) == total["count"]


# --------------------------------------------------------------------------
# open-loop load generator — the CI fast-lane smoke (satellite 6)
# --------------------------------------------------------------------------
class TestLoadgenSmoke:
    def test_short_open_loop_run(self, workload):
        Q, h = workload
        svc = make_service(Q, streaming=True, default_deadline=30.0)
        add_tenants(svc, h)
        svc.prewarm(n_records=N_RECORDS)
        spec = LoadSpec(duration=0.25, rate=40.0, seed=3,
                        mix={"mwem": 0.7, "answer": 0.3}, max_wall=60.0)
        rep = run_open_loop(svc, spec)
        assert rep.counts["offered"] > 0
        assert rep.counts["done"] > 0
        assert rep.counts["done"] + rep.counts["expired"] + \
            rep.counts["failed"] == len(rep.tickets)
        assert rep.sustained_qps > 0
        q = rep.quantiles["mwem"]
        assert np.isfinite([q["p50"], q["p95"], q["p99"]]).all()
        assert q["p50"] <= q["p95"] <= q["p99"]
        assert rep.latencies["mwem"].size == rep.counts["done"]
        # nothing left holding budget after the final flush
        for sess in svc.sessions.values():
            assert not sess.ledger.reservations

    def test_lp_mass_folds_into_mwem_without_attach(self, workload):
        Q, h = workload
        svc = make_service(Q, streaming=True)
        add_tenants(svc, h, ["alice"])
        spec = LoadSpec(duration=0.1, rate=30.0, seed=5,
                        mix={"mwem": 0.5, "lp": 0.5})
        rep = run_open_loop(svc, spec)
        assert all(t.kind == "mwem" for t in rep.tickets)
        assert rep.latencies["lp"].size == 0

    def test_no_tenants_rejected(self, workload):
        Q, _ = workload
        svc = make_service(Q, streaming=True)
        with pytest.raises(ValueError, match="no tenant"):
            run_open_loop(svc, LoadSpec(duration=0.01))
