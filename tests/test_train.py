"""Training substrate: optimizers, grad accumulation, checkpointing, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import (ElasticController, StragglerWatchdog,
                                 plan_mesh, shard_plan)
from repro.train.optim import clip_by_global_norm, global_norm, make_optimizer
from repro.train.trainer import make_train_step


def _setup(arch="llama3.2-3b", **tkw):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    model = build_model(cfg)
    tcfg = TrainConfig(lr=1e-2, total_steps=50, warmup_steps=2,
                       remat="none", **tkw)
    opt_init, train_step = make_train_step(model, tcfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, opt_init, jax.jit(train_step), params


def _batch(cfg, B=4, S=16, seed=0):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S),
                                         0, cfg.vocab_size)}


class TestOptim:
    @pytest.mark.parametrize("opt", ["adam", "adafactor"])
    def test_loss_decreases(self, opt):
        cfg, model, opt_init, train_step, params = _setup(optimizer=opt)
        opt_state = opt_init(params)
        batch = _batch(cfg)
        losses = []
        for _ in range(12):
            params, opt_state, m = train_step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()

    def test_grad_clip(self):
        tree = {"a": jnp.full((10,), 100.0), "b": jnp.full((5,), -100.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(global_norm(clipped)) <= 1.0 + 1e-5
        assert float(norm) > 100


class TestGradAccum:
    def test_microbatch_equivalence(self):
        """k microbatches ≈ the full-batch gradient step."""
        cfg, model, opt_init1, step1, params = _setup(microbatches=1)
        _, _, opt_init2, step2, _ = _setup(microbatches=4)
        batch = _batch(cfg, B=8)
        p1, s1, m1 = step1(params, opt_init1(params), batch)
        p2, s2, m2 = step2(params, opt_init2(params), batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        # Adam's 1/√ν amplifies f32 summation-order noise on tiny grads —
        # compare post-update params with a tolerance reflecting that.
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-3, atol=5e-4)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cfg, model, opt_init, train_step, params = _setup()
        opt_state = opt_init(params)
        mgr = CheckpointManager(str(tmp_path), keep_n=2)
        mgr.save(10, {"params": params, "opt": opt_state}, block=True)
        step, state = mgr.restore_latest({"params": params, "opt": opt_state})
        assert step == 10
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gc_keeps_newest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2)
        x = {"w": jnp.ones((3,))}
        for s in (1, 2, 3, 4):
            mgr.save(s, x, block=True)
        assert mgr.list_steps() == [3, 4]

    def test_resume_continues_training(self, tmp_path):
        cfg, model, opt_init, train_step, params = _setup()
        opt_state = opt_init(params)
        mgr = CheckpointManager(str(tmp_path))
        batch = _batch(cfg)
        for _ in range(3):
            params, opt_state, _ = train_step(params, opt_state, batch)
        mgr.save(3, {"params": params, "opt": opt_state}, block=True)
        # simulate a crash: fresh process state, restore, keep training
        params2, _ = model.init(jax.random.PRNGKey(0))
        step, state = mgr.restore_latest(
            {"params": params2, "opt": opt_init(params2)})
        assert step == 3
        p, o, m = train_step(state["params"], state["opt"], batch)
        assert np.isfinite(float(m["loss"]))

    def test_torn_checkpoint_invisible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        # a .tmp dir (crashed writer) must not be listed
        os.makedirs(tmp_path / ".tmp_step_00000007")
        assert mgr.list_steps() == []


class TestElastic:
    def test_plan_mesh_shrinks_data_axis(self):
        shape, used = plan_mesh(512, model_degree=16, pods=2)
        assert shape == (2, 16, 16) and used == 512
        shape, used = plan_mesh(500, model_degree=16, pods=2)
        assert shape == (2, 15, 16) and used == 480

    def test_plan_mesh_raises_when_impossible(self):
        with pytest.raises(RuntimeError):
            plan_mesh(8, model_degree=16)

    def test_shard_plan_deterministic_and_disjoint(self):
        a = shard_plan(0, step=7, n_shards=4, shard=1, global_batch=64)
        b = shard_plan(0, step=7, n_shards=4, shard=1, global_batch=64)
        assert a == b
        all_ids = sum((shard_plan(0, 7, 4, s, 64) for s in range(4)), [])
        assert len(set(all_ids)) == 64  # disjoint cover

    def test_watchdog_ejects_persistent_straggler(self):
        wd = StragglerWatchdog(threshold=2.0, patience=3)
        for i in range(3):
            eject = wd.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0})
        assert eject == [3]

    def test_watchdog_forgives_transient(self):
        wd = StragglerWatchdog(threshold=2.0, patience=3)
        wd.observe({0: 1.0, 1: 5.0})
        eject = wd.observe({0: 1.0, 1: 1.0})
        assert eject == []

    def test_controller_fail_recover(self):
        c = ElasticController(n_devices=512, model_degree=16, pods=2)
        c.fail(range(10))
        plan = c.current_plan()
        assert plan["mesh_shape"] == (2, 15, 16)
        c.recover(range(10))
        assert c.current_plan()["mesh_shape"] == (2, 16, 16)
