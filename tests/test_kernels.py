"""Per-kernel allclose validation against the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (this container is CPU-only; TPU
is the compile target) and is swept over shapes/dtypes with hypothesis.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ivf_probe.ops import ivf_probe_topk, ivf_probe_topk_batch
from repro.kernels.ivf_probe.ref import (ivf_probe_topk_batch_ref,
                                         ivf_probe_topk_ref)
from repro.kernels.mips_topk.ops import mips_topk
from repro.kernels.mips_topk.ref import mips_topk_ref
from repro.kernels.mwu_update.ops import mwu_update
from repro.kernels.mwu_update.ref import mwu_update_ref
from repro.kernels.mwem_step import ops as step_ops
from repro.kernels.mwem_step.ref import UPDATE_RULES, mwem_step_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref, ssd_chunked_jnp


class TestMipsTopk:
    @given(n=st.integers(8, 300), d=st.integers(4, 70),
           k=st.integers(1, 16), seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, n, d, k, seed):
        k = min(k, n)
        rng = np.random.default_rng(seed)
        V = rng.standard_normal((n, d)).astype(np.float32)
        q = rng.standard_normal((d,)).astype(np.float32)
        idx_k, s_k = mips_topk(jnp.asarray(V), jnp.asarray(q), k,
                               block_n=64, block_d=32)
        idx_r, s_r = mips_topk_ref(jnp.asarray(V), jnp.asarray(q), k)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-5, atol=1e-5)
        # indices may differ on exact ties; scores already checked — compare sets
        assert set(np.asarray(idx_k).tolist()) == set(np.asarray(idx_r).tolist())

    def test_bf16_inputs(self):
        rng = np.random.default_rng(0)
        V = jnp.asarray(rng.standard_normal((256, 128)), jnp.bfloat16)
        q = jnp.asarray(rng.standard_normal((128,)), jnp.bfloat16)
        idx_k, s_k = mips_topk(V, q, 8, block_n=128, block_d=64)
        idx_r, s_r = mips_topk_ref(V.astype(jnp.float32), q.astype(jnp.float32), 8)
        # bf16 rounding: require ≥75% top-8 recall and close scores
        inter = set(np.asarray(idx_k).tolist()) & set(np.asarray(idx_r).tolist())
        assert len(inter) >= 6


class TestMipsTopkAbs:
    @given(n=st.integers(8, 300), d=st.integers(4, 70),
           k=st.integers(1, 16), seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_absolute_mode_matches_jnp(self, n, d, k, seed):
        k = min(k, n)
        rng = np.random.default_rng(seed)
        V = rng.standard_normal((n, d)).astype(np.float32)
        q = rng.standard_normal((d,)).astype(np.float32)
        idx_k, s_k = mips_topk(jnp.asarray(V), jnp.asarray(q), k,
                               block_n=64, block_d=32, absolute=True)
        s_r, i_r = jax.lax.top_k(jnp.abs(jnp.asarray(V) @ jnp.asarray(q)), k)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-5, atol=1e-5)
        assert set(np.asarray(idx_k).tolist()) == set(np.asarray(i_r).tolist())


def _ivf_structure(n, dim, nlist, cap, seed, integer=False):
    """A small IVF layout: random rows dealt round-robin into padded cells,
    centroids = member means, plus the cell-grouped row copy the kernel
    streams from. ``integer`` data makes every dot exactly representable so
    scores collide — the tie-break parity regime."""
    rng = np.random.default_rng(seed)
    if integer:
        V = rng.integers(-4, 5, size=(n, dim)).astype(np.float32)
    else:
        V = rng.standard_normal((n, dim)).astype(np.float32)
    perm = rng.permutation(n)
    cells = np.full((nlist, cap), -1, np.int32)
    for j, idx in enumerate(perm):
        c, s = j % nlist, j // nlist
        if s < cap:
            cells[c, s] = idx
    cents = np.zeros((nlist, dim), np.float32)
    for c in range(nlist):
        members = cells[c][cells[c] >= 0]
        if len(members):
            cents[c] = V[members].mean(0)
    cell_rows = V[np.clip(cells, 0, None)] * (cells >= 0)[..., None]
    return tuple(map(jnp.asarray, (V, cents, cells, cell_rows)))


class TestIVFProbe:
    """Interpret-mode parity for the fused IVF probe vs the XLA reference —
    exact index/score agreement, ties broken identically (the stable
    incremental merge equals one stable top_k in the same candidate
    order)."""

    @given(n=st.integers(40, 400), d=st.integers(4, 48),
           k=st.integers(1, 16), nprobe=st.integers(1, 6),
           seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_matches_ref(self, n, d, k, nprobe, seed):
        nlist = max(4, int(np.sqrt(n)))
        cap = -(-n // nlist) + 2
        nprobe = min(nprobe, nlist)
        V, cents, cells, cell_rows = _ivf_structure(n, d, nlist, cap, seed)
        q = jnp.asarray(np.random.default_rng(seed + 1)
                        .standard_normal(d).astype(np.float32))
        for absolute in (False, True):
            i_k, s_k, n_k = ivf_probe_topk(cents, cell_rows, cells, q, k,
                                           nprobe, interpret=True,
                                           absolute=absolute)
            i_r, s_r, n_r = ivf_probe_topk_ref(cents, cells, V, q, k,
                                               nprobe, absolute=absolute)
            np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
            np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                       rtol=1e-6, atol=1e-6)
            assert int(n_k) == int(n_r)

    def test_tie_break_parity(self):
        """Integer-valued rows make duplicate scores the norm; the kernel
        must pick the *same* candidates in the same slots as the ref."""
        V, cents, cells, cell_rows = _ivf_structure(
            200, 16, 10, 24, seed=7, integer=True)
        q = jnp.asarray(np.random.default_rng(3)
                        .integers(-3, 4, size=16).astype(np.float32))
        i_k, s_k, _ = ivf_probe_topk(cents, cell_rows, cells, q, 12, 5,
                                     interpret=True)
        i_r, s_r, _ = ivf_probe_topk_ref(cents, cells, V, q, 12, 5)
        np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))

    def test_overfill_pads_minus_one(self):
        """k beyond the probed cells' valid rows pads ids with −1/−inf."""
        V, cents, cells, cell_rows = _ivf_structure(30, 8, 6, 8, seed=2)
        q = jnp.asarray(np.ones(8, np.float32))
        k = 12  # > the ~10 valid rows in two probed cells (5 each + pads)
        i_k, s_k, _ = ivf_probe_topk(cents, cell_rows, cells, q, k, 2,
                                     interpret=True)
        i_r, s_r, _ = ivf_probe_topk_ref(cents, cells, V, q, k, 2)
        np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
        assert (np.asarray(i_k) == -1).any()
        assert np.isneginf(np.asarray(s_k)[np.asarray(i_k) == -1]).all()

    @given(b=st.integers(1, 6), k=st.integers(1, 12),
           nprobe=st.integers(1, 5), seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_batch_matches_ref(self, b, k, nprobe, seed):
        n, d, nlist, cap = 240, 20, 12, 24
        V, cents, cells, cell_rows = _ivf_structure(n, d, nlist, cap, seed)
        Vb = jnp.asarray(np.random.default_rng(seed + 5)
                         .standard_normal((b, d)).astype(np.float32))
        for absolute in (False, True):
            i_k, s_k, n_k = ivf_probe_topk_batch(
                cents, cell_rows, cells, Vb, k, nprobe, interpret=True,
                absolute=absolute)
            i_r, s_r, n_r = ivf_probe_topk_batch_ref(
                cents, cells, V, Vb, k, nprobe, absolute=absolute)
            np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
            np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))

    def test_batch_lane_matches_single_probe(self):
        """Away from exact ties, each wave lane retrieves the same
        candidate set as its standalone probe (dedup/masking is invisible)."""
        n, d, nlist, cap, k, nprobe = 300, 24, 16, 24, 10, 4
        V, cents, cells, cell_rows = _ivf_structure(n, d, nlist, cap, 11)
        Vb = jnp.asarray(np.random.default_rng(6)
                         .standard_normal((5, d)).astype(np.float32))
        ib, sb, _ = ivf_probe_topk_batch(cents, cell_rows, cells, Vb, k,
                                         nprobe, interpret=True)
        for b in range(5):
            i1, s1, _ = ivf_probe_topk(cents, cell_rows, cells, Vb[b], k,
                                       nprobe, interpret=True)
            assert (set(np.asarray(ib[b]).tolist())
                    == set(np.asarray(i1).tolist()))
            np.testing.assert_allclose(np.sort(np.asarray(sb[b])),
                                       np.sort(np.asarray(s1)),
                                       rtol=1e-5, atol=1e-5)


class TestMWUUpdate:
    @given(u=st.integers(4, 5000), seed=st.integers(0, 10_000),
           coef=st.floats(-3, 3))
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, u, seed, coef):
        rng = np.random.default_rng(seed)
        lw = rng.standard_normal(u).astype(np.float32) * 3
        c = rng.uniform(0, 1, u).astype(np.float32)
        lw_k, p_k = mwu_update(jnp.asarray(lw), jnp.asarray(c), coef, block_u=256)
        lw_r, p_r = mwu_update_ref(jnp.asarray(lw), jnp.asarray(c), coef)
        np.testing.assert_allclose(np.asarray(lw_k), np.asarray(lw_r),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r),
                                   rtol=2e-5, atol=1e-7)
        assert np.isclose(np.asarray(p_k).sum(), 1.0, atol=1e-5)


def _step_state(u, seed):
    """A carried (log_w, p, p_sum) triple honoring the max-shift invariant
    (max(log_w) == 0, p == softmax(log_w)) plus a row table and histogram."""
    rng = np.random.default_rng(seed)
    lw = rng.standard_normal(u).astype(np.float32) * 2
    lw = jnp.asarray(lw)
    lw = lw - jnp.max(lw)
    p = jax.nn.softmax(lw)
    ps = jnp.asarray(rng.uniform(0, 3, u).astype(np.float32))
    rows = jnp.asarray(rng.integers(0, 2, size=(16, u)).astype(np.float32))
    h = jnp.asarray(rng.uniform(0, 1, u).astype(np.float32))
    h = h / jnp.sum(h)
    return lw, p, ps, rows, h


class TestMWEMStep:
    """The iteration megakernel must be *bitwise* against the jit'd oracle —
    the fused drivers interleave kernel and XLA steps (overflow fallback),
    so any drift would break the host-vs-fused trace conformance tier."""

    @pytest.mark.parametrize("rule", UPDATE_RULES)
    @pytest.mark.parametrize("u", [128, 256])
    def test_bitwise_vs_ref(self, rule, u):
        lw, p, ps, rows, h = _step_state(u, seed=u)
        noise = jnp.float32(0.37)
        sel = jnp.int32(5)
        ref = jax.jit(lambda *a: mwem_step_ref(*a, rule=rule, eta=0.5))
        out_k = step_ops.mwem_step(lw, p, ps, rows, sel, h, noise,
                                   rule=rule, eta=0.5)
        out_r = ref(lw, p, ps, rows[5], h, noise)
        for a, b in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # invariant out: max-shifted log-weights, density sums to 1
        assert float(jnp.max(out_k[0])) == 0.0
        np.testing.assert_allclose(float(jnp.sum(out_k[1])), 1.0, atol=1e-5)

    @given(seed=st.integers(0, 10_000), sel=st.integers(0, 15),
           rule=st.sampled_from(UPDATE_RULES))
    @settings(max_examples=15, deadline=None)
    def test_bitwise_sweep(self, seed, sel, rule):
        lw, p, ps, rows, h = _step_state(128, seed)
        noise = jnp.float32(np.random.default_rng(seed).laplace() * 0.1)
        ref = jax.jit(lambda *a: mwem_step_ref(*a, rule=rule, eta=0.3))
        out_k = step_ops.mwem_step(lw, p, ps, rows, jnp.int32(sel), h, noise,
                                   rule=rule, eta=0.3)
        out_r = ref(lw, p, ps, rows[sel], h, noise)
        for a, b in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("shared_h", [True, False])
    def test_batch_matches_single_lanes(self, shared_h):
        B, u = 5, 128
        states = [_step_state(u, seed=100 + b) for b in range(B)]
        lw = jnp.stack([s[0] for s in states])
        p = jnp.stack([s[1] for s in states])
        ps = jnp.stack([s[2] for s in states])
        rows = states[0][3]
        h = states[0][4] if shared_h else jnp.stack([s[4] for s in states])
        sel = jnp.arange(B, dtype=jnp.int32) % rows.shape[0]
        noise = jnp.linspace(-0.2, 0.2, B, dtype=jnp.float32)
        out_b = step_ops.mwem_step_batch(lw, p, ps, rows, sel, h, noise,
                                         rule="hardt", eta=0.5)
        for b in range(B):
            hb = h if shared_h else h[b]
            out_1 = step_ops.mwem_step(lw[b], p[b], ps[b], rows, sel[b], hb,
                                       noise[b], rule="hardt", eta=0.5)
            # batch and single are *different jit programs*: on CPU the
            # interpret-mode emulation may fuse the dot reductions
            # differently, so lanes agree to 1 ulp here (on TPU the grid
            # programs share one kernel body and match bitwise)
            for a, s in zip(out_b, out_1):
                np.testing.assert_allclose(np.asarray(a[b]), np.asarray(s),
                                           rtol=3e-7, atol=3e-7)

    def test_unsupported_shape_falls_back(self):
        # U = 96 is not lane-aligned: the wrapper must silently take the ref
        lw, p, ps, rows, h = _step_state(96, seed=9)
        ref = jax.jit(lambda *a: mwem_step_ref(*a, rule="signed", eta=0.4))
        out_k = step_ops.mwem_step(lw, p, ps, rows, jnp.int32(3), h,
                                   jnp.float32(0.1), rule="signed", eta=0.4)
        out_r = ref(lw, p, ps, rows[3], h, jnp.float32(0.1))
        for a, b in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_supported_gate(self):
        assert step_ops.mwem_step_supported(128)
        assert step_ops.mwem_step_supported(1024)
        assert not step_ops.mwem_step_supported(96)       # not lane-aligned
        assert not step_ops.mwem_step_supported(1 << 20)  # VMEM blowout

    def test_bad_rule_raises(self):
        lw, p, ps, rows, h = _step_state(128, seed=1)
        with pytest.raises(ValueError, match="rule"):
            step_ops.mwem_step(lw, p, ps, rows, jnp.int32(0), h,
                               jnp.float32(0.0), rule="nope", eta=0.5)

    @given(seed=st.integers(0, 10_000), c=st.integers(1, 40))
    @settings(max_examples=15, deadline=None)
    def test_aug_gather_score_bitwise(self, seed, c):
        rng = np.random.default_rng(seed)
        m, u = 64, 128
        Q = jnp.asarray(rng.integers(0, 2, size=(m, u)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal(u).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 2 * m, size=c).astype(np.int32))
        ref = jax.jit(lambda Q, v, i: (Q[i % m] @ v)
                      * jnp.where(i < m, 1.0, -1.0))
        got = step_ops.aug_gather_score(Q, v, idx)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref(Q, v, idx)))

    def test_mwu_apply_matches_step(self):
        """The sharded tail's materialized-row variant is the same body."""
        lw, p, ps, rows, h = _step_state(128, seed=21)
        out_a = step_ops.mwu_apply(lw, p, ps, rows[7], h, jnp.float32(0.2),
                                   rule="hardt", eta=0.5)
        out_s = step_ops.mwem_step(lw, p, ps, rows, jnp.int32(7), h,
                                   jnp.float32(0.2), rule="hardt", eta=0.5)
        for a, b in zip(out_a, out_s):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFlashAttention:
    @pytest.mark.parametrize("mode,window", [
        ("full", 0), ("causal", 0), ("window", 16), ("chunk", 32)])
    def test_modes_match_ref(self, mode, window):
        rng = np.random.default_rng(0)
        B, Hq, Hkv, S, D = 2, 4, 2, 80, 16
        q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
        out_k = flash_attention(q, k, v, mode=mode, window=window,
                                block_q=32, block_kv=32)
        out_r = attention_ref(q, k, v, mode=mode, window=window)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)

    @given(b=st.integers(1, 3), g=st.integers(1, 4), hkv=st.integers(1, 3),
           sq=st.integers(1, 40), skv=st.integers(8, 80), d=st.integers(4, 24),
           seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_decode_offset_sweep(self, b, g, hkv, sq, skv, d, seed):
        """decode / prefill-continuation: q rows sit at offset ≥ 0 in the cache."""
        rng = np.random.default_rng(seed)
        sq = min(sq, skv)
        q_offset = skv - sq
        q = jnp.asarray(rng.standard_normal((b, hkv * g, sq, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), jnp.float32)
        out_k = flash_attention(q, k, v, mode="causal", q_offset=q_offset,
                                block_q=16, block_kv=32)
        out_r = attention_ref(q, k, v, mode="causal", q_offset=q_offset)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)

    def test_softcap(self):
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, 32, 8)), jnp.float32)
        out_k = flash_attention(q, k, v, mode="causal", logit_softcap=20.0,
                                block_q=16, block_kv=16)
        out_r = attention_ref(q, k, v, mode="causal", logit_softcap=20.0)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.standard_normal((1, 2, 64, 16)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 1, 64, 16)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 1, 64, 16)), jnp.bfloat16)
        out_k = flash_attention(q, k, v, mode="causal", block_q=32, block_kv=32)
        out_r = attention_ref(q, k, v, mode="causal")
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=2e-2, atol=2e-2)


class TestSSDScan:
    @given(b=st.integers(1, 2), s=st.integers(3, 70), h=st.integers(1, 3),
           p=st.integers(2, 12), n=st.integers(2, 12), seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_kernel_matches_sequential(self, b, s, h, p, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.1, 2.0, (h,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        y_k = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
        y_r, _ = ssd_scan_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   rtol=2e-4, atol=2e-4)

    @given(s=st.integers(5, 90), chunk=st.sampled_from([8, 16, 32]),
           seed=st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_chunked_jnp_matches_sequential(self, s, chunk, seed):
        rng = np.random.default_rng(seed)
        b, h, p, n = 2, 2, 8, 4
        x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.1, 2.0, (h,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        y_c, hT_c = ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk=chunk)
        y_r, hT_r = ssd_scan_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hT_c), np.asarray(hT_r),
                                   rtol=2e-4, atol=2e-4)

    def test_state_continuation(self):
        """Chunked scan with h0 continues exactly (the decode path)."""
        rng = np.random.default_rng(9)
        b, s, h, p, n = 1, 48, 2, 4, 4
        x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.1, 2.0, (h,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
        y_full, hT = ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk=16)
        y1, h1 = ssd_chunked_jnp(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], chunk=16)
        y2, h2 = ssd_chunked_jnp(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                                 chunk=16, h0=h1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(hT), rtol=2e-4,
                                   atol=2e-4)
