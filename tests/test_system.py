"""End-to-end behaviour: the paper's pipeline feeding the framework.

DP release of corpus statistics via Fast-MWEM → train an LM on the
synthetic histogram → checkpoint → resume → serve. One small pass over
every layer of the system.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.private import PrivateDataPipeline
from repro.data.synthetic import SyntheticCorpus, batch_for_step
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import make_train_step


def test_dp_release_train_serve(tmp_path):
    cfg = get_smoke_config("llama3-8b").with_(dtype="float32")
    model = build_model(cfg)

    # 1. private corpus → Fast-MWEM DP release
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    raw = np.asarray(batch_for_step(corpus, 0, 0, 1, 32, 64))
    pipe = PrivateDataPipeline(vocab_size=cfg.vocab_size, eps=2.0,
                               n_queries=64, T=25, seed=0)
    pipe.fit(raw)
    eps, delta = pipe.privacy_spent()
    assert eps > 0 and delta > 0

    # 2. train on the released distribution (post-processing ⇒ DP)
    tcfg = TrainConfig(lr=5e-3, total_steps=30, warmup_steps=2, remat="none")
    opt_init, train_step = make_train_step(model, tcfg)
    train_step = jax.jit(train_step)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_state = opt_init(params)
    losses = []
    mgr = CheckpointManager(str(tmp_path))
    for step in range(12):
        tokens = pipe.sample_batch(step, 0, 4, 32)
        params, opt_state, m = train_step(params, opt_state,
                                          {"tokens": tokens})
        losses.append(float(m["loss"]))
    mgr.save(12, {"params": params}, block=True)
    assert losses[-1] < losses[0]

    # 3. crash-resume
    step, state = mgr.restore_latest({"params": params})
    assert step == 12

    # 4. serve the trained model
    engine = ServeEngine(model, state["params"], batch_size=2, max_len=48)
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=6) for _ in range(3)]
    engine.run(reqs)
    assert all(r.done and len(r.out_tokens) == 6 for r in reqs)
