"""Private LP solvers (paper §4, §5.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    DualLPConfig, ScalarLPConfig,
    solve_constraint_private_lp, solve_scalar_lp,
)
from repro.core.bregman import bregman_project_dense
from repro.core.queries import random_feasible_lp, random_packing_lp
from repro.mips import FlatIndex, IVFIndex


class TestBregman:
    @pytest.mark.slow
    @given(st.integers(4, 100), st.integers(1, 20), st.integers(0, 500))
    @settings(max_examples=50, deadline=None)
    def test_projection_is_dense_distribution(self, n, s, seed):
        s = min(s, n)
        a = np.abs(np.random.default_rng(seed).standard_normal(n)) + 1e-3
        y = np.asarray(bregman_project_dense(jnp.asarray(a, jnp.float32), float(s)))
        assert np.isclose(y.sum(), 1.0, atol=1e-4)
        assert y.max() <= 1.0 / s + 1e-4

    def test_lemma_a3_neighbor_stability(self):
        """Lemma A.3: projections of A and A∪{a'} differ by ≤ 1/s in L1."""
        rng = np.random.default_rng(7)
        s = 8
        for _ in range(20):
            a = np.abs(rng.standard_normal(50)) + 1e-3
            extra = abs(rng.standard_normal()) + 1e-3
            a_ext = np.concatenate([a, [extra]])
            y1 = np.asarray(bregman_project_dense(jnp.asarray(a, jnp.float32), s))
            y2 = np.asarray(bregman_project_dense(jnp.asarray(a_ext, jnp.float32), s))
            diff = np.abs(y1 - y2[:-1]).sum() + y2[-1]
            assert diff <= 2.0 / s + 5e-2  # statement bound + numeric slack

    def test_uniform_input_stays_uniform(self):
        a = jnp.ones((10,))
        y = np.asarray(bregman_project_dense(a, 5.0))
        np.testing.assert_allclose(y, 0.1, atol=1e-5)


@pytest.fixture(scope="module")
def lp_instance():
    A, b, x_star = random_feasible_lp(jax.random.PRNGKey(0), m=300, d=20)
    return A, b, x_star


class TestScalarLP:
    def test_exact_solver_low_violations(self, lp_instance):
        A, b, _ = lp_instance
        cfg = ScalarLPConfig(T=300, alpha=0.5, mode="exact")
        res = solve_scalar_lp(A, b, cfg, jax.random.PRNGKey(1))
        assert res.violated_frac <= 0.15

    def test_fast_matches_exact(self, lp_instance):
        """Fig. 5: Fast solver ≈ exhaustive solver on violated fraction."""
        A, b, _ = lp_instance
        Ab = np.concatenate([np.asarray(A), np.asarray(b)[:, None]], axis=1)
        index = FlatIndex(Ab, use_pallas="never")
        exact = solve_scalar_lp(A, b, ScalarLPConfig(T=200, mode="exact"),
                                jax.random.PRNGKey(2))
        fast = solve_scalar_lp(A, b, ScalarLPConfig(T=200, mode="fast"),
                               jax.random.PRNGKey(2), index=index)
        assert abs(exact.violated_frac - fast.violated_frac) < 0.12
        assert np.mean(fast.n_scored) < A.shape[0]

    def test_solution_on_simplex(self, lp_instance):
        A, b, _ = lp_instance
        res = solve_scalar_lp(A, b, ScalarLPConfig(T=50, mode="exact"),
                              jax.random.PRNGKey(3))
        x = np.asarray(res.x_bar)
        assert np.isclose(x.sum(), 1.0, atol=1e-4) and np.all(x >= 0)


class TestDualLP:
    def test_constraint_private_solver(self):
        A, b, c = random_packing_lp(jax.random.PRNGKey(4), m=150, d=40)
        # choose OPT so that K_OPT contains a near-feasible vertex mixture
        x0 = jnp.full((40,), 1.0 / 40)
        opt = float(c @ x0) * 0.5
        cfg = DualLPConfig(T=150, s=12, alpha=1.0, mode="exact")
        res = solve_constraint_private_lp(A, b, c, opt, cfg, jax.random.PRNGKey(5))
        # mass of badly-violated constraints is controlled
        assert res.n_violated <= A.shape[0] * 0.3
        assert np.isclose(float(jnp.sum(res.x_bar * c)), opt, rtol=1e-3)

    def test_fast_dual_with_index(self):
        A, b, c = random_packing_lp(jax.random.PRNGKey(6), m=100, d=64)
        x0 = jnp.full((64,), 1.0 / 64)
        opt = float(c @ x0) * 0.5
        N = np.asarray(-(opt / c)[:, None] * A.T)
        index = FlatIndex(N, use_pallas="never")
        cfg = DualLPConfig(T=100, s=10, alpha=1.0, mode="fast")
        res = solve_constraint_private_lp(A, b, c, opt, cfg, jax.random.PRNGKey(7),
                                          index=index)
        assert res.n_violated <= A.shape[0] * 0.35
        assert np.mean(res.n_scored) < 64
