"""Host-vs-megakernel conformance (DESIGN.md §7, ISSUE 6 acceptance).

The iteration megakernel must be *invisible*: for every driver × index ×
mode cell, the selection trace (the privacy-relevant artifact) of the
fused scan running the mega step — kernel or XLA ref, whatever
``use_pallas`` resolves to — must be bitwise the host loop's, and the
classic pre-fusion body (``use_pallas="never"``) must agree too. U = 128
here so the shape qualifies for the real kernel gate
(`mwem_step_supported`); the driver tier at U = 64 covers the mega-ref
fallback route.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MWEMConfig, run_mwem, run_mwem_batch, run_mwem_fused
from repro.core.mwem import _run_mwem_host
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.mips import FlatAbsIndex, IVFIndex, NSWIndex, augment_complement

U, M, N, T = 128, 128, 300, 10


@pytest.fixture(scope="module")
def workload():
    kh, kq = jax.random.split(jax.random.PRNGKey(0))
    h = gaussian_histogram(kh, N, U)
    Q = random_binary_queries(kq, M, U)
    return Q, h


def _indexes(Q):
    aug = augment_complement(np.asarray(Q))
    return {
        "flat": FlatAbsIndex(Q),
        "ivf": IVFIndex(aug, seed=0, train_iters=4),
        "nsw": NSWIndex(aug, deg=8, ef=24, rounds=3, seed=0),
    }


def _cfg(**kw):
    kw.setdefault("T", T)
    kw.setdefault("n_records", N)
    return MWEMConfig(**kw)


def _traces(res):
    return res.selected, res.n_scored, res.overflow_count


class TestHostMegaConformance:
    @pytest.mark.parametrize("use_pallas", ["auto", "always"])
    @pytest.mark.parametrize("kind", ["flat", "ivf", "nsw"])
    def test_fast_mode(self, workload, kind, use_pallas):
        Q, h = workload
        ix = _indexes(Q)[kind]
        key = jax.random.PRNGKey(7)
        host = _run_mwem_host(Q, h, _cfg(), key, index=ix)
        mega = run_mwem_fused(Q, h, _cfg(use_pallas=use_pallas), key, index=ix)
        assert _traces(mega) == _traces(host)
        np.testing.assert_allclose(np.asarray(mega.p_hat),
                                   np.asarray(host.p_hat),
                                   rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("use_pallas", ["auto", "always"])
    def test_exact_mode(self, workload, use_pallas):
        Q, h = workload
        key = jax.random.PRNGKey(3)
        host = _run_mwem_host(Q, h, _cfg(mode="exact"), key)
        mega = run_mwem_fused(Q, h, _cfg(mode="exact", use_pallas=use_pallas),
                              key)
        assert _traces(mega) == _traces(host)
        np.testing.assert_allclose(np.asarray(mega.p_hat),
                                   np.asarray(host.p_hat),
                                   rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("kind", ["flat", "ivf", "nsw"])
    def test_classic_body_unchanged(self, workload, kind):
        """``use_pallas="never"`` (the pre-fusion baseline) and the mega
        route tell the same story — fusing moved bytes, not math."""
        Q, h = workload
        ix = _indexes(Q)[kind]
        key = jax.random.PRNGKey(11)
        classic = run_mwem_fused(Q, h, _cfg(use_pallas="never"), key, index=ix)
        mega = run_mwem_fused(Q, h, _cfg(use_pallas="auto"), key, index=ix)
        assert _traces(mega) == _traces(classic)
        np.testing.assert_allclose(np.asarray(mega.p_hat),
                                   np.asarray(classic.p_hat),
                                   rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("kind", ["flat", "ivf", "nsw"])
    def test_forced_overflow_parity(self, workload, kind):
        """tail_cap=1 overflows nearly every iteration: the `lax.cond`
        fallback (which lives *outside* the kernel precisely for this)
        must redo selection with the same folded key as the host."""
        Q, h = workload
        ix = _indexes(Q)[kind]
        key = jax.random.PRNGKey(5)
        host = _run_mwem_host(Q, h, _cfg(tail_cap=1), key, index=ix)
        mega = run_mwem_fused(Q, h, _cfg(tail_cap=1, use_pallas="always"),
                              key, index=ix)
        assert host.overflow_count > 0  # the regime actually triggered
        assert _traces(mega) == _traces(host)

    def test_run_mwem_autoroutes_mega(self, workload):
        """The public entry point reaches the mega scan by default."""
        Q, h = workload
        ix = _indexes(Q)["ivf"]
        key = jax.random.PRNGKey(2)
        res = run_mwem(Q, h, _cfg(driver="fused"), key, index=ix)
        host = _run_mwem_host(Q, h, _cfg(), key, index=ix)
        assert _traces(res) == _traces(host)


class TestWavedConformance:
    def test_batch_lanes_match_single_runs(self, workload):
        Q, h = workload
        ix = _indexes(Q)["ivf"]
        keys = jax.random.split(jax.random.PRNGKey(9), 4)
        batch = run_mwem_batch(Q, h, _cfg(use_pallas="always"), keys, index=ix)
        for b, key in enumerate(keys):
            single = run_mwem_fused(Q, h, _cfg(use_pallas="always"), key,
                                    index=ix)
            assert [int(s) for s in batch.selected[b]] == single.selected
            # waved lanes run a different jit program than the single
            # scan — densities agree to float noise, traces exactly
            np.testing.assert_allclose(np.asarray(batch.p_hat[b]),
                                       np.asarray(single.p_hat),
                                       rtol=1e-5, atol=1e-6)

    def test_batch_never_vs_always(self, workload):
        Q, h = workload
        ix = _indexes(Q)["ivf"]
        keys = jax.random.split(jax.random.PRNGKey(4), 3)
        classic = run_mwem_batch(Q, h, _cfg(use_pallas="never"), keys,
                                 index=ix)
        mega = run_mwem_batch(Q, h, _cfg(use_pallas="always"), keys, index=ix)
        np.testing.assert_array_equal(np.asarray(mega.selected),
                                      np.asarray(classic.selected))


class TestShardedSeam:
    def test_sharded_mwu_seam_parity(self, workload):
        """1-device mesh: ``use_pallas="always"`` routes the sharded MWU
        tail + lazy tail scoring through the kernels; traces must match
        the XLA tail."""
        from repro.core.distributed import run_mwem_sharded
        from repro.mips.ivf import ShardedIVFIndex

        Q, h = workload
        key = jax.random.PRNGKey(8)
        out = {}
        for up in ("never", "always"):
            ix = ShardedIVFIndex(augment_complement(np.asarray(Q)),
                                 n_shards=1, seed=0, use_pallas=up)
            out[up] = run_mwem_sharded(Q, h, _cfg(), key, index=ix)
        assert _traces(out["always"]) == _traces(out["never"])
        np.testing.assert_allclose(np.asarray(out["always"].p_hat),
                                   np.asarray(out["never"].p_hat),
                                   rtol=1e-5, atol=1e-6)


class TestNoPerCallRecompilation:
    """The megakernel wrappers are module-level jits — repeat dispatches
    with fresh same-shaped arrays must hit the cache (the drivers call
    them every scan trace)."""

    def _burn(self, seed):
        from repro.kernels.mwem_step import ops as step_ops

        rng = np.random.default_rng(seed)
        lw = jnp.asarray(rng.standard_normal(U).astype(np.float32))
        lw = lw - jnp.max(lw)
        p = jax.nn.softmax(lw)
        ps = jnp.zeros((U,), jnp.float32)
        rows = jnp.asarray(rng.integers(0, 2, (M, U)).astype(np.float32))
        h = jnp.asarray(rng.uniform(0, 1, U).astype(np.float32))
        step_ops.mwem_step(lw, p, ps, rows, jnp.int32(1), h,
                           jnp.float32(0.1), rule="hardt", eta=0.5)
        step_ops.mwem_step_batch(lw[None], p[None], ps[None], rows,
                                 jnp.zeros((1,), jnp.int32), h,
                                 jnp.zeros((1,), jnp.float32),
                                 rule="hardt", eta=0.5)
        step_ops.aug_gather_score(rows, lw, jnp.arange(8, dtype=jnp.int32))
        step_ops.mwu_apply(lw, p, ps, rows[0], h, jnp.float32(0.1),
                           rule="hardt", eta=0.5)

    def test_step_ops_share_compiled_programs(self):
        from repro.kernels.mwem_step import ops as step_ops

        fns = (step_ops.mwem_step, step_ops.mwem_step_batch,
               step_ops.aug_gather_score, step_ops.mwu_apply)
        self._burn(0)
        sizes = [f._cache_size() for f in fns]
        self._burn(1)
        assert [f._cache_size() for f in fns] == sizes


class TestRoofline:
    def test_megakernel_halves_hbm_bytes(self):
        """ISSUE 6 acceptance: ≥2× modeled per-iteration HBM reduction."""
        from repro.analysis.roofline import mwem_step_roofline

        for m in (4096, 8192, 32768):
            mega = mwem_step_roofline(m=m, U=256, megakernel=True)
            classic = mwem_step_roofline(m=m, U=256, megakernel=False)
            assert mega["hbm_bytes"] * 2 <= classic["hbm_bytes"], m
