"""Factored query workloads (DESIGN.md §9): primitive correctness, the
dense-vs-factored bitwise conformance matrix, kernel/probe parity, the
adaptive worst-marginal loop, and the service marginal path.

The safety rail of the whole refactor is *bitwise* agreement between a
`MarginalWorkload` and its densified (m, U) matrix on every seam the
drivers consume — row construction, selection scoring, tail gathers, the
error metric — at shapes small enough to densify. The factored-only
scale behaviour (no (m, U) anywhere) is asserted separately at a
dense-infeasible shape in `benchmarks/bench_marginals.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MWEMConfig, run_mwem
from repro.core.adaptive import (AdaptiveConfig, run_adaptive_marginals,
                                 select_worst_marginal)
from repro.core.accountant import PrivacyLedger
from repro.core.queries import max_error, ngram_marginal_queries
from repro.core.workload import (DenseWorkload, MarginalWorkload, Workload,
                                 aug_decompose, as_workload)
from repro.mips import FlatAbsIndex, MarginalIVFIndex, build_index
from repro.kernels.ivf_probe import marginal_probe_topk_ref
from repro.kernels.mwem_step import ops as step_ops


CARD = (3, 2, 4, 2)          # U = 48, heterogeneous cardinalities


@pytest.fixture(scope="module")
def marg():
    W = MarginalWorkload.all_kway(CARD, 2)
    Qd = W.densify()
    key = jax.random.PRNGKey(0)
    h = jax.random.dirichlet(key, jnp.ones(W.U) * 0.4)
    v = h - jnp.full((W.U,), 1.0 / W.U)
    return W, Qd, h, v


class TestWorkloadPrimitives:
    def test_rows_match_densified(self, marg):
        W, Qd, _, _ = marg
        ids = jnp.arange(W.m)
        assert np.array_equal(np.asarray(W.rows(ids)), np.asarray(Qd))

    def test_row_sums_are_marginal_partitions(self, marg):
        """Each clique's cells partition the domain: summing its rows gives
        the all-ones vector, and each row's support is U / clique cells."""
        W, Qd, _, _ = marg
        Q = np.asarray(Qd)
        for c in range(W.n_cliques):
            lo, hi = W.clique_slice(c)
            assert np.array_equal(Q[lo:hi].sum(axis=0), np.ones(W.U))

    def test_scores_bitwise_vs_dense(self, marg):
        W, Qd, _, v = marg
        assert W.m <= W.score_block  # the parity regime
        s_f = np.asarray(W.scores(v))
        s_d = np.asarray(DenseWorkload(Qd).scores(v))
        assert np.array_equal(s_f, s_d)

    def test_answer_all_matches_dense(self, marg):
        W, Qd, _, v = marg
        np.testing.assert_allclose(np.asarray(W.answer_all(v)),
                                   np.asarray(Qd @ v), rtol=0, atol=1e-6)

    def test_score_in_graph_sign_convention(self, marg):
        W, Qd, _, v = marg
        ids = jnp.arange(2 * W.m, dtype=jnp.int32)
        got = np.asarray(W.score_in_graph(v, ids))
        base, sign = aug_decompose(ids, W.m)
        want = np.asarray((Qd[base] @ v) * sign)
        assert np.array_equal(got, want)
        # and the complement identity itself: ⟨1−q, v⟩ = −⟨q, v⟩ for Σv=0
        np.testing.assert_allclose(np.asarray((1.0 - Qd) @ v),
                                   -np.asarray(Qd @ v), atol=1e-6)

    def test_blockwise_scores_match(self, marg):
        W, _, _, v = marg
        Wb = MarginalWorkload(CARD, W.cliques, score_block=7, clique_chunk=2)
        np.testing.assert_allclose(np.asarray(Wb.scores(v)),
                                   np.asarray(W.scores(v)), atol=1e-5)
        np.testing.assert_allclose(np.asarray(Wb.answer_all(v)),
                                   np.asarray(W.answer_all(v)), atol=1e-6)

    def test_clique_abs_err(self, marg):
        W, Qd, _, v = marg
        got = np.asarray(W.clique_abs_err(v))
        per_q = np.abs(np.asarray(Qd @ v))
        want = np.array([per_q[slice(*W.clique_slice(c))].max()
                         for c in range(W.n_cliques)])
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_max_err_matches_dense_metric(self, marg):
        """Satellite: the workload-aware `max_error` overload. Dense arrays
        keep the pre-refactor expression byte-for-byte; the factored path
        agrees to segment-sum accuracy."""
        W, Qd, h, _ = marg
        p = jax.nn.softmax(jnp.arange(W.U, dtype=jnp.float32) / W.U)
        dense_legacy = jnp.max(jnp.abs(Qd @ (p - h)))
        assert np.array_equal(np.asarray(max_error(Qd, h, p)),
                              np.asarray(dense_legacy))
        assert np.array_equal(np.asarray(max_error(DenseWorkload(Qd), h, p)),
                              np.asarray(dense_legacy))
        np.testing.assert_allclose(float(max_error(W, h, p)),
                                   float(dense_legacy), atol=1e-6)

    def test_pytree_roundtrip_and_jit_arg(self, marg):
        W, _, _, v = marg
        leaves, treedef = jax.tree_util.tree_flatten(W)
        W2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert np.array_equal(np.asarray(W2.scores(v)),
                              np.asarray(W.scores(v)))

        calls = []

        @jax.jit
        def f(wl, x):
            calls.append(1)
            return wl.answer_all(x)

        f(W, v)
        f(W2, v)       # same treedef/shapes → no retrace
        assert len(calls) == 1

    def test_densify_limit_raises(self, marg):
        W, _, _, _ = marg
        with pytest.raises(ValueError, match="refuses to materialize"):
            W.require_dense("test", limit=16)

    def test_as_workload(self, marg):
        W, Qd, _, _ = marg
        assert as_workload(W) is W
        dw = as_workload(Qd)
        assert isinstance(dw, DenseWorkload) and dw.is_dense
        assert not W.is_dense
        assert W.dense_nbytes == 4 * W.m * W.U

    def test_all_kway_enumeration(self):
        W = MarginalWorkload.all_kway((2, 3, 2), 2)
        assert W.n_cliques == 3
        assert W.m == 2 * 3 + 2 * 2 + 3 * 2
        assert W.U == 12


class TestNgramQueriesRegression:
    def test_rows_sum_to_arity(self):
        """Regression: the old randint draw repeated indices, so `.at[].set`
        silently produced rows summing below ``arity``."""
        Q = ngram_marginal_queries(jax.random.PRNGKey(3), 64, 96, arity=48)
        sums = np.asarray(Q.sum(axis=1))
        assert np.array_equal(sums, np.full(64, 48.0))
        assert set(np.unique(np.asarray(Q))) == {0.0, 1.0}

    def test_arity_exceeding_domain_raises(self):
        with pytest.raises(ValueError, match="arity"):
            ngram_marginal_queries(jax.random.PRNGKey(0), 4, 8, arity=9)


class TestConformanceMatrix:
    """Dense-vs-factored bitwise parity of full (Fast-)MWEM runs:
    {exact, fast} × {Flat, MarginalIVF} × {host, fused}."""

    N = 2000

    def _cfg(self, mode, driver, **kw):
        return MWEMConfig(eps=1.0, delta=1e-3, T=8, mode=mode, driver=driver,
                          n_records=self.N, use_pallas="never", **kw)

    @pytest.mark.parametrize("driver", ["host", "fused"])
    def test_exact_bitwise(self, marg, driver):
        W, Qd, h, _ = marg
        cfg = self._cfg("exact", driver)
        r_d = run_mwem(Qd, h, cfg, jax.random.PRNGKey(1))
        r_f = run_mwem(W, h, cfg, jax.random.PRNGKey(1))
        assert np.array_equal(np.asarray(r_d.p_hat), np.asarray(r_f.p_hat))
        assert np.array_equal(np.asarray(r_d.selected),
                              np.asarray(r_f.selected))
        # the mechanism outputs above are bitwise; the reported error
        # metric is post-processing and its factored path answers through
        # segment sums, so it agrees only to reassociation accuracy
        np.testing.assert_allclose(float(r_d.final_error),
                                   float(r_f.final_error), rtol=1e-5)

    @pytest.mark.parametrize("driver", ["host", "fused"])
    def test_fast_flat_bitwise(self, marg, driver):
        W, Qd, h, _ = marg
        cfg = self._cfg("fast", driver, k=8)
        r_d = run_mwem(Qd, h, cfg, jax.random.PRNGKey(2),
                       index=FlatAbsIndex(Qd, use_pallas="never"))
        r_f = run_mwem(W, h, cfg, jax.random.PRNGKey(2),
                       index=FlatAbsIndex(W, use_pallas="never"))
        assert np.array_equal(np.asarray(r_d.p_hat), np.asarray(r_f.p_hat))
        assert np.array_equal(np.asarray(r_d.selected),
                              np.asarray(r_f.selected))

    def test_fast_marginal_ivf_driver_parity(self, marg):
        """The clique-structured index has no dense twin; its rail is
        fused-vs-host bitwise parity plus probe-level parity below."""
        W, _, h, _ = marg
        idx = MarginalIVFIndex(W)
        r_fu = run_mwem(W, h, self._cfg("fast", "fused", k=8),
                        jax.random.PRNGKey(2), index=idx)
        r_ho = run_mwem(W, h, self._cfg("fast", "host", k=8),
                        jax.random.PRNGKey(2), index=idx)
        assert np.array_equal(np.asarray(r_fu.p_hat), np.asarray(r_ho.p_hat))
        assert np.array_equal(np.asarray(r_fu.selected),
                              np.asarray(r_ho.selected))

    def test_fast_reduces_error(self, marg):
        W, _, h, _ = marg
        cfg = MWEMConfig(eps=2.0, delta=1e-3, T=30, mode="fast",
                         n_records=self.N, use_pallas="never")
        res = run_mwem(W, h, cfg, jax.random.PRNGKey(5),
                       index=MarginalIVFIndex(W))
        uniform = float(max_error(W, h, jnp.full((W.U,), 1.0 / W.U)))
        assert float(res.final_error) < uniform

    def test_sharded_requires_densifiable(self, marg):
        """Explicit sharded routing on a factored workload goes through the
        documented densify fallback — small shapes densify, and the
        auto-router never silently shards a beyond-limit workload."""
        from repro.core.mwem import _resolve_driver
        cfg = self._cfg("exact", "auto")
        assert _resolve_driver(cfg, None, mesh=None, shape=(10, 10),
                               densifiable=False) != "sharded"


class TestMarginalIVFIndex:
    def test_full_probe_matches_exhaustive(self, marg):
        W, _, _, v = marg
        flat = FlatAbsIndex(W, use_pallas="never")
        full = MarginalIVFIndex(W, nprobe=W.n_cliques)
        af, sf = flat.query(v, 8)
        am, sm = full.query(v, 8)
        assert np.array_equal(np.asarray(af), np.asarray(am))
        np.testing.assert_allclose(np.asarray(sf), np.asarray(sm), atol=1e-6)

    def test_nprobe_covers_k(self, marg):
        """Top-k exactness needs the probed cliques to cover ≥ k cells; the
        index widens nprobe automatically for large k."""
        W, _, _, v = marg
        idx = MarginalIVFIndex(W, nprobe=1)
        k = W.m  # worst case: every query requested
        aug, scores = idx.query(v, k)
        af, sf = FlatAbsIndex(W, use_pallas="never").query(v, k)
        np.testing.assert_allclose(np.asarray(scores), np.asarray(sf),
                                   atol=1e-6)

    def test_with_scores_surface(self, marg):
        W, _, _, v = marg
        idx = MarginalIVFIndex(W)
        assert idx.has_full_scores and idx.supports_in_graph
        aug, top_a, s_full = idx.query_in_graph_with_scores(v, 4)
        np.testing.assert_allclose(np.asarray(s_full),
                                   np.asarray(W.answer_all(v)), atol=1e-6)
        assert idx.query_cost(4) < 2 * W.m  # sublinear vs augmented scan

    def test_factory_and_type_guard(self, marg):
        W, Qd, _, _ = marg
        assert isinstance(build_index("marginal_ivf", W), MarginalIVFIndex)
        with pytest.raises(TypeError, match="MarginalWorkload"):
            MarginalIVFIndex(np.asarray(Qd))

    def test_probe_ref_pad_cells_masked(self, marg):
        W, _, _, v = marg
        tabs = W.marginal_tables(v)
        starts = jnp.asarray(np.concatenate(
            [[0], np.cumsum(np.asarray(W.cl_cells))[:-1]]).astype(np.int32))
        aug, top_a, n_scored = marginal_probe_topk_ref(
            tabs, W.cl_cells, starts, W.m, 6, W.n_cliques)
        assert int(n_scored) == W.m          # pads excluded from the count
        assert np.all(np.asarray(aug) < 2 * W.m)
        base, _ = aug_decompose(aug, W.m)
        assert np.all(np.asarray(base) < W.m)


class TestKernelSeam:
    def test_marginal_gather_score_matches_workload(self, marg):
        """The kernel-route factored tail scorer (`marginal_gather_score`)
        agrees with the workload's traceable gather — on CPU it exercises
        the XLA fallback; the Pallas program itself is covered in interpret
        mode below."""
        W, _, _, v = marg
        ids = jnp.asarray([0, 3, W.m - 1, W.m, W.m + 5, 2 * W.m - 1],
                          jnp.int32)
        got = np.asarray(step_ops.marginal_gather_score(W, v, ids))
        want = np.asarray(W.score_in_graph(v, ids))
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_marginal_score_pallas_interpret(self):
        """The Pallas gather-score program at a lane-aligned domain,
        interpret mode (runs anywhere)."""
        from repro.kernels.mwem_step.mwem_step import (
            marginal_gather_score_pallas)
        W = MarginalWorkload.all_kway((2, 4, 4, 4), 2)  # U = 128
        v = jax.random.normal(jax.random.PRNGKey(0), (W.U,), jnp.float32)
        ids = jnp.asarray([1, 7, W.m - 2, W.m + 3, 2 * W.m - 1], jnp.int32)
        base, sign = aug_decompose(ids, W.m)
        cl = W.q_clique[base]
        tab = jnp.concatenate([W.cl_dstride[cl], W.cl_card[cl],
                               W.cl_stride[cl]], axis=1)
        got = marginal_gather_score_pallas(
            tab, W.q_offset[base], sign.astype(jnp.float32), v,
            kmax=W.kmax, interpret=True)
        want = np.asarray(W.score_in_graph(v, ids))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


class TestAdaptiveMarginals:
    def test_run_improves_and_accounts(self, marg):
        W, _, h, _ = marg
        led = PrivacyLedger()
        cfg = AdaptiveConfig(eps=2.0, delta=1e-3, T=6, n_records=5000)
        res = run_adaptive_marginals(W, h, cfg, jax.random.PRNGKey(4),
                                     ledger=led)
        uniform = float(max_error(W, h, jnp.full((W.U,), 1.0 / W.U)))
        assert float(res.final_error) < uniform
        assert res.selected.shape == (6,)
        assert len(led.events) == 12        # EM + measurement per round
        assert res.eps_spent > 0.0
        np.testing.assert_allclose(float(jnp.sum(res.p_hat)), 1.0, atol=1e-5)

    def test_selection_tracks_worst_clique(self, marg):
        W, _, _, v = marg
        res = select_worst_marginal(jax.random.PRNGKey(9), W, v, scale=1e6)
        worst = int(jnp.argmax(W.clique_abs_err(v)))
        assert int(res.index) == worst

    def test_requires_marginal_workload(self, marg):
        _, Qd, h, _ = marg
        cfg = AdaptiveConfig(T=2, n_records=100)
        with pytest.raises(TypeError, match="MarginalWorkload"):
            run_adaptive_marginals(DenseWorkload(Qd), h, cfg,
                                   jax.random.PRNGKey(0))


class TestServiceMarginalPath:
    def _service(self, Q, **kw):
        from repro.serve.release_service import ReleaseService
        cfg = MWEMConfig(eps=1.0, delta=1e-3, T=6, mode="fast",
                         n_records=2000, use_pallas="never")
        return ReleaseService(Q, cfg, **kw)

    def test_release_parity_with_dense_service(self, marg):
        W, Qd, h, _ = marg
        hn = np.asarray(h, np.float32)
        outs = []
        for Q in (W, Qd):
            svc = self._service(Q, wave_size=1, index_kind="flat", seed=7)
            svc.create_session("t", eps_budget=50.0, delta_budget=1e-2,
                               h=hn, n_records=2000)
            t = svc.submit("t")
            assert t.status == "done"
            outs.append(np.asarray(t.release.p_hat))
        assert np.array_equal(outs[0], outs[1])

    def test_marginal_ivf_release_and_accounting(self, marg):
        W, _, h, _ = marg
        svc = self._service(W, wave_size=2, index_kind="marginal_ivf")
        assert isinstance(svc.index, MarginalIVFIndex)
        hn = np.asarray(h, np.float32)
        for t_id in ("a", "b"):
            svc.create_session(t_id, eps_budget=50.0, delta_budget=1e-2,
                               h=hn, n_records=2000)
        t1, t2 = svc.submit("a"), svc.submit("b")
        assert t1.status == t2.status == "done"
        assert np.isfinite(t1.final_error)
        assert svc.session("a").ledger.composed()[0] > 0.0
        ans = svc.answer("a", np.ones(W.U, np.float32))
        np.testing.assert_allclose(ans.value, 1.0, atol=1e-4)

    def test_ivf_kind_routes_factored(self, marg):
        W, Qd, _, _ = marg
        assert isinstance(
            self._service(W, wave_size=1, index_kind="ivf").index,
            MarginalIVFIndex)
        with pytest.raises(ValueError, match="marginal_ivf"):
            self._service(np.asarray(Qd), wave_size=1,
                          index_kind="marginal_ivf")


class TestDenseInfeasibleScale:
    """Acceptance shape: ≥ 2^15 cells and m ≥ 10^4 runs end to end without
    a dense table (densifying would need ≥ 2 GiB)."""

    @pytest.fixture(scope="class")
    def big(self):
        W = MarginalWorkload.all_kway((2,) * 15, 4, max_cliques=1100)
        assert W.U == 2 ** 15 and W.m >= 10_000
        assert W.dense_nbytes > 2 ** 31
        key = jax.random.PRNGKey(0)
        logits = jax.random.normal(key, (W.U,)) * 2.0
        return W, jax.nn.softmax(logits)

    def test_run_mwem_completes(self, big):
        W, h = big
        cfg = MWEMConfig(eps=1.0, delta=1e-3, T=3, mode="fast",
                         n_records=10_000, k=64, use_pallas="never")
        res = run_mwem(W, h, cfg, jax.random.PRNGKey(1),
                       index=MarginalIVFIndex(W))
        assert np.isfinite(float(res.final_error))

    def test_service_release_completes(self, big):
        W, h = big
        svc_cfg = MWEMConfig(eps=1.0, delta=1e-3, T=3, mode="fast",
                             n_records=10_000, k=64, use_pallas="never")
        from repro.serve.release_service import ReleaseService
        svc = ReleaseService(W, svc_cfg, wave_size=1,
                             index_kind="marginal_ivf")
        svc.create_session("big", eps_budget=50.0, delta_budget=1e-2,
                           h=np.asarray(h, np.float32), n_records=10_000)
        t = svc.submit("big")
        assert t.status == "done" and np.isfinite(t.final_error)

    def test_explicit_densify_refused(self, big):
        W, _ = big
        with pytest.raises(ValueError, match="refuses to materialize"):
            W.require_dense("test-scale")
