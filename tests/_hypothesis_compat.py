"""Import-or-skip shim for the optional ``hypothesis`` dev dependency.

Property tests import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly. When hypothesis is installed the real objects
are re-exported unchanged; when it is absent the decorated tests are
collected but skipped, so the suite never fails at import time.

``hypothesis`` is listed under the ``dev`` optional dependencies in
pyproject.toml.
"""

from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning an inert placeholder, so strategy expressions at
        decoration time (``st.integers(1, 5)``) evaluate without error."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    class HealthCheck:  # noqa: D401 - attribute bag
        all = staticmethod(lambda: ())


__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
