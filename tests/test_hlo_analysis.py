"""Validate the trip-count-aware HLO cost parser against XLA's own numbers
on loop-free (unrolled) modules, and its loop handling on scanned ones."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import roofline_terms


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _xla_cost(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on newer JAX and a
    one-per-partition list on older releases — normalize to the dict."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


class TestFlops:
    def test_matches_xla_on_unrolled(self):
        D, F, L = 64, 128, 4

        def f(w1, w2, x):
            for _ in range(L):
                x = jnp.tanh(x @ w1) @ w2
            return x

        w1 = jax.ShapeDtypeStruct((D, F), jnp.float32)
        w2 = jax.ShapeDtypeStruct((F, D), jnp.float32)
        x = jax.ShapeDtypeStruct((32, D), jnp.float32)
        compiled = _compile(f, w1, w2, x)
        mine = analyze_hlo(compiled.as_text())
        xla = _xla_cost(compiled)["flops"]
        # dot flops dominate; tanh etc. not counted by our parser
        expected_dots = L * (2 * 32 * D * F + 2 * 32 * F * D)
        assert mine.flops == pytest.approx(expected_dots, rel=1e-6)
        assert mine.flops == pytest.approx(xla, rel=0.05)

    def test_scan_trip_count_correction(self):
        D, F, L = 32, 64, 12

        def f(stack, x):
            def body(h, w):
                w1, w2 = w
                return jnp.tanh(h @ w1) @ w2, None
            h, _ = jax.lax.scan(body, x, stack)
            return h

        stack = (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
                 jax.ShapeDtypeStruct((L, F, D), jnp.float32))
        x = jax.ShapeDtypeStruct((8, D), jnp.float32)
        compiled = _compile(f, stack, x)
        mine = analyze_hlo(compiled.as_text())
        xla_once = _xla_cost(compiled)["flops"]
        expected = L * (2 * 8 * D * F + 2 * 8 * F * D)
        assert mine.flops == pytest.approx(expected, rel=1e-6)
        # XLA counts the body once — our multiplier fixes exactly that
        assert mine.flops == pytest.approx(xla_once * L, rel=0.05)
        assert L in [t for t in mine.while_trip_counts.values()]

    def test_nested_scans_multiply(self):
        D, INNER, OUTER = 16, 3, 5

        def f(w, x):
            def outer(h, _):
                def inner(h2, _):
                    return h2 @ w, None
                h, _ = jax.lax.scan(inner, h, None, length=INNER)
                return h, None
            h, _ = jax.lax.scan(outer, x, None, length=OUTER)
            return h

        w = jax.ShapeDtypeStruct((D, D), jnp.float32)
        x = jax.ShapeDtypeStruct((4, D), jnp.float32)
        compiled = _compile(f, w, x)
        mine = analyze_hlo(compiled.as_text())
        expected = OUTER * INNER * 2 * 4 * D * D
        assert mine.flops == pytest.approx(expected, rel=1e-6)


class TestCollectives:
    def test_allreduce_bytes(self):
        import os
        n_dev = len(jax.devices())
        if n_dev < 2:
            pytest.skip("needs >1 device (dry-run env has 512)")
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((n_dev,), ("d",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        def f(x):
            return jnp.sum(x)

        x = jax.ShapeDtypeStruct((n_dev * 128,), jnp.float32,
                                 sharding=NamedSharding(mesh, P("d")))
        compiled = jax.jit(f).lower(x).compile()
        mine = analyze_hlo(compiled.as_text())
        assert mine.n_collectives >= 1
        assert mine.collective_bytes > 0


class TestRoofline:
    def test_terms_and_bottleneck(self):
        t = roofline_terms(197e12, 100e9, 1e9)  # 1s compute, .12s mem, .02s coll
        assert t["bottleneck"] == "compute"
        assert t["roofline_fraction"] == pytest.approx(1.0)
        t = roofline_terms(1e12, 819e9, 0.0)
        assert t["bottleneck"] == "memory"
        assert t["step_lower_bound_s"] == pytest.approx(1.0)
