"""Statistical + unit tests for the Gumbel / EM / LazyEM machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.gumbel import gumbel, tail_prob, truncated_gumbel
from repro.core.em import exact_em, em_scores
from repro.core.lazy_em import lazy_em, lazy_em_from_topk, _complement_shift


def _empirical_dist(sample_fn, n, trials, seed=0):
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, trials)
    idx = jax.vmap(sample_fn)(keys)
    counts = np.bincount(np.asarray(idx), minlength=n)
    return counts / trials


def _tv(p, q):
    return 0.5 * np.abs(np.asarray(p) - np.asarray(q)).sum()


class TestGumbel:
    def test_tail_prob_matches_definition(self):
        for B in [-2.0, 0.0, 1.0, 5.0, 12.0]:
            expected = 1.0 - np.exp(-np.exp(-B))
            assert np.isclose(float(tail_prob(jnp.float32(B))), expected, rtol=1e-5)

    def test_tail_prob_stable_for_large_B(self):
        # naive 1 - exp(-exp(-B)) rounds to 0 in f32 near B ~ 17
        p = float(tail_prob(jnp.float32(20.0)))
        assert p > 0
        assert np.isclose(p, np.exp(-20.0), rtol=1e-4)

    def test_truncated_gumbel_exceeds_threshold(self):
        key = jax.random.PRNGKey(0)
        for B in [-1.0, 0.0, 3.0, 10.0]:
            g = truncated_gumbel(key, (20_000,), B)
            assert bool(jnp.all(g > B)), f"B={B}"

    def test_truncated_gumbel_matches_conditional_law(self):
        # Compare with rejection sampling from the unconditional Gumbel.
        B = 0.5
        key = jax.random.PRNGKey(1)
        g_trunc = np.asarray(truncated_gumbel(key, (200_000,), B))
        raw = np.asarray(gumbel(jax.random.PRNGKey(2), (2_000_000,)))
        g_rej = raw[raw > B][:200_000]
        qs = np.linspace(0.01, 0.99, 25)
        a, b = np.quantile(g_trunc, qs), np.quantile(g_rej, qs)
        np.testing.assert_allclose(a, b, atol=0.05)


class TestExactEM:
    def test_gumbel_max_matches_softmax(self):
        utilities = jnp.array([0.0, 1.0, 2.0, 0.5, -1.0])
        eps, sens = 2.0, 1.0
        x = em_scores(utilities, eps, sens)
        target = np.asarray(jax.nn.softmax(x))
        emp = _empirical_dist(lambda k: exact_em(k, utilities, eps, sens),
                              5, 40_000)
        assert _tv(emp, target) < 0.015


class TestComplementShift:
    @given(st.integers(2, 60), st.integers(1, 10), st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_maps_to_complement(self, n, k, seed):
        k = min(k, n - 1)
        rng = np.random.default_rng(seed)
        S = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)
        comp = np.setdiff1d(np.arange(n), S)
        u = jnp.arange(n - k, dtype=jnp.int32)
        mapped = np.asarray(_complement_shift(jnp.asarray(S), u))
        np.testing.assert_array_equal(mapped, comp)


class TestLazyEM:
    def test_matches_exact_em_distribution(self):
        scores = jnp.array([3.0, 2.5, 2.0, 1.0, 0.5, 0.0, -0.5, -1.0, -2.0, -3.0])
        n = scores.shape[0]
        target = np.asarray(jax.nn.softmax(scores))
        emp = _empirical_dist(
            lambda k: lazy_em(k, scores, k=3, tail_cap=8 * n).index, n, 60_000)
        assert _tv(emp, target) < 0.015

    def test_uniform_scores(self):
        # worst case for the tail bound: everything survives the margin
        n = 16
        scores = jnp.zeros((n,))
        emp = _empirical_dist(
            lambda k: lazy_em(k, scores, k=4, tail_cap=8 * n).index, n, 40_000)
        assert _tv(emp, np.full(n, 1 / n)) < 0.02

    def test_tail_count_expectation(self):
        # Mussmann et al.: E[C] ≤ n/k
        n, k = 400, 20
        key = jax.random.PRNGKey(0)
        scores = jax.random.normal(key, (n,))
        total = 0
        trials = 300
        for i in range(trials):
            out = lazy_em(jax.random.PRNGKey(i + 1), scores, k=k, tail_cap=n)
            total += int(out.tail_count)
        assert total / trials <= 3.0 * n / k  # generous slack on the bound

    def test_overflow_flag(self):
        n = 100
        scores = jnp.zeros((n,))  # uniform → C ≈ n
        seen = False
        for i in range(20):
            out = lazy_em(jax.random.PRNGKey(i), scores, k=2, tail_cap=4)
            seen = seen or bool(out.overflow)
        assert seen

    def test_alg6_margin_slack_preserves_distribution(self):
        """Alg. 6: with a c-approximate top-k and B lowered by c, sampling is exact."""
        scores = jnp.array([2.0, 1.9, 1.8, 1.2, 1.1, 0.4, 0.0, -0.7])
        n = scores.shape[0]
        # adversarial approximate top-3: misses the true #3 (1.8), has #4 (1.2)
        approx_idx = jnp.array([0, 1, 3], dtype=jnp.int32)
        approx_scores = scores[approx_idx]
        c = 1.8 - 1.2 + 1e-6  # Def 3.4 margin of this S
        target = np.asarray(jax.nn.softmax(scores))

        def sample(k):
            return lazy_em_from_topk(
                k, approx_idx, approx_scores, n,
                score_fn=lambda idx: scores[idx], tail_cap=8 * n,
                margin_slack=c).index

        emp = _empirical_dist(sample, n, 60_000)
        assert _tv(emp, target) < 0.015

    def test_alg5_ratio_bounds(self):
        """Thm F.4: approximate top-k without slack stays within e^{±c}."""
        scores = jnp.array([2.0, 1.9, 1.8, 1.2, 1.1, 0.4, 0.0, -0.7])
        n = scores.shape[0]
        approx_idx = jnp.array([0, 1, 3], dtype=jnp.int32)
        c = 1.8 - 1.2
        target = np.asarray(jax.nn.softmax(scores))

        def sample(k):
            return lazy_em_from_topk(
                k, approx_idx, scores[approx_idx], n,
                score_fn=lambda idx: scores[idx], tail_cap=8 * n,
                margin_slack=0.0).index

        emp = _empirical_dist(sample, n, 120_000)
        ratio = emp / target
        # generous statistical slack around [e^-c, e^c]
        assert np.all(ratio < np.exp(c) * 1.15)
        assert np.all(ratio > np.exp(-c) * 0.85)

    @given(st.integers(4, 64), st.integers(1, 8), st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_winner_always_valid(self, n, k, seed):
        k = min(k, n)
        key = jax.random.PRNGKey(seed)
        scores = jax.random.normal(key, (n,))
        out = lazy_em(jax.random.PRNGKey(seed + 1), scores, k=k, tail_cap=4 * n)
        assert 0 <= int(out.index) < n
        assert int(out.n_scored) <= 5 * n + k
