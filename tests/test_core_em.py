"""Statistical + unit tests for the Gumbel / EM / LazyEM machinery,
including the mechanism-statistics tier for the LP scoring geometries
(§4.1 ``A@x − b`` and §4.2 ``N@y`` — the distributions the fast-mode LP
solvers must sample from).

Large-trial distribution checks and hypothesis property suites carry the
``slow`` marker: CI's tier-1 fast lane deselects them (``-m "not slow"``)
and a separate lane runs them on their own.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bregman import bregman_project_dense
from repro.core.gumbel import gumbel, tail_prob, truncated_gumbel
from repro.core.em import exact_em, em_scores
from repro.core.lazy_em import lazy_em, lazy_em_from_topk, _complement_shift
from repro.core.lp_dual import _dual_update
from repro.core.lp_scalar import _lp_update
from repro.core.queries import random_feasible_lp, random_packing_lp
from repro.mips import FlatIndex, lp_dual_rows, lp_scalar_rows


def _empirical_dist(sample_fn, n, trials, seed=0):
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, trials)
    idx = jax.vmap(sample_fn)(keys)
    counts = np.bincount(np.asarray(idx), minlength=n)
    return counts / trials


def _tv(p, q):
    return 0.5 * np.abs(np.asarray(p) - np.asarray(q)).sum()


class TestGumbel:
    def test_tail_prob_matches_definition(self):
        for B in [-2.0, 0.0, 1.0, 5.0, 12.0]:
            expected = 1.0 - np.exp(-np.exp(-B))
            assert np.isclose(float(tail_prob(jnp.float32(B))), expected, rtol=1e-5)

    def test_tail_prob_stable_for_large_B(self):
        # naive 1 - exp(-exp(-B)) rounds to 0 in f32 near B ~ 17
        p = float(tail_prob(jnp.float32(20.0)))
        assert p > 0
        assert np.isclose(p, np.exp(-20.0), rtol=1e-4)

    def test_truncated_gumbel_exceeds_threshold(self):
        key = jax.random.PRNGKey(0)
        for B in [-1.0, 0.0, 3.0, 10.0]:
            g = truncated_gumbel(key, (20_000,), B)
            assert bool(jnp.all(g > B)), f"B={B}"

    @pytest.mark.slow
    def test_truncated_gumbel_matches_conditional_law(self):
        # Compare with rejection sampling from the unconditional Gumbel.
        B = 0.5
        key = jax.random.PRNGKey(1)
        g_trunc = np.asarray(truncated_gumbel(key, (200_000,), B))
        raw = np.asarray(gumbel(jax.random.PRNGKey(2), (2_000_000,)))
        g_rej = raw[raw > B][:200_000]
        qs = np.linspace(0.01, 0.99, 25)
        a, b = np.quantile(g_trunc, qs), np.quantile(g_rej, qs)
        np.testing.assert_allclose(a, b, atol=0.05)


class TestExactEM:
    @pytest.mark.slow
    def test_gumbel_max_matches_softmax(self):
        utilities = jnp.array([0.0, 1.0, 2.0, 0.5, -1.0])
        eps, sens = 2.0, 1.0
        x = em_scores(utilities, eps, sens)
        target = np.asarray(jax.nn.softmax(x))
        emp = _empirical_dist(lambda k: exact_em(k, utilities, eps, sens),
                              5, 40_000)
        assert _tv(emp, target) < 0.015


class TestComplementShift:
    @pytest.mark.slow
    @given(st.integers(2, 60), st.integers(1, 10), st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_maps_to_complement(self, n, k, seed):
        k = min(k, n - 1)
        rng = np.random.default_rng(seed)
        S = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)
        comp = np.setdiff1d(np.arange(n), S)
        u = jnp.arange(n - k, dtype=jnp.int32)
        mapped = np.asarray(_complement_shift(jnp.asarray(S), u))
        np.testing.assert_array_equal(mapped, comp)


class TestLazyEM:
    @pytest.mark.slow
    def test_matches_exact_em_distribution(self):
        scores = jnp.array([3.0, 2.5, 2.0, 1.0, 0.5, 0.0, -0.5, -1.0, -2.0, -3.0])
        n = scores.shape[0]
        target = np.asarray(jax.nn.softmax(scores))
        emp = _empirical_dist(
            lambda k: lazy_em(k, scores, k=3, tail_cap=8 * n).index, n, 60_000)
        assert _tv(emp, target) < 0.015

    @pytest.mark.slow
    def test_uniform_scores(self):
        # worst case for the tail bound: everything survives the margin
        n = 16
        scores = jnp.zeros((n,))
        emp = _empirical_dist(
            lambda k: lazy_em(k, scores, k=4, tail_cap=8 * n).index, n, 40_000)
        assert _tv(emp, np.full(n, 1 / n)) < 0.02

    @pytest.mark.slow
    def test_tail_count_expectation(self):
        # Mussmann et al.: E[C] ≤ n/k
        n, k = 400, 20
        key = jax.random.PRNGKey(0)
        scores = jax.random.normal(key, (n,))
        total = 0
        trials = 300
        for i in range(trials):
            out = lazy_em(jax.random.PRNGKey(i + 1), scores, k=k, tail_cap=n)
            total += int(out.tail_count)
        assert total / trials <= 3.0 * n / k  # generous slack on the bound

    def test_overflow_flag(self):
        n = 100
        scores = jnp.zeros((n,))  # uniform → C ≈ n
        seen = False
        for i in range(20):
            out = lazy_em(jax.random.PRNGKey(i), scores, k=2, tail_cap=4)
            seen = seen or bool(out.overflow)
        assert seen

    @pytest.mark.slow
    def test_alg6_margin_slack_preserves_distribution(self):
        """Alg. 6: with a c-approximate top-k and B lowered by c, sampling is exact."""
        scores = jnp.array([2.0, 1.9, 1.8, 1.2, 1.1, 0.4, 0.0, -0.7])
        n = scores.shape[0]
        # adversarial approximate top-3: misses the true #3 (1.8), has #4 (1.2)
        approx_idx = jnp.array([0, 1, 3], dtype=jnp.int32)
        approx_scores = scores[approx_idx]
        c = 1.8 - 1.2 + 1e-6  # Def 3.4 margin of this S
        target = np.asarray(jax.nn.softmax(scores))

        def sample(k):
            return lazy_em_from_topk(
                k, approx_idx, approx_scores, n,
                score_fn=lambda idx: scores[idx], tail_cap=8 * n,
                margin_slack=c).index

        emp = _empirical_dist(sample, n, 60_000)
        assert _tv(emp, target) < 0.015

    @pytest.mark.slow
    def test_alg5_ratio_bounds(self):
        """Thm F.4: approximate top-k without slack stays within e^{±c}."""
        scores = jnp.array([2.0, 1.9, 1.8, 1.2, 1.1, 0.4, 0.0, -0.7])
        n = scores.shape[0]
        approx_idx = jnp.array([0, 1, 3], dtype=jnp.int32)
        c = 1.8 - 1.2
        target = np.asarray(jax.nn.softmax(scores))

        def sample(k):
            return lazy_em_from_topk(
                k, approx_idx, scores[approx_idx], n,
                score_fn=lambda idx: scores[idx], tail_cap=8 * n,
                margin_slack=0.0).index

        emp = _empirical_dist(sample, n, 120_000)
        ratio = emp / target
        # generous statistical slack around [e^-c, e^c]
        assert np.all(ratio < np.exp(c) * 1.15)
        assert np.all(ratio > np.exp(-c) * 0.85)

    @pytest.mark.slow
    @given(st.integers(4, 64), st.integers(1, 8), st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_winner_always_valid(self, n, k, seed):
        k = min(k, n)
        key = jax.random.PRNGKey(seed)
        scores = jax.random.normal(key, (n,))
        out = lazy_em(jax.random.PRNGKey(seed + 1), scores, k=k, tail_cap=4 * n)
        assert 0 <= int(out.index) < n
        assert int(out.n_scored) <= 5 * n + k


def _chi_square_stat(counts: np.ndarray, probs: np.ndarray, trials: int) -> float:
    expected = trials * probs
    return float(np.sum((counts - expected) ** 2 / expected))


def _chi_square_threshold(dof: int) -> float:
    """Mean + 5σ of a χ²(dof) variable — a ≈3e-5 false-positive bound
    without a scipy dependency."""
    return dof + 5.0 * np.sqrt(2.0 * dof)


class TestLPSelectionGeometry:
    """Mechanism statistics for the LP solvers' fast-mode selection: the
    lazy path over a k-MIPS probe must sample the *exact* EM softmax over
    the LP score geometries (§4.1 scalar, §4.2 dual) — the distribution
    contract `TestLazyEM` asserts on synthetic scores, re-asserted on the
    real scoring pipelines (index probe + concatenated-row tail gathers)."""

    @pytest.mark.slow
    def test_scalar_fast_selection_matches_em_softmax(self):
        """χ² check: fast-mode constraint-selection frequencies match the
        EM softmax over ``(A@x − b)·scale``."""
        m, d, trials = 40, 8, 40_000
        A, b, _ = random_feasible_lp(jax.random.PRNGKey(0), m=m, d=d)
        Ab = jnp.asarray(lp_scalar_rows(np.asarray(A), np.asarray(b)))
        x = jnp.full((d,), 1.0 / d, jnp.float32)   # the t=0 iterate
        raw_scores = np.asarray(A @ x - b)
        # bound the scaled spread so every cell's expected count is ≳15
        scale = 4.0 / float(raw_scores.max() - raw_scores.min())
        target = np.asarray(jax.nn.softmax(jnp.asarray(raw_scores * scale)))

        index = FlatIndex(Ab, use_pallas="never")
        xq = jnp.concatenate([x, -jnp.ones((1,), x.dtype)])
        topk_idx, topk_raw = index.query(xq, 6)

        def sample(key):
            return lazy_em_from_topk(
                key, topk_idx, topk_raw * scale, m,
                score_fn=lambda i: (Ab[i] @ xq) * scale,
                tail_cap=8 * m).index

        idx = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(1), trials))
        counts = np.bincount(np.asarray(idx), minlength=m)
        stat = _chi_square_stat(counts, target, trials)
        assert stat < _chi_square_threshold(m - 1), stat

    @pytest.mark.slow
    def test_dual_fast_selection_matches_em_softmax(self):
        """χ² check on the dual geometry: vertex-selection frequencies
        match the EM softmax over ``(N@y)·scale`` at a 1/s-dense y."""
        m, d, trials = 32, 24, 40_000
        A, b, c = random_packing_lp(jax.random.PRNGKey(2), m=m, d=d)
        opt = float(c @ jnp.full((d,), 1.0 / d)) * 0.5
        N = jnp.asarray(lp_dual_rows(np.asarray(A), np.asarray(c), opt))
        y_raw = jnp.exp(jax.random.normal(jax.random.PRNGKey(3), (m,)))
        y = bregman_project_dense(y_raw, 8.0)      # a realistic dual iterate
        raw_scores = np.asarray(N @ y)
        scale = 4.0 / float(raw_scores.max() - raw_scores.min())
        target = np.asarray(jax.nn.softmax(jnp.asarray(raw_scores * scale)))

        index = FlatIndex(N, use_pallas="never")
        topk_idx, topk_raw = index.query(y, 5)

        def sample(key):
            return lazy_em_from_topk(
                key, topk_idx, topk_raw * scale, d,
                score_fn=lambda i: (N[i] @ y) * scale,
                tail_cap=8 * d).index

        idx = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(4), trials))
        counts = np.bincount(np.asarray(idx), minlength=d)
        stat = _chi_square_stat(counts, target, trials)
        assert stat < _chi_square_threshold(d - 1), stat


class TestMarginalSelectionStatistics:
    """Mechanism statistics for the adaptive worst-marginal oracle
    (`core.adaptive.select_worst_marginal`): lazy Gumbel sampling over
    per-clique ``max |marg_c(v)|`` utilities must match the EM softmax over
    exactly those utilities — the `TestLazyEM` distribution contract
    re-asserted on the factored-workload scoring pipeline (segment-sum
    tables, no rows)."""

    @pytest.mark.slow
    def test_selection_matches_em_softmax(self):
        from repro.core.adaptive import select_worst_marginal
        from repro.core.workload import MarginalWorkload

        W = MarginalWorkload.all_kway((3, 2, 4, 2), 2)
        key = jax.random.PRNGKey(0)
        h = jax.random.dirichlet(key, jnp.ones(W.U) * 0.4)
        v = h - jnp.full((W.U,), 1.0 / W.U)
        util = np.asarray(W.clique_abs_err(v))
        # bound the scaled spread so every clique's expected count is ≳15
        scale = 4.0 / float(util.max() - util.min())
        target = np.asarray(jax.nn.softmax(jnp.asarray(util * scale)))

        trials = 40_000

        def sample(k):
            return select_worst_marginal(k, W, v, scale, k=3).index

        idx = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(1), trials))
        counts = np.bincount(np.asarray(idx), minlength=W.n_cliques)
        stat = _chi_square_stat(counts, target, trials)
        assert stat < _chi_square_threshold(W.n_cliques - 1), stat


class TestLPScoreProperties:
    """Hypothesis property tier for the LP iteration algebra."""

    @pytest.mark.slow
    @given(st.integers(2, 40), st.integers(2, 16), st.integers(0, 2000))
    @settings(max_examples=40, deadline=None)
    def test_score_identity(self, m, d, seed):
        """§4.1 identity the index probe relies on:
        ``Q_t(i) = ⟨[A_i, b_i], [x, −1]⟩ = A_i·x − b_i``."""
        rng = np.random.default_rng(seed)
        A = rng.standard_normal((m, d)).astype(np.float32)
        b = rng.standard_normal(m).astype(np.float32)
        x = rng.dirichlet(np.ones(d)).astype(np.float32)
        Ab = lp_scalar_rows(A, b)
        xq = np.concatenate([x, [-1.0]]).astype(np.float32)
        np.testing.assert_allclose(Ab @ xq, A @ x - b, atol=1e-4)

    @pytest.mark.slow
    @given(st.integers(2, 40), st.integers(2, 16), st.integers(0, 2000))
    @settings(max_examples=40, deadline=None)
    def test_dual_rows_identity(self, m, d, seed):
        """§4.2 identity: ``(N@y)_j = −(OPT/c_j)·⟨A[:, j], y⟩``."""
        rng = np.random.default_rng(seed)
        A = rng.uniform(0.1, 1.0, (m, d)).astype(np.float32)
        c = rng.uniform(0.5, 1.5, d).astype(np.float32)
        y = rng.dirichlet(np.ones(m)).astype(np.float32)
        opt = float(rng.uniform(0.1, 2.0))
        N = lp_dual_rows(A, c, opt)
        np.testing.assert_allclose(
            N @ y, -(opt / c) * (A.T @ y), rtol=1e-4, atol=1e-5)

    @pytest.mark.slow
    @given(st.integers(2, 32), st.integers(0, 2000))
    @settings(max_examples=40, deadline=None)
    def test_lp_update_stays_on_simplex(self, d, seed):
        """`_lp_update` invariants: the iterate is a distribution and the
        log-weights stay drift-controlled (max = 0)."""
        rng = np.random.default_rng(seed)
        logX = jnp.asarray(rng.standard_normal(d), jnp.float32)
        A_row = jnp.asarray(rng.standard_normal(d), jnp.float32)
        logX2, x = _lp_update(logX, A_row, 0.3, 1.5)
        x = np.asarray(x)
        assert np.isclose(x.sum(), 1.0, atol=1e-5)
        assert np.all(x >= 0)
        assert np.isclose(float(jnp.max(logX2)), 0.0, atol=1e-6)

    @pytest.mark.slow
    @given(st.integers(4, 40), st.integers(2, 12), st.integers(1, 10),
           st.integers(0, 2000))
    @settings(max_examples=40, deadline=None)
    def test_dual_update_stays_dense(self, m, d, s, seed):
        """`_dual_step` invariant: the projected dual iterate is a 1/s-dense
        distribution (Def. A.2) for any vertex play."""
        s = min(s, m)
        rng = np.random.default_rng(seed)
        logY = jnp.asarray(rng.standard_normal(m), jnp.float32)
        A = jnp.asarray(rng.uniform(0.1, 1.0, (m, d)), jnp.float32)
        b = jnp.asarray(rng.uniform(0.5, 1.5, m), jnp.float32)
        x_vertex = jnp.zeros((d,), jnp.float32).at[int(rng.integers(d))].set(
            float(rng.uniform(0.1, 3.0)))
        _, y = _dual_update(logY, x_vertex, A, b, 0.4, 2.0, int(s))
        y = np.asarray(y)
        assert np.isclose(y.sum(), 1.0, atol=1e-4)
        assert y.max() <= 1.0 / s + 1e-4
        assert np.all(y >= 0)
