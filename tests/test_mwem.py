"""End-to-end MWEM / Fast-MWEM behaviour (paper §3, §5.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MWEMConfig, run_mwem
from repro.core.queries import gaussian_histogram, random_binary_queries, max_error
from repro.mips import FlatAbsIndex, IVFIndex, augment_complement


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    U, m, n = 128, 200, 500
    h = gaussian_histogram(kh, n, U)
    Q = random_binary_queries(kq, m, U)
    return Q, h, n


def test_exact_mwem_reduces_error(workload):
    Q, h, n = workload
    cfg = MWEMConfig(eps=1.0, delta=1e-3, T=150, mode="exact", n_records=n)
    res = run_mwem(Q, h, cfg, jax.random.PRNGKey(1))
    uniform_err = float(max_error(Q, h, jnp.full_like(h, 1 / h.shape[0])))
    assert res.final_error < uniform_err * 0.8
    assert len(res.selected) == 150


def test_paper_literal_update_diverges(workload):
    """Empirical support for the DESIGN.md faithfulness note: the literal
    Alg. 1 update (sign-less downweighting) does not reduce error."""
    Q, h, n = workload
    hardt = run_mwem(Q, h, MWEMConfig(T=100, mode="exact", update_rule="hardt",
                                      n_records=n), jax.random.PRNGKey(9))
    lit = run_mwem(Q, h, MWEMConfig(T=100, mode="exact", update_rule="paper",
                                    n_records=n), jax.random.PRNGKey(9))
    assert hardt.final_error < lit.final_error


def test_fast_matches_exact_error(workload):
    """Fig. 2: |error(MWEM) − error(FastMWEM-flat)| ≈ 0."""
    Q, h, n = workload
    T = 80
    exact = run_mwem(Q, h, MWEMConfig(T=T, mode="exact", n_records=n),
                     jax.random.PRNGKey(2))
    index = FlatAbsIndex(Q)
    fast = run_mwem(Q, h, MWEMConfig(T=T, mode="fast", n_records=n),
                    jax.random.PRNGKey(2), index=index)
    assert abs(exact.final_error - fast.final_error) < 0.05
    # sublinear scoring: mean evaluations well below m
    assert np.mean(fast.n_scored) < Q.shape[0] * 0.9


def test_fast_with_ivf_index(workload):
    Q, h, n = workload
    aug = augment_complement(np.asarray(Q))
    index = IVFIndex(aug, seed=0)
    cfg = MWEMConfig(T=40, mode="fast", n_records=n)
    res = run_mwem(Q, h, cfg, jax.random.PRNGKey(3), index=index)
    uniform_err = float(max_error(Q, h, jnp.full_like(h, 1 / h.shape[0])))
    assert res.final_error < uniform_err  # still learns
    eps, delta = res.ledger.composed()
    assert delta >= 1.0 / aug.shape[0]  # Thm 3.3 failure mass recorded


def test_update_rules(workload):
    Q, h, n = workload
    for rule in ("paper", "signed", "hardt"):
        cfg = MWEMConfig(T=20, mode="exact", update_rule=rule, n_records=n)
        res = run_mwem(Q, h, cfg, jax.random.PRNGKey(4))
        assert np.isfinite(res.final_error)


def test_privacy_ledger_totals(workload):
    Q, h, n = workload
    T = 16
    cfg = MWEMConfig(eps=1.0, delta=1e-3, T=T, mode="exact",
                     update_rule="hardt", n_records=n)
    res = run_mwem(Q, h, cfg, jax.random.PRNGKey(5))
    # 2 events per iteration (EM + Laplace)
    assert len(res.ledger.events) == 2 * T
    eps, delta = res.ledger.composed()
    assert 0 < eps < 10
    assert delta <= 1e-2


def test_eval_every_records_errors(workload):
    Q, h, n = workload
    cfg = MWEMConfig(T=20, mode="exact", eval_every=5, n_records=n)
    res = run_mwem(Q, h, cfg, jax.random.PRNGKey(6))
    assert [t for t, _ in res.errors] == [5, 10, 15, 20]
