"""Multi-tenant release service: admission, waves, zero-ε answer cache."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import MWEMConfig, release_cost, run_mwem_fused
from repro.core.accountant import PrivacyLedger
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.serve import ReleaseService
import repro.serve.release_service as rs_mod


U, M, N_RECORDS = 64, 128, 300


def make_workload():
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    h = gaussian_histogram(kh, N_RECORDS, U)
    Q = random_binary_queries(kq, M, U)
    return Q, np.asarray(h)


@pytest.fixture(scope="module")
def workload():
    return make_workload()


def make_service(Q, wave_size=2, T=6, auto_flush=True, **kw):
    cfg = MWEMConfig(eps=0.5, delta=1e-3, T=T, mode="fast")
    return ReleaseService(Q, cfg, wave_size=wave_size,
                          auto_flush=auto_flush, **kw)


def add_tenant(svc, h, name, eps_budget=50.0, delta_budget=0.5):
    return svc.create_session(name, eps_budget=eps_budget,
                              delta_budget=delta_budget, h=h,
                              n_records=N_RECORDS)


class TestAdmission:
    def test_over_budget_rejected_with_correct_composed_cost(self, workload):
        """Acceptance (a): rejection reports exactly the composed (ε, δ)
        execution would have spent."""
        Q, h = workload
        svc = make_service(Q)
        sess = add_tenant(svc, h, "tiny", eps_budget=1e-4, delta_budget=0.5)
        ticket = svc.submit("tiny")
        assert ticket.status == "rejected"
        assert not ticket.decision.admitted
        assert svc.pending_count() == 0
        assert len(sess.ledger.events) == 0  # nothing was spent
        # independent recomputation of the projected cost
        cfg = svc._group_cfg(N_RECORDS)
        events, gamma, slack = release_cost(cfg, M, U, index=svc.index)
        exp_eps, exp_delta = PrivacyLedger().preview(events, gamma, slack)
        assert ticket.decision.eps_projected == pytest.approx(exp_eps, rel=1e-12)
        assert ticket.decision.delta_projected == pytest.approx(exp_delta, rel=1e-12)
        assert ticket.decision.eps_projected > 1e-4  # genuinely over budget
        assert "exceeds budget" in ticket.decision.reason

    def test_within_budget_admitted_and_charged_as_projected(self, workload):
        """Admission preview equals what execution actually composes to."""
        Q, h = workload
        svc = make_service(Q, auto_flush=False)
        sess = add_tenant(svc, h, "t0")
        ticket = svc.submit("t0")
        assert ticket.status == "queued"
        svc.flush()
        assert ticket.status == "done"
        spent = sess.ledger.composed()
        assert spent[0] == pytest.approx(ticket.decision.eps_projected, rel=1e-12)
        assert spent[1] == pytest.approx(ticket.decision.delta_projected, rel=1e-12)
        assert ticket.release.eps_cost == pytest.approx(spent[0], rel=1e-12)

    def test_queued_reservation_blocks_joint_overspend(self, workload):
        """Two requests that individually fit but jointly exceed the budget
        cannot both be admitted."""
        Q, h = workload
        svc = make_service(Q, auto_flush=False)
        cfg = svc._group_cfg(N_RECORDS)
        one_eps, _ = PrivacyLedger().preview(*release_cost(cfg, M, U,
                                                           index=svc.index))
        # two releases compose sublinearly (≈ √2× one release), so a budget
        # of 1.05× one release admits the first and must reject the second
        add_tenant(svc, h, "t0", eps_budget=1.05 * one_eps)
        first = svc.submit("t0")
        second = svc.submit("t0")
        assert first.status == "queued"
        assert second.status == "rejected"
        svc.flush()
        assert first.status == "done"

    def test_budget_recovers_nothing_rejection_is_sticky(self, workload):
        Q, h = workload
        svc = make_service(Q)
        sess = add_tenant(svc, h, "t0", eps_budget=1e-4)
        svc.submit("t0")
        svc.submit("t0")
        assert sess.rejected_count == 2
        assert svc.stats.rejected == 2


class TestWaves:
    def test_n_requests_ceil_n_over_b_dispatches(self, workload, monkeypatch):
        """Acceptance (b): N requests from distinct tenants → ⌈N/B⌉
        `run_mwem_batch` dispatches, every wave exactly B lanes."""
        Q, h = workload
        calls = []
        orig = rs_mod.run_mwem_batch

        def counting(Qm, hs, cfg, keys, **kw):
            calls.append(int(keys.shape[0]))
            return orig(Qm, hs, cfg, keys, **kw)

        monkeypatch.setattr(rs_mod, "run_mwem_batch", counting)
        B, N = 2, 5
        svc = make_service(Q, wave_size=B)
        for i in range(N):
            add_tenant(svc, h, f"t{i}")
            svc.submit(f"t{i}")
        svc.flush()
        assert len(calls) == -(-N // B)  # ⌈N/B⌉
        assert all(c == B for c in calls)  # fixed-size (padded) waves
        assert svc.stats.dispatches == len(calls)
        assert svc.stats.released == N
        assert svc.stats.padded_slots == len(calls) * B - N
        for i in range(N):
            sess = svc.session(f"t{i}")
            assert len(sess.releases) == 1
            assert np.isfinite(sess.releases[0].final_error)

    def test_wave_lane_matches_single_fused_run(self, workload):
        """A tenant's released histogram is exactly what a standalone fused
        run with the same key would produce — wave packing is invisible."""
        Q, h = workload
        svc = make_service(Q, wave_size=3, auto_flush=False)
        for i in range(3):
            add_tenant(svc, h, f"t{i}")
            svc.submit(f"t{i}", seed=100 + i)
        svc.flush()
        cfg = svc._group_cfg(N_RECORDS)
        for i in range(3):
            rel = svc.session(f"t{i}").latest
            solo = run_mwem_fused(Q, jnp.asarray(h), cfg,
                                  jax.random.PRNGKey(100 + i), index=svc.index)
            np.testing.assert_allclose(rel.p_hat, np.asarray(solo.p_hat),
                                       atol=1e-6)

    def test_padded_wave_single_request(self, workload):
        Q, h = workload
        svc = make_service(Q, wave_size=4, auto_flush=False)
        sess = add_tenant(svc, h, "solo")
        svc.submit("solo")
        done = svc.flush()
        assert [t.status for t in done] == ["done"]
        assert svc.stats.dispatches == 1
        assert svc.stats.padded_slots == 3
        # pad lanes charged nothing anywhere: only this tenant's ledger grew
        assert len(sess.ledger.events) > 0
        assert len(sess.releases) == 1

    def test_auto_flush_fires_on_full_wave(self, workload):
        Q, h = workload
        svc = make_service(Q, wave_size=2, auto_flush=True)
        add_tenant(svc, h, "a")
        add_tenant(svc, h, "b")
        t1 = svc.submit("a")
        assert t1.status == "queued"
        t2 = svc.submit("b")  # fills the wave → dispatch
        assert t1.status == "done" and t2.status == "done"
        assert svc.stats.dispatches == 1

    def test_same_tenant_multi_lane_costs_sum_to_spend(self, workload):
        """Per-release marginal costs must sum to the tenant's total spend
        even when both lanes land in ONE wave (a naive before/after ledger
        diff double-counts)."""
        Q, h = workload
        svc = make_service(Q, wave_size=2, auto_flush=False)
        sess = add_tenant(svc, h, "t0")
        svc.submit("t0")
        svc.submit("t0")
        svc.flush()
        assert svc.stats.dispatches == 1  # both lanes in the same wave
        total = sess.spent()[0]
        costs = [r.eps_cost for r in sess.releases]
        assert sum(costs) == pytest.approx(total, rel=1e-9)
        # second lane's marginal cost is smaller (advanced composition)
        assert costs[1] < costs[0]

    def test_decision_cost_is_marginal_under_reservations(self, workload):
        """With a request already queued, the next decision's eps_cost
        reports only that request's marginal share, not queue + request."""
        Q, h = workload
        svc = make_service(Q, auto_flush=False)
        add_tenant(svc, h, "t0")
        first = svc.submit("t0")
        second = svc.submit("t0")
        assert second.decision.eps_cost < first.decision.eps_cost
        assert second.decision.eps_projected == pytest.approx(
            first.decision.eps_projected + second.decision.eps_cost, rel=1e-9)
        svc.flush()

    def test_mixed_dataset_sizes_batch_separately(self, workload):
        Q, h = workload
        svc = make_service(Q, wave_size=2, T=4, auto_flush=False)
        add_tenant(svc, h, "small")
        svc.create_session("big", eps_budget=50.0, delta_budget=0.5,
                           h=h, n_records=10 * N_RECORDS)
        svc.submit("small")
        svc.submit("big")
        svc.flush()
        assert svc.stats.dispatches == 2  # n_records is a compile-time static
        assert svc.session("small").latest is not None
        assert svc.session("big").latest is not None


class TestMeshWaves:
    """Waves on a device mesh route through the sharded driver. A 1-device
    mesh exercises the full routing/charging path inside the normal suite;
    the multi-device variant lives in tests/test_distributed.py."""

    def test_wave_parity_and_charging_on_mesh(self, workload):
        from repro.core import run_mwem_sharded
        from repro.core.accountant import PrivacyLedger
        from repro.launch.mesh import make_driver_mesh
        from repro.mips import ShardedIVFIndex

        Q, h = workload
        mesh = make_driver_mesh(1)
        svc = make_service(Q, wave_size=2, auto_flush=False)
        svc_mesh = ReleaseService(Q, svc.cfg, wave_size=2, auto_flush=False,
                                  mesh=mesh)
        assert isinstance(svc_mesh.index, ShardedIVFIndex)
        sess = add_tenant(svc_mesh, h, "t0")
        ticket = svc_mesh.submit("t0", seed=7)
        assert ticket.status == "queued"
        done = svc_mesh.flush()
        assert [t.status for t in done] == ["done"]
        # short wave, no pad lanes: sharded lanes dispatch one by one
        assert svc_mesh.stats.padded_slots == 0
        assert svc_mesh.stats.dispatches == 1
        # the released histogram is exactly a standalone sharded run
        cfg = svc_mesh._group_cfg(N_RECORDS)
        solo = run_mwem_sharded(Q, jnp.asarray(h), cfg,
                                jax.random.PRNGKey(7), mesh=mesh,
                                index=svc_mesh.index)
        np.testing.assert_allclose(np.asarray(sess.latest.p_hat),
                                   np.asarray(solo.p_hat), atol=1e-6)
        # admission preview == executed spend, same contract as off-mesh
        spent = sess.ledger.composed()
        assert spent[0] == pytest.approx(ticket.decision.eps_projected,
                                         rel=1e-12)
        exp = PrivacyLedger().preview(*release_cost(cfg, M, U,
                                                    index=svc_mesh.index))
        assert spent == exp

    def test_mesh_answers_are_post_processing(self, workload):
        from repro.launch.mesh import make_driver_mesh

        Q, h = workload
        svc = ReleaseService(Q, make_service(Q).cfg, wave_size=2,
                             auto_flush=False, mesh=make_driver_mesh(1))
        sess = add_tenant(svc, h, "t0")
        svc.submit("t0")
        svc.flush()
        events_before = list(sess.ledger.events)
        q = np.asarray(Q)[5]
        fresh = svc.answer("t0", q)
        again = svc.answer("t0", q)
        assert not fresh.cached and again.cached
        assert again.value == fresh.value
        assert sess.ledger.events == events_before  # zero-ε reads


class TestAnswerCache:
    def test_repeat_query_cached_bitwise_zero_ledger_delta(self, workload):
        """Acceptance (c): a repeated query is answered from the cache,
        bitwise-equal to the fresh answer, with zero ledger delta."""
        Q, h = workload
        svc = make_service(Q, wave_size=2)
        sess = add_tenant(svc, h, "t0")
        svc.submit("t0")
        svc.flush()
        q = np.asarray(Q)[7]
        events_before = list(sess.ledger.events)
        gamma_before = sess.ledger.index_failure_mass
        fresh = svc.answer("t0", q)
        again = svc.answer("t0", q)
        assert not fresh.cached and again.cached
        assert again.value == fresh.value  # bitwise: the stored float
        assert sess.ledger.events == events_before  # zero-ε read path
        assert sess.ledger.index_failure_mass == gamma_before
        assert sess.cache.hits == 1 and sess.cache.misses == 1
        # and the answer is the post-processed histogram's, as promised
        assert fresh.value == pytest.approx(
            float(q @ np.asarray(sess.latest.p_hat)), abs=1e-6)

    def test_derived_combination_from_cache_only(self, workload):
        Q, h = workload
        svc = make_service(Q, wave_size=2)
        add_tenant(svc, h, "t0")
        svc.submit("t0")
        svc.flush()
        Qnp = np.asarray(Q)
        a1 = svc.answer("t0", Qnp[0])
        a2 = svc.answer("t0", Qnp[1])
        combo = svc.answer_derived("t0", {a1.fingerprint: 2.0,
                                          a2.fingerprint: -1.0})
        assert combo is not None
        assert combo.value == pytest.approx(2 * a1.value - a2.value, abs=1e-9)
        # missing component → not derivable, no histogram touch
        assert svc.answer_derived("t0", {"deadbeef": 1.0}) is None

    def test_answer_before_any_release_raises(self, workload):
        Q, h = workload
        svc = make_service(Q)
        add_tenant(svc, h, "t0")
        with pytest.raises(LookupError):
            svc.answer("t0", np.asarray(Q)[0])

    def test_tenant_isolation(self, workload):
        """Different tenants' caches and releases never alias."""
        Q, h = workload
        h2 = np.roll(h, 7)
        svc = make_service(Q, wave_size=2, auto_flush=False)
        add_tenant(svc, h, "a")
        svc.create_session("b", eps_budget=50.0, delta_budget=0.5, h=h2,
                           n_records=N_RECORDS)
        svc.submit("a", seed=1)
        svc.submit("b", seed=2)
        svc.flush()
        q = np.asarray(Q)[3]
        ans_a = svc.answer("a", q)
        ans_b = svc.answer("b", q)
        assert not ans_b.cached  # b's cache is its own
        assert svc.session("a").latest.release_id != svc.session("b").latest.release_id


class TestLPWorkload:
    """The LP serving path (DESIGN.md §6): submit_lp admission-gates on
    `lp_release_cost`, waves ride one `solve_lp_batch` dispatch, and the
    charging contract matches histogram releases."""

    @staticmethod
    def make_lp_service(Q, **kw):
        from repro.core import ScalarLPConfig
        from repro.core.queries import random_feasible_lp

        svc = make_service(Q, **kw)
        A, b, _ = random_feasible_lp(jax.random.PRNGKey(9), m=200, d=12)
        svc.attach_lp(A, b, ScalarLPConfig(eps=0.5, T=10, mode="fast"))
        return svc, A, b

    def test_lp_wave_lane_matches_single_fused_run(self, workload):
        from repro.core import solve_scalar_lp_fused

        Q, h = workload
        svc, A, b = self.make_lp_service(Q, wave_size=2, auto_flush=False)
        for i in range(2):
            add_tenant(svc, h, f"t{i}")
            svc.submit_lp(f"t{i}", seed=40 + i)
        svc.flush()
        assert svc.stats.dispatches == 1
        assert svc.stats.lp_released == 2
        for i in range(2):
            rel = svc.session(f"t{i}").latest_lp
            solo = solve_scalar_lp_fused(A, b, svc.lp.cfg,
                                         jax.random.PRNGKey(40 + i),
                                         index=svc.lp.index)
            np.testing.assert_allclose(rel.x_bar, np.asarray(solo.x_bar),
                                       atol=1e-6)
            assert rel.violated_frac == pytest.approx(solo.violated_frac,
                                                      abs=1e-6)

    def test_lp_admission_preview_equals_spend(self, workload):
        from repro.core import lp_release_cost

        Q, h = workload
        svc, A, b = self.make_lp_service(Q, auto_flush=False)
        sess = add_tenant(svc, h, "t0")
        ticket = svc.submit_lp("t0")
        assert ticket.kind == "lp" and ticket.status == "queued"
        svc.flush()
        spent = sess.ledger.composed()
        assert spent[0] == pytest.approx(ticket.decision.eps_projected,
                                         rel=1e-12)
        exp = PrivacyLedger().preview(*lp_release_cost(svc.lp.cfg, A,
                                                       index=svc.lp.index))
        assert spent == exp
        assert sess.latest_lp.eps_cost == pytest.approx(spent[0], rel=1e-12)

    def test_lp_over_budget_rejected_before_spend(self, workload):
        Q, h = workload
        svc, _, _ = self.make_lp_service(Q)
        sess = add_tenant(svc, h, "tiny", eps_budget=1e-4)
        ticket = svc.submit_lp("tiny")
        assert ticket.status == "rejected"
        assert len(sess.ledger.events) == 0
        assert svc.pending_count() == 0
        assert "exceeds budget" in ticket.decision.reason

    def test_reservations_pool_across_workloads(self, workload):
        """A queued histogram release reserves budget against an LP submit:
        jointly-overspending cross-workload requests can't both queue."""
        from repro.core import lp_release_cost
        from repro.core.mwem import release_cost

        Q, h = workload
        svc, A, _ = self.make_lp_service(Q, auto_flush=False)
        mwem_eps, _ = PrivacyLedger().preview(
            *release_cost(svc._group_cfg(N_RECORDS), M, U, index=svc.index))
        joint_eps, _ = PrivacyLedger().preview(
            list(release_cost(svc._group_cfg(N_RECORDS), M, U,
                              index=svc.index)[0])
            + list(lp_release_cost(svc.lp.cfg, A, index=svc.lp.index)[0]))
        add_tenant(svc, h, "t0", eps_budget=(mwem_eps + joint_eps) / 2)
        first = svc.submit("t0")
        second = svc.submit_lp("t0")
        assert first.status == "queued"
        assert second.status == "rejected"
        svc.flush()

    def test_padded_lp_wave_charges_only_real_lanes(self, workload):
        Q, h = workload
        svc, _, _ = self.make_lp_service(Q, wave_size=4, auto_flush=False)
        sess = add_tenant(svc, h, "solo")
        svc.submit_lp("solo")
        done = svc.flush()
        assert [t.status for t in done] == ["done"]
        assert svc.stats.padded_slots == 3
        assert len(sess.lp_releases) == 1
        # exactly one release's events, no pad-lane charges
        assert len(sess.ledger.events) == svc.lp.cfg.T

    def test_same_tenant_multi_lane_lp_costs_sum(self, workload):
        Q, h = workload
        svc, _, _ = self.make_lp_service(Q, wave_size=2, auto_flush=False)
        sess = add_tenant(svc, h, "t0")
        svc.submit_lp("t0")
        svc.submit_lp("t0")
        svc.flush()
        assert svc.stats.dispatches == 1
        costs = [r.eps_cost for r in sess.lp_releases]
        assert sum(costs) == pytest.approx(sess.spent()[0], rel=1e-9)
        assert costs[1] < costs[0]  # advanced composition

    def test_attach_and_submit_guards(self, workload):
        from repro.core import ScalarLPConfig

        Q, h = workload
        svc = make_service(Q)
        add_tenant(svc, h, "t0")
        with pytest.raises(ValueError, match="no LP workload"):
            svc.submit_lp("t0")
        svc2, A, b = self.make_lp_service(Q)
        with pytest.raises(ValueError, match="already attached"):
            svc2.attach_lp(A, b, ScalarLPConfig())
        svc3 = make_service(Q)
        # host driver must be refused at attach time — a wave-time failure
        # would strand already-admitted (budget-reserved) tickets
        with pytest.raises(ValueError, match="fused batch driver"):
            svc3.attach_lp(A, b, ScalarLPConfig(driver="host"))


class TestSessions:
    def test_from_tokens(self, workload):
        Q, _ = workload
        svc = make_service(Q)
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, U, size=2000)
        sess = svc.create_session("tok", eps_budget=50.0, delta_budget=0.5,
                                  tokens=tokens)
        assert sess.n_records == 2000
        assert sess.h.shape == (U,)
        assert sess.h.sum() == pytest.approx(1.0, abs=1e-5)

    def test_remaining_tracks_budget(self, workload):
        Q, h = workload
        svc = make_service(Q, wave_size=2)
        sess = add_tenant(svc, h, "t0", eps_budget=10.0)
        eps_rem0, _ = sess.remaining()
        assert eps_rem0 == pytest.approx(10.0)
        svc.submit("t0")
        svc.flush()
        eps_rem1, _ = sess.remaining()
        assert eps_rem1 < eps_rem0
        assert eps_rem1 == pytest.approx(10.0 - sess.spent()[0], rel=1e-12)

    def test_duplicate_session_rejected(self, workload):
        Q, h = workload
        svc = make_service(Q)
        add_tenant(svc, h, "t0")
        with pytest.raises(ValueError, match="already exists"):
            add_tenant(svc, h, "t0")


class TestSeedIssuance:
    """Regression for the seed-collision bug: an explicit ``seed=`` did not
    advance the auto counter, so a later auto-seeded ticket could share a
    PRNG stream (⇒ identical release, double-charged) with it."""

    def test_auto_seed_skips_explicit_seeds(self, workload):
        Q, h = workload
        svc = make_service(Q, auto_flush=False)
        add_tenant(svc, h, "t0")
        explicit = svc.submit("t0", seed=1)  # the counter's next-but-one
        auto = [svc.submit("t0") for _ in range(3)]
        seeds = [explicit.seed] + [t.seed for t in auto]
        assert explicit.seed == 1
        assert len(set(seeds)) == len(seeds), seeds

    def test_seed_uniqueness_across_workloads(self, workload):
        Q, h = workload
        svc = make_service(Q, auto_flush=False)
        svc.attach_lp(np.abs(np.asarray(Q[:8])), np.full(8, 0.9, np.float32))
        add_tenant(svc, h, "t0")
        tickets = [svc.submit("t0", seed=2), svc.submit_lp("t0"),
                   svc.submit("t0"), svc.submit_lp("t0", seed=5),
                   svc.submit("t0")]
        seeds = [t.seed for t in tickets]
        assert len(set(seeds)) == len(seeds), seeds
